//! Hermetic stub of the `xla` PJRT bindings.
//!
//! The turbomind `pjrt` feature compiles against exactly the API surface
//! below (see `runtime/client.rs` and `runtime/tensor.rs`). This stub keeps
//! that path *compiling* in environments without the real PJRT C API or any
//! network access; every runtime entry point fails with a clear error so a
//! misconfigured deployment cannot silently produce garbage.
//!
//! To run real AOT artifacts, replace the `xla = { path = "xla-stub" }`
//! dependency with the actual bindings (same crate name and API).

use std::fmt;

/// Error type returned by every stubbed entry point.
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla-stub: {}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla-stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable: this build links the hermetic xla stub. \
         Point the `xla` dependency at the real PJRT bindings to execute artifacts."
    ))
}

/// Element types crossing the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    U8,
    U32,
    F16,
    F32,
    F64,
}

/// A device handle (opaque in the stub).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A device buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A PJRT client.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A host-side literal.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self, Error> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn shape(&self) -> Result<Shape, Error> {
        Err(unavailable("Literal::shape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn copy_raw_to<T: Copy>(&self, _dst: &mut [T]) -> Result<(), Error> {
        Err(unavailable("Literal::copy_raw_to"))
    }
}

/// A literal's shape.
#[derive(Debug, Clone)]
pub struct Shape;

/// A dense array shape: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

impl TryFrom<&Shape> for ArrayShape {
    type Error = Error;

    fn try_from(_shape: &Shape) -> Result<Self, Error> {
        Err(unavailable("ArrayShape::try_from"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_errors_clearly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0; 4])
            .unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
    }
}

//! `cargo bench --bench codec_hotpath` — size sweeps over the word-level
//! quant codecs and the planned KV gather, vectorized vs the retained
//! scalar references.
//!
//! The summary trajectory (fixed shapes, JSON mirror, CI gate) lives in
//! `turbomind bench hotpath`; this binary is for poking at how the win
//! scales with row length and batch geometry.

use std::time::Instant;

use turbomind::kvcache::{KvLayout, KvPool};
use turbomind::quant::kv::{
    dequantize_kv_int4, dequantize_kv_int4_scalar, int4_from_int8, int4_from_int8_scalar,
};
use turbomind::quant::transcode::{int8_row_to_int4, int8_row_to_int4_scalar};
use turbomind::util::rng::Rng;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warm
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_codecs() {
    println!("\n== int4 codec: word-at-a-time vs scalar, by row length ==");
    let mut rng = Rng::new(7);
    for &n in &[64usize, 512, 4096, 32768] {
        let codes: Vec<i8> = (0..n).map(|_| (rng.next_u64() as u8) as i8).collect();
        let iters = (1 << 22) / n.max(1);
        let sp = time_it(iters, || {
            std::hint::black_box(int4_from_int8_scalar(&codes, 1.0));
        });
        let vp = time_it(iters, || {
            std::hint::black_box(int4_from_int8(&codes, 1.0));
        });
        let (packed, scale) = int4_from_int8(&codes, 1.0);
        let su = time_it(iters, || {
            std::hint::black_box(dequantize_kv_int4_scalar(&packed, n, scale));
        });
        let vu = time_it(iters, || {
            std::hint::black_box(dequantize_kv_int4(&packed, n, scale));
        });
        let bytes: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
        let mut dst = vec![0u8; n.div_ceil(2)];
        let st = time_it(iters, || {
            std::hint::black_box(int8_row_to_int4_scalar(&bytes, 0.02, &mut dst));
        });
        let vt = time_it(iters, || {
            std::hint::black_box(int8_row_to_int4(&bytes, 0.02, &mut dst));
        });
        println!(
            "  n={n:>5}: pack {:.2}x ({:.0} -> {:.0} ns)  unpack {:.2}x ({:.0} -> {:.0} ns)  transcode {:.2}x ({:.0} -> {:.0} ns)",
            sp / vp, sp * 1e9, vp * 1e9,
            su / vu, su * 1e9, vu * 1e9,
            st / vt, st * 1e9, vt * 1e9,
        );
    }
}

fn bench_gather() {
    println!("\n== kv gather: planned runs vs scalar walk (mixed 12-layer layout) ==");
    let n_layers = 12usize;
    let spec: String = (0..n_layers)
        .map(|l| format!("l{l}:{}", ["kv16", "kv16", "kv8", "kv8", "kv4", "kv4"][l % 6]))
        .collect::<Vec<_>>()
        .join(",");
    let (kv_heads, head_dim, bt, t_pad, seq_len) = (4usize, 32usize, 16usize, 256usize, 240usize);
    for &b in &[1usize, 4, 8] {
        let layout = KvLayout::parse(&spec, n_layers).unwrap();
        let mut pool =
            KvPool::with_layout(layout, kv_heads, head_dim, bt, b * t_pad + 4 * bt).unwrap();
        let per_side = kv_heads * pool.layout().sum_row_bytes(head_dim);
        let scales = vec![0.5f32; n_layers * kv_heads];
        let mut rng = Rng::new(11);
        let mut handles = Vec::new();
        for _ in 0..b {
            let h = pool.alloc_seq();
            for _ in 0..seq_len {
                let row: Vec<u8> = (0..per_side).map(|_| rng.next_u64() as u8).collect();
                pool.append_token(h, &row, &scales, &row, &scales).unwrap();
            }
            handles.push(Some(h));
        }
        let code_bytes = b * kv_heads * t_pad * pool.layout().sum_row_bytes(head_dim);
        let scale_len = n_layers * b * kv_heads * t_pad;
        let mut k_out = vec![0u8; code_bytes];
        let mut v_out = vec![0u8; code_bytes];
        let mut ks = vec![0f32; scale_len];
        let mut vs = vec![0f32; scale_len];
        let ss = time_it(20, || {
            pool.gather_batch_scalar(&handles, t_pad, &mut k_out, &mut ks, &mut v_out, &mut vs)
                .unwrap();
        });
        let vs_t = time_it(20, || {
            std::hint::black_box(
                pool.gather_batch(&handles, t_pad, &mut k_out, &mut ks, &mut v_out, &mut vs)
                    .unwrap(),
            );
        });
        let plan = pool.plan_gather(&handles, t_pad).unwrap();
        println!(
            "  B={b}: {:.2}x ({:.1} -> {:.1} µs), {} runs, {:.2} MB modeled HBM reads",
            ss / vs_t,
            ss * 1e6,
            vs_t * 1e6,
            plan.runs().len(),
            plan.hbm_bytes() as f64 / 1e6,
        );
    }
}

fn main() {
    println!("codec_hotpath: word-level codec + planned-gather sweeps (release profile)");
    bench_codecs();
    bench_gather();
}

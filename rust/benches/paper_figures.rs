//! `cargo bench --bench paper_figures` — regenerate every gpusim-backed
//! paper exhibit (Figs 11-21, 26-28, Table 2) and print the paper-style
//! tables, with generation wall-time per exhibit.

use std::time::Instant;

fn main() {
    let mut total = 0.0;
    for (name, f) in turbomind::bench::registry() {
        let t0 = Instant::now();
        let table = f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        table.print();
        println!("  [generated {name} in {:.2}s]", dt);
    }
    println!("\nall exhibits regenerated in {total:.2}s");
}

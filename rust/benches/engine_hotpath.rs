//! `cargo bench --bench engine_hotpath` — L3 request-path micro-benchmarks
//! on the real engine (the §Perf targets in DESIGN.md).
//!
//! Times the decode iteration end-to-end and its components: KV gather
//! (pool → padded batch tensors), backend execute, and KV append, across
//! batch buckets. Runs hermetically on the sim backend; the coordinator
//! target is that everything except backend execute stays a small fraction
//! of the iteration. The modeled A100 column is the gpusim prediction the
//! sim backend attaches per iteration.

use std::time::Instant;

use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, Request};
use turbomind::kvcache::{KvPool, KvPrecision};
use turbomind::util::rng::Rng;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_gather() {
    println!("\n== kv gather: pool -> padded batch tensors (tiny-qwen dims) ==");
    // tiny-qwen: L=4, Hkv=4, D=32, T=512.
    let (l, hkv, d, t_pad) = (4usize, 4usize, 32usize, 512usize);
    for &b in &[1usize, 4, 8] {
        let mut pool = KvPool::new(KvPrecision::Int8, l, hkv, d, 16, 16 * 512).unwrap();
        let mut handles = vec![];
        let rb = pool.row_bytes();
        let mut rng = Rng::new(1);
        for _ in 0..b {
            let h = pool.alloc_seq();
            for _ in 0..400 {
                let k: Vec<u8> = (0..l * hkv * rb).map(|_| rng.next_u64() as u8).collect();
                let s: Vec<f32> = (0..l * hkv).map(|_| rng.next_f32()).collect();
                pool.append_token(h, &k, &s, &k, &s).unwrap();
            }
            handles.push(Some(h));
        }
        let kdim = l * b * hkv * t_pad;
        let mut k_out = vec![0u8; kdim * rb];
        let mut v_out = k_out.clone();
        let mut ks = vec![0f32; kdim];
        let mut vs = ks.clone();
        let dt = time_it(50, || {
            pool.gather_batch(&handles, t_pad, &mut k_out, &mut ks, &mut v_out, &mut vs)
                .unwrap();
        });
        println!("  B={b}: {:.1} µs ({:.1} MB touched)", dt * 1e6,
                 (2 * k_out.len()) as f64 / 1e6);
    }
}

fn bench_engine_steps() {
    println!("\n== engine iteration latency (sim backend, W4A16KV8) ==");
    for &b in &[1usize, 2, 4, 8] {
        let cfg = EngineConfig {
            precision: "W4A16KV8".parse().unwrap(),
            max_batch: b,
            kv_pool_tokens: 16 * 512,
            max_new_tokens: 1 << 20,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg).unwrap();
        e.warmup().unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..b {
            let prompt: Vec<i32> = (0..24).map(|_| rng.below(2048) as i32).collect();
            e.submit(Request::new(prompt, 200)).unwrap();
        }
        // Drain prefills.
        while e.stats.decode_iters == 0 {
            e.step().unwrap();
        }
        let modeled_before = e.stats.sim_time_s;
        let t0 = Instant::now();
        let iters = 30;
        for _ in 0..iters {
            e.step().unwrap();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        let modeled_per = (e.stats.sim_time_s - modeled_before) / iters as f64;
        println!(
            "  decode B={b}: wall {:.3} ms/iter ({:.1} tok/s) | modeled A100 {:.3} ms/iter",
            per * 1e3,
            b as f64 / per,
            modeled_per * 1e3
        );
    }
}

fn main() {
    bench_gather();
    bench_engine_steps();
}

//! `cargo bench --bench table1_accuracy` — the Table 1 analogue: accuracy
//! equivalence of low-bit KV cache through the serving path.
//!
//! The paper shows GSM8K/MMLU parity between fp16-KV and 8-bit-KV serving.
//! Our primitive is sharper: per-token perplexity over a synthetic corpus,
//! measured through the *actual serving backend* at each KV precision —
//! chunk 1 builds a quantized past, chunk 2 attends it through the cache,
//! exactly the path Table 1 is about (a fresh prefill never reads the
//! quantized cache; chunk 2 does). Runs hermetically on the sim backend,
//! whose KV rows round-trip through the real `quant` codecs.

use turbomind::config::PrecisionFormat;
use turbomind::kvcache::KvPrecision;
use turbomind::runtime::{ExecutionBackend, ModelSpec, PrefillArgs, SimBackend};
use turbomind::util::rng::Rng;

fn softmax_nll(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f64 = logits.iter().map(|&x| ((x - m) as f64).exp()).sum();
    -(((logits[target] - m) as f64) - z.ln())
}

/// Perplexity of the second corpus chunk given the first chunk as *past
/// context stored at the serving KV precision*.
fn perplexity(format: &str, corpus: &[i32]) -> f64 {
    let precision: PrecisionFormat = format.parse().unwrap();
    let be = SimBackend::new(ModelSpec::tiny(), precision, 0, 4).unwrap();
    let m = be.model().clone();
    let s = 128usize; // prefill bucket
    let t_pad = m.max_seq_len;
    let rb = KvPrecision::from_dtype(precision.kv).unwrap().row_bytes(m.head_dim);

    // Chunk 1: build the quantized past from an empty cache.
    let n = m.n_layers * m.n_kv_heads * t_pad;
    let empty_codes = vec![0u8; n * rb];
    let ones = vec![1f32; n];
    let out1 = be
        .prefill(&PrefillArgs {
            tokens: &corpus[..s],
            real: s,
            pos: 0,
            t_pad,
            k_codes: &empty_codes,
            k_scales: &ones,
            v_codes: &empty_codes,
            v_scales: &ones,
        })
        .expect("chunk 1");

    // Scatter chunk-1 KV ([L,Hkv,S,rb]) into the gathered layout
    // ([L,1,Hkv,T,rb]) — what the pool's append + gather does.
    let mut k_cache = vec![0u8; n * rb];
    let mut v_cache = k_cache.clone();
    let mut ks_cache = vec![1f32; n];
    let mut vs_cache = ks_cache.clone();
    for l in 0..m.n_layers {
        for h in 0..m.n_kv_heads {
            for t in 0..s {
                let src = ((l * m.n_kv_heads + h) * s + t) * rb;
                let dst = ((l * m.n_kv_heads + h) * t_pad + t) * rb;
                k_cache[dst..dst + rb].copy_from_slice(&out1.k_codes[src..src + rb]);
                v_cache[dst..dst + rb].copy_from_slice(&out1.v_codes[src..src + rb]);
                let ssrc = (l * m.n_kv_heads + h) * s + t;
                let sdst = (l * m.n_kv_heads + h) * t_pad + t;
                ks_cache[sdst] = out1.k_scales[ssrc];
                vs_cache[sdst] = out1.v_scales[ssrc];
            }
        }
    }

    // Chunk 2: attends the quantized past; score its next-token NLLs.
    let out2 = be
        .prefill(&PrefillArgs {
            tokens: &corpus[s..2 * s],
            real: s,
            pos: s,
            t_pad,
            k_codes: &k_cache,
            k_scales: &ks_cache,
            v_codes: &v_cache,
            v_scales: &vs_cache,
        })
        .expect("chunk 2");

    let v = m.vocab_size;
    let mut nll = 0.0;
    for pos in 0..s - 1 {
        nll += softmax_nll(&out2.logits[pos * v..(pos + 1) * v], corpus[s + pos + 1] as usize);
    }
    (nll / (s - 1) as f64).exp()
}

fn main() {
    let vocab = ModelSpec::tiny().vocab_size;
    let mut rng = Rng::new(1234);
    let corpus: Vec<i32> = (0..256).map(|_| rng.below(vocab) as i32).collect();

    println!("\n== Table 1 analogue — KV-precision accuracy equivalence (sim backend) ==");
    println!("{:<12} {:>12}", "format", "perplexity");
    let mut results = vec![];
    for format in ["W16A16KV16", "W4A16KV16", "W4A16KV8", "W4A16KV4"] {
        let ppl = perplexity(format, &corpus);
        assert!(ppl.is_finite() && ppl > 0.0, "{format}: ppl {ppl}");
        println!("{format:<12} {ppl:>12.4}");
        results.push((format, ppl));
    }
    let base = results.iter().find(|r| r.0 == "W4A16KV16").unwrap().1;
    let kv8 = results.iter().find(|r| r.0 == "W4A16KV8").unwrap().1;
    let kv4 = results.iter().find(|r| r.0 == "W4A16KV4").unwrap().1;
    let d8 = (kv8 / base - 1.0) * 100.0;
    let d4 = (kv4 / base - 1.0) * 100.0;
    println!("\nKV8 ppl delta vs KV16: {d8:+.3}%   KV4: {d4:+.3}%");
    println!("paper Table 1: benchmark scores within 1-4 points across systems (equivalence)");
    assert!(d8.abs() < 5.0, "KV8 must be accuracy-equivalent, got {d8}%");
    assert!(d4.abs() < 25.0, "KV4 drift unexpectedly large: {d4}%");
    println!("accuracy equivalence: PASS");
}

//! `cargo bench --bench table1_accuracy` — the Table 1 analogue: accuracy
//! equivalence of low-bit KV cache on the real (PJRT) model.
//!
//! The paper shows GSM8K/MMLU parity between fp16-KV and 8-bit-KV serving.
//! Our primitive is sharper: per-token perplexity of the tiny model over a
//! synthetic corpus, measured through the *actual serving graphs* at each
//! KV precision, plus greedy-decode agreement. KV8 must be within a small
//! epsilon of KV16 ("accuracy equivalence"); KV4 may drift more.

use turbomind::runtime::{HostTensor, Manifest, Runtime};
use turbomind::util::rng::Rng;

fn softmax_nll(logits: &[f32], target: usize) -> f64 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let z: f64 = logits.iter().map(|&x| ((x - m) as f64).exp()).sum();
    -(((logits[target] - m) as f64) - z.ln())
}

/// Perplexity of the second corpus chunk given the first chunk as *past
/// context stored at the serving KV precision* — the path Table 1 is about
/// (a fresh prefill never reads the quantized cache; chunk 2 does).
fn perplexity(rt: &Runtime, wprec: &str, kvprec: &str, corpus: &[i32]) -> f64 {
    let m = &rt.manifest.model;
    let s = 128usize; // prefill bucket
    let t_pad = m.max_seq_len;
    let code_dt = match kvprec {
        "kv16" => turbomind::runtime::Dt::F32,
        "kv8" => turbomind::runtime::Dt::I8,
        "kv4" => turbomind::runtime::Dt::U8,
        _ => unreachable!(),
    };
    let rb_elems = match kvprec {
        "kv16" => m.head_dim,
        "kv8" => m.head_dim,
        "kv4" => m.head_dim / 2,
        _ => unreachable!(),
    };
    let kdim = m.n_layers * m.n_kv_heads * t_pad;
    let cache_shape = vec![m.n_layers, 1, m.n_kv_heads, t_pad, rb_elems];
    let scale_shape = vec![m.n_layers, 1, m.n_kv_heads, t_pad];
    let graph = Manifest::prefill_graph(wprec, kvprec, s);

    let run_chunk = |toks: &[i32], past: usize, kc: &HostTensor, ks: &HostTensor,
                     vc: &HostTensor, vs: &HostTensor| {
        rt.execute(
            &graph,
            &[
                HostTensor::from_i32(vec![s], toks).unwrap(),
                HostTensor::from_i32(vec![1], &[past as i32]).unwrap(),
                kc.clone(),
                ks.clone(),
                vc.clone(),
                vs.clone(),
            ],
        )
        .expect("prefill")
    };

    // Chunk 1: build the quantized past.
    let empty_k = HostTensor::zeros(code_dt, cache_shape.clone());
    let ones = HostTensor::from_f32(scale_shape.clone(), &vec![1f32; kdim]).unwrap();
    let toks1: Vec<i32> = corpus[..s].to_vec();
    let out1 = run_chunk(&toks1, 0, &empty_k, &ones, &empty_k, &ones);
    // Outputs: logits, k_chunk [L,Hkv,S,rb], k_scales [L,Hkv,S], v_chunk, v_scales.
    let (k_chunk, k_sc, v_chunk, v_sc) = (&out1[1], &out1[2], &out1[3], &out1[4]);

    // Scatter chunk-1 KV into the padded cache layout [L,1,Hkv,T,rb].
    let rb_bytes = rb_elems * code_dt.size();
    let mut k_cache = vec![0u8; m.n_layers * m.n_kv_heads * t_pad * rb_bytes];
    let mut v_cache = k_cache.clone();
    let mut ks_cache = vec![1f32; kdim];
    let mut vs_cache = ks_cache.clone();
    let ksf = k_sc.as_f32().unwrap();
    let vsf = v_sc.as_f32().unwrap();
    for l in 0..m.n_layers {
        for h in 0..m.n_kv_heads {
            for t in 0..s {
                let src = ((l * m.n_kv_heads + h) * s + t) * rb_bytes;
                let dst = ((l * m.n_kv_heads + h) * t_pad + t) * rb_bytes;
                k_cache[dst..dst + rb_bytes]
                    .copy_from_slice(&k_chunk.data[src..src + rb_bytes]);
                v_cache[dst..dst + rb_bytes]
                    .copy_from_slice(&v_chunk.data[src..src + rb_bytes]);
                let ssrc = (l * m.n_kv_heads + h) * s + t;
                let sdst = (l * m.n_kv_heads + h) * t_pad + t;
                ks_cache[sdst] = ksf[ssrc];
                vs_cache[sdst] = vsf[ssrc];
            }
        }
    }
    let kc = HostTensor::new(code_dt, cache_shape.clone(), k_cache).unwrap();
    let vc = HostTensor::new(code_dt, cache_shape, v_cache).unwrap();
    let ks = HostTensor::from_f32(scale_shape.clone(), &ks_cache).unwrap();
    let vs = HostTensor::from_f32(scale_shape, &vs_cache).unwrap();

    // Chunk 2: attends the quantized past; score its next-token NLLs.
    let toks2: Vec<i32> = corpus[s..2 * s].to_vec();
    let out2 = run_chunk(&toks2, s, &kc, &ks, &vc, &vs);
    let logits = out2[0].as_f32().unwrap();
    let v = m.vocab_size;
    let mut nll = 0.0;
    for pos in 0..s - 1 {
        nll += softmax_nll(&logits[pos * v..(pos + 1) * v], corpus[s + pos + 1] as usize);
    }
    (nll / (s - 1) as f64).exp()
}

fn main() {
    let dir = std::env::var("TM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(rt) = Runtime::load(&dir) else {
        eprintln!("SKIP table1_accuracy: artifacts not built (`make artifacts`)");
        return;
    };
    let vocab = rt.manifest.model.vocab_size;
    let mut rng = Rng::new(1234);
    let corpus: Vec<i32> = (0..256).map(|_| rng.below(vocab) as i32).collect();

    println!("\n== Table 1 analogue — KV-precision accuracy equivalence (tiny model, real graphs) ==");
    println!("{:<10} {:<10} {:>12}", "weights", "kv", "perplexity");
    let mut results = vec![];
    for (wprec, kvprec) in
        [("w16", "kv16"), ("w4", "kv16"), ("w4", "kv8"), ("w4", "kv4")]
    {
        let ppl = perplexity(&rt, wprec, kvprec, &corpus);
        println!("{wprec:<10} {kvprec:<10} {ppl:>12.4}");
        results.push((wprec, kvprec, ppl));
    }
    let base = results.iter().find(|r| r.1 == "kv16" && r.0 == "w4").unwrap().2;
    let kv8 = results.iter().find(|r| r.1 == "kv8").unwrap().2;
    let kv4 = results.iter().find(|r| r.1 == "kv4").unwrap().2;
    let d8 = (kv8 / base - 1.0) * 100.0;
    let d4 = (kv4 / base - 1.0) * 100.0;
    println!("\nKV8 ppl delta vs KV16: {d8:+.3}%   KV4: {d4:+.3}%");
    println!("paper Table 1: benchmark scores within 1-4 points across systems (equivalence)");
    assert!(d8.abs() < 2.0, "KV8 must be accuracy-equivalent, got {d8}%");
    assert!(d4.abs() < 10.0, "KV4 drift unexpectedly large: {d4}%");
    println!("accuracy equivalence: PASS");
}

//! `cargo bench --bench ablations` — design-choice ablations (DESIGN.md §5).
//!
//! * packing        — §4.1 offline packing on/off: measured transactions +
//!                    bank conflicts on real buffers, and the simulated GEMM
//!                    latency consequence.
//! * overlap        — §4.3 pipeline overlap fraction sweep: exposed dequant
//!                    cycles as overlap degrades (the Figure 9 mechanism).
//! * head_alignment — §4.2 Q-rearrange vs dequant-KV-before-load at each KV
//!                    precision.
//! * scheduler      — continuous vs static batching on the real engine
//!                    driving the hermetic sim backend (runs everywhere).

use turbomind::config::{DeviceProfile, EngineConfig};
use turbomind::config::engine::SchedulerPolicy;
use turbomind::coordinator::{Engine, Request};
use turbomind::gpusim::{
    AttentionKernelModel, AttnWorkload, Framework, GemmKernelModel, GemmWorkload, PipelineSim,
};
use turbomind::quant::access::analyze_global;
use turbomind::quant::packing::naive_fragment_access;
use turbomind::quant::{pack_weights_hw_aware, GroupwiseQuant, QuantizedMatrix};
use turbomind::util::rng::Rng;

fn ablate_packing() {
    println!("\n== ablation: §4.1 hardware-aware packing on/off ==");
    let (k, n) = (256usize, 4096usize);
    let mut rng = Rng::new(1);
    let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
    let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(64));
    let p = pack_weights_hw_aware(&q);

    let packed = p.runtime_load_report(0, 128);
    let naive = analyze_global(&naive_fragment_access(n, 0, 0), 128);
    println!(
        "  measured/tile-pair : packed {} tx, conflict {}  |  naive {} tx, conflict {}",
        packed.transactions, packed.bank_conflict_degree,
        naive.transactions * 2, naive.bank_conflict_degree
    );

    // Latency consequence via the GEMM model: packed = TurboMind traits;
    // naive = coalescing/banks degraded to the measured ratios.
    let dev = DeviceProfile::a100();
    let mut tm = Framework::TurboMind.traits_on(&dev);
    let g = GemmKernelModel::new(&dev, &tm).run(&GemmWorkload::w4a16(8, 8192, 8192)).time_s;
    tm.coalescing_eff = packed.transactions as f64 * 2.0 / naive.transactions as f64;
    tm.bank_conflict_factor = naive.bank_conflict_degree as f64 / 2.0;
    let g_naive = GemmKernelModel::new(&dev, &tm).run(&GemmWorkload::w4a16(8, 8192, 8192)).time_s;
    println!(
        "  simulated GEMM (B=8, 8192^2): packed {:.3} ms | naive layout {:.3} ms ({:.1}x slower)",
        g * 1e3, g_naive * 1e3, g_naive / g
    );
    assert!(g_naive / g > 2.0, "packing must matter");
}

fn ablate_overlap() {
    println!("\n== ablation: §4.3 MMA-dequant overlap sweep (16384^3 INT4, A100) ==");
    let dev = DeviceProfile::a100();
    let mut tr = Framework::TurboMind.traits_on(&dev);
    let f16 = PipelineSim::new(&dev, &tr).gemm(16384, 16384, 16384, 16).cycles;
    println!("  {:<10} {:>14} {:>12}", "overlap", "int4 cycles", "overhead");
    for ov in [0.0, 0.35, 0.55, 0.82, 0.95] {
        tr.dequant_overlap = ov;
        let c = PipelineSim::new(&dev, &tr).gemm(16384, 16384, 16384, 4).cycles;
        println!(
            "  {:<10.2} {:>14} {:>11.2}%",
            ov, c, (c as f64 / f16 as f64 - 1.0) * 100.0
        );
    }
    println!("  (paper Table 2 operating point: overlap ≈ 0.82 → +2.89% cycles)");
}

fn ablate_head_alignment() {
    println!("\n== ablation: §4.2 Q-rearrange vs dequant-KV-before-load ==");
    let dev = DeviceProfile::a100();
    let mut aligned = Framework::TurboMind.traits_on(&dev);
    let mut preload = Framework::TurboMind.traits_on(&dev);
    preload.attn_dequant_before_load = true;
    println!("  {:<8} {:>14} {:>16} {:>10}", "kv_bits", "aligned(ms)", "deq-before(ms)", "penalty");
    for kv_bits in [16usize, 8, 4] {
        let w = AttnWorkload::decode(32, 8192, 32, 8, 128, kv_bits);
        let a = AttentionKernelModel::new(&dev, &aligned).run(&w).time_s;
        let b = AttentionKernelModel::new(&dev, &preload).run(&w).time_s;
        println!(
            "  {:<8} {:>14.3} {:>16.3} {:>9.1}%",
            kv_bits, a * 1e3, b * 1e3, (b / a - 1.0) * 100.0
        );
        if kv_bits < 16 {
            assert!(b > a, "alignment must win for quantized KV");
        }
    }
    let _ = &mut aligned; // symmetry
}

fn ablate_scheduler() {
    println!("\n== ablation: continuous vs static batching (engine on sim backend) ==");
    for (name, policy) in [
        ("continuous", SchedulerPolicy::Continuous),
        ("static", SchedulerPolicy::Static),
    ] {
        let cfg = EngineConfig {
            precision: "W4A16KV8".parse().unwrap(),
            max_batch: 4,
            kv_pool_tokens: 16 * 256,
            scheduler: policy,
            ..EngineConfig::default()
        };
        let mut e = Engine::new(cfg).unwrap();
        e.warmup().unwrap();
        let t0 = std::time::Instant::now();
        let mut rng = Rng::new(3);
        for i in 0..8 {
            let prompt: Vec<i32> = (0..20 + i * 3).map(|_| rng.below(2048) as i32).collect();
            e.submit(Request::new(prompt, 12)).unwrap();
        }
        let outs = e.run_to_completion().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), 8, "{name}: all requests must complete");
        let mean_ttft: f64 =
            outs.iter().map(|o| o.ttft).sum::<f64>() / outs.len() as f64;
        println!(
            "  {:<12} wall {:>7.3}s  modeled {:>8.5}s  mean TTFT {:>7.4}s  decode iters {}",
            name, dt, e.stats.sim_time_s, mean_ttft, e.stats.decode_iters
        );
        assert!(e.stats.sim_time_s > 0.0, "{name}: backend must report modeled time");
    }
    println!("  (continuous admits mid-drain; static waits — TTFT is where they differ)");
}

fn main() {
    ablate_packing();
    ablate_overlap();
    ablate_head_alignment();
    ablate_scheduler();
}

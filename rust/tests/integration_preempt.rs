//! Preemption-under-KV-pressure integration tests (DESIGN.md §8) on the
//! hermetic sim backend: a randomized overload harness (bursty arrivals
//! against deliberately tiny pools across kv16/kv8/kv4 × both scheduler
//! policies × all three preemption modes), deterministic engineered
//! overflows for each mode, and a golden pressure-free determinism
//! regression guarding PR 2's chunk-alignment invariant.
//!
//! The load-bearing claims:
//!   (a) swap/recompute modes lose **nothing** — every request completes;
//!   (b) pool + swap-store accounting balances to zero at drain;
//!   (c) outputs are **bit-identical** to an unpressured run of the same
//!       seeds (greedy sampling; KV restored byte-exactly by swap, or
//!       regenerated exactly by recompute — sim KV codes are a pure
//!       function of (token, position)).

use turbomind::config::engine::{PreemptionMode, SchedulerPolicy};
use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request, RequestOutput};
use turbomind::kvcache::SwapBackend;
use turbomind::util::proptest::run_prop;
use turbomind::workload::BurstGen;

fn cfg(
    precision: &str,
    policy: SchedulerPolicy,
    mode: PreemptionMode,
    cache: bool,
    block_tokens: usize,
    pool_blocks: usize,
) -> EngineConfig {
    EngineConfig {
        precision: precision.parse().unwrap(),
        max_batch: 4,
        kv_block_tokens: block_tokens,
        kv_pool_tokens: block_tokens * pool_blocks,
        prefill_chunk: 32,
        scheduler: policy,
        enable_prefix_cache: cache,
        preemption_mode: mode,
        ..EngineConfig::default()
    }
}

/// Submit every request up front (a burst), run to drain, return outputs
/// sorted by id alongside the engine for post-mortem accounting checks.
fn run_burst(cfg: EngineConfig, reqs: &[(Vec<i32>, usize)]) -> (Engine, Vec<RequestOutput>) {
    let mut e = Engine::new(cfg).unwrap();
    for (prompt, gen) in reqs {
        e.submit(Request::new(prompt.clone(), *gen)).unwrap();
    }
    let mut outs = e.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    (e, outs)
}

/// Drain-time accounting: only prefix-index-pinned blocks may remain in
/// the pool (each with exactly one reference), and the swap store must be
/// empty with entry-level conservation (outs = ins + downgraded drops).
fn assert_drained(e: &Engine, ctx: &str) {
    let pool = e.kv_pool();
    assert_eq!(
        pool.used_blocks(),
        e.prefix_cached_blocks(),
        "{ctx}: non-index blocks leaked at drain"
    );
    let single_ref =
        (0..pool.total_blocks()).filter(|&b| pool.block_ref_count(b) == 1).count();
    assert_eq!(single_ref, e.prefix_cached_blocks(), "{ctx}: index pins exactly one ref");
    assert!(
        (0..pool.total_blocks()).all(|b| pool.block_ref_count(b) <= 1),
        "{ctx}: stray references at drain"
    );
    let swap = e.swap_store();
    assert!(swap.is_empty(), "{ctx}: swap store must drain");
    assert_eq!(swap.used_blocks(), 0, "{ctx}");
    assert_eq!(
        swap.stats().swap_outs,
        swap.stats().swap_ins + swap.stats().dropped,
        "{ctx}: every swap-out is either restored or downgraded"
    );
}

#[test]
fn randomized_overload_swap_and_recompute_lose_nothing_and_stay_bit_identical() {
    // The acceptance matrix is sampled per case: precision × policy ×
    // prefix-cache, with random bursty request sets against a ~3×
    // oversubscribed pool; both lossless modes run every case. Aggregated
    // counters prove the harness genuinely exercised both mechanisms.
    let mut preemptions = 0usize;
    let mut swaps = 0usize;
    let mut recomputes = 0usize;
    run_prop("preempt-overload", 0x0E11_0AD5, 10, |g| {
        let precision = *g.choose(&["W4A16KV16", "W4A16KV8", "W4A16KV4"]);
        let policy =
            if g.bool() { SchedulerPolicy::Continuous } else { SchedulerPolicy::Static };
        let cache = g.bool();
        let n = g.usize_in(4, 6);
        let mut reqs: Vec<(Vec<i32>, usize)> = Vec::new();
        for _ in 0..n {
            // Short prompts (1-2 blocks) with long generations: several
            // requests co-admit cheaply, then outgrow the pool together —
            // the shape that forces mid-decode preemption.
            let p_len = g.usize_in(8, 15);
            let gen = g.usize_in(16, 40);
            let prompt: Vec<i32> = (0..p_len).map(|_| g.usize_in(0, 2047) as i32).collect();
            reqs.push((prompt, gen));
        }
        let bt = 8usize;
        let need = |r: &(Vec<i32>, usize)| (r.0.len() + r.1).div_ceil(bt);
        let max_need = reqs.iter().map(need).max().unwrap();
        let sum_need: usize = reqs.iter().map(need).sum();
        // Every request individually fits; collectively they want ~3×.
        let pool_blocks = max_need.max(sum_need / 3).max(2);

        // Unpressured baseline of the same seeds (roomy pool, legacy mode).
        let (be, baseline) =
            run_burst(cfg(precision, policy, PreemptionMode::Abort, cache, bt, 512), &reqs);
        assert!(baseline.iter().all(|o| o.finish != FinishReason::Aborted));
        assert_eq!(be.preempt_stats.preemptions, 0, "roomy pool must not preempt");

        for mode in [PreemptionMode::Swap, PreemptionMode::Recompute] {
            let ctx = format!(
                "{precision} {policy:?} {mode:?} cache={cache} pool={pool_blocks}blk (case {:#x})",
                g.seed
            );
            let (e, outs) = run_burst(cfg(precision, policy, mode, cache, bt, pool_blocks), &reqs);
            // (a) no request lost or aborted.
            assert_eq!(outs.len(), n, "{ctx}: outputs lost");
            assert_eq!(e.preempt_stats.oom_aborts, 0, "{ctx}");
            for (o, b) in outs.iter().zip(&baseline) {
                assert_ne!(o.finish, FinishReason::Aborted, "{ctx}: req {} aborted", o.id);
                // (c) bit-identical to the unpressured baseline.
                assert_eq!(o.tokens, b.tokens, "{ctx}: req {} diverged", o.id);
                assert_eq!(o.finish, b.finish, "{ctx}: req {}", o.id);
            }
            // (b) accounting balances to zero.
            assert_drained(&e, &ctx);
            preemptions += e.preempt_stats.preemptions;
            swaps += e.preempt_stats.swap_preemptions;
            recomputes += e.preempt_stats.recompute_preemptions;
        }
    });
    assert!(preemptions > 0, "harness never hit the preemption path — pools too roomy");
    assert!(swaps > 0, "harness never exercised swap-out");
    assert!(recomputes > 0, "harness never exercised recompute");
}

/// Three 17-prompt/32-gen requests against an 8×16-token pool overflow by
/// arithmetic, not timing: each admits holding 2 blocks (conservative need
/// 4 ≤ free at admission), then all three cross the 32-token block
/// boundary in lockstep needing 3 blocks with only 2 free.
fn engineered_overflow() -> Vec<(Vec<i32>, usize)> {
    (0..3)
        .map(|i| {
            let prompt: Vec<i32> = (0..17).map(|j| ((i * 211 + j * 7) % 2048) as i32).collect();
            (prompt, 32usize)
        })
        .collect()
}

#[test]
fn abort_mode_returns_partial_generation_with_structured_reason() {
    // The satellite fix: the legacy path must *report* the overload — the
    // doomed request keeps its generated-so-far tokens and carries an
    // explicit machine-readable reason, instead of tokens + eprintln-only
    // diagnostics.
    let reqs = engineered_overflow();
    let (e, outs) = run_burst(
        cfg("W4A16KV8", SchedulerPolicy::Continuous, PreemptionMode::Abort, false, 16, 8),
        &reqs,
    );
    assert_eq!(outs.len(), 3);
    let aborted: Vec<_> =
        outs.iter().filter(|o| o.finish == FinishReason::Aborted).collect();
    assert_eq!(aborted.len(), 1, "exactly the youngest victim dies");
    let victim = aborted[0];
    assert_eq!(victim.id, 2, "append order makes the last sequence fail");
    assert_eq!(victim.tokens.len(), 16, "partial generation returned, not dropped");
    assert!(
        victim.abort_reason.as_deref().unwrap_or("").contains("exhausted"),
        "{:?}",
        victim.abort_reason
    );
    assert_eq!(e.stats.aborted, 1);
    assert_eq!(e.preemption_summary().oom_aborts, 1);
    for o in outs.iter().filter(|o| o.finish != FinishReason::Aborted) {
        assert_eq!(o.tokens.len(), 32);
        assert!(o.abort_reason.is_none());
    }
}

#[test]
fn swap_mode_preserves_the_victim_byte_exactly() {
    let reqs = engineered_overflow();
    let (_, baseline) = run_burst(
        cfg("W4A16KV8", SchedulerPolicy::Continuous, PreemptionMode::Abort, false, 16, 512),
        &reqs,
    );
    let (e, outs) = run_burst(
        cfg("W4A16KV8", SchedulerPolicy::Continuous, PreemptionMode::Swap, false, 16, 8),
        &reqs,
    );
    assert_eq!(outs.len(), 3);
    for (o, b) in outs.iter().zip(&baseline) {
        assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
        assert_eq!(o.tokens.len(), 32);
        assert_eq!(o.tokens, b.tokens, "req {}: swap round-trip must be bit-exact", o.id);
    }
    // The youngest sequence was the cost-model victim: tied costs break
    // toward the highest id, and its resume restored both resident blocks.
    assert!(outs[2].preempt_count >= 1, "victim must record its preemption");
    assert_eq!(outs[2].swapped_in_blocks, 2);
    assert_eq!(outs[0].preempt_count + outs[1].preempt_count, 0);
    assert!(e.preempt_stats.swap_preemptions >= 1);
    assert_eq!(e.stats.aborted, 0);
    assert_drained(&e, "engineered swap");
}

#[test]
fn recompute_mode_regenerates_the_victim_exactly() {
    let reqs = engineered_overflow();
    let (_, baseline) = run_burst(
        cfg("W4A16KV8", SchedulerPolicy::Continuous, PreemptionMode::Abort, false, 16, 512),
        &reqs,
    );
    let (e, outs) = run_burst(
        cfg("W4A16KV8", SchedulerPolicy::Continuous, PreemptionMode::Recompute, false, 16, 8),
        &reqs,
    );
    for (o, b) in outs.iter().zip(&baseline) {
        assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
        assert_eq!(o.tokens, b.tokens, "req {}: recompute must be bit-exact", o.id);
    }
    assert!(outs[2].preempt_count >= 1);
    assert_eq!(outs[2].swapped_in_blocks, 0, "recompute never touches the swap store");
    assert!(e.preempt_stats.recompute_preemptions >= 1);
    // The victim re-prefilled its prompt + generated prefix (32 tokens).
    assert!(e.preempt_stats.recomputed_tokens >= 32);
    assert_eq!(e.swap_store().stats().swap_outs, 0);
    assert_eq!(e.stats.aborted, 0);
    assert_drained(&e, "engineered recompute");
}

#[test]
fn golden_fixed_trace_is_identical_with_preemption_on_pressure_free() {
    // Golden determinism regression: a fixed-seed burst trace through a
    // roomy pool must produce identical token streams with preemption off
    // vs on (both modes, both policies, prefix cache off and on) — the
    // chunk-alignment invariant PR 2 established survives the new
    // admission/resume machinery, and an unpressured engine never pays a
    // preemption.
    let gen = BurstGen {
        bursts: 2,
        burst_size: 4,
        gap_s: 1.0,
        prompt_tokens: 40,
        gen_tokens: 16,
        seed: 0x601D,
    };
    let trace = gen.generate();
    let reqs: Vec<(Vec<i32>, usize)> = trace
        .iter()
        .enumerate()
        .map(|(i, r)| (gen.prompt_tokens(i, r.prompt_tokens, 2048), r.gen_tokens))
        .collect();
    for policy in [SchedulerPolicy::Continuous, SchedulerPolicy::Static] {
        let (_, golden) =
            run_burst(cfg("W4A16KV8", policy, PreemptionMode::Abort, false, 16, 512), &reqs);
        assert!(golden.iter().all(|o| o.finish == FinishReason::Length));
        for mode in [PreemptionMode::Swap, PreemptionMode::Recompute] {
            for cache in [false, true] {
                let ctx = format!("{policy:?} {mode:?} cache={cache}");
                let (e, outs) =
                    run_burst(cfg("W4A16KV8", policy, mode, cache, 16, 512), &reqs);
                assert_eq!(outs.len(), golden.len(), "{ctx}");
                for (o, b) in outs.iter().zip(&golden) {
                    assert_eq!(o.tokens, b.tokens, "{ctx}: req {} drifted", o.id);
                    assert_eq!(o.preempt_count, 0, "{ctx}");
                    assert_eq!(o.swapped_in_blocks, 0, "{ctx}");
                }
                assert_eq!(e.preempt_stats.preemptions, 0, "{ctx}: phantom preemption");
                assert_eq!(e.stats.preempt_iters, 0, "{ctx}");
                assert!(e.swap_store().is_empty(), "{ctx}");
            }
        }
    }
}

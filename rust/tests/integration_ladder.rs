//! Precision-laddering integration tests (DESIGN.md §10) on the hermetic
//! sim backend: a randomized overload harness across admission layouts ×
//! both scheduler policies, an engineered deterministic multi-rung
//! descent, a pool-level bitwise equivalence property for in-place
//! relayout, and the negative prefix-cache test.
//!
//! The load-bearing claims:
//!   (a) ladder mode loses **nothing** — every request completes, and the
//!       per-mechanism buckets partition `preemptions` exactly
//!       (swap + recompute + ladder);
//!   (b) pool + swap-store accounting balances to zero at drain;
//!   (c) the determinism contract: greedy outputs at a given *final*
//!       per-layer precision assignment are **bit-identical** to an
//!       unpressured run admitted at that assignment, on both schedulers;
//!   (d) in-place transcode (including multi-rung chains) produces codes
//!       and scales bit-identical to admitting directly at the target
//!       layout;
//!   (e) the prefix index never serves a stale-precision block after a
//!       ladder event — old-layout entries are invalidated wholesale,
//!       while fresh blocks registered at the new layout still hit.

use turbomind::config::engine::{LadderPolicy, PreemptionMode, SchedulerPolicy};
use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request, RequestOutput};
use turbomind::kvcache::{KvLayout, KvPool, KvPrecision, SeqHandle, SwapBackend};
use turbomind::quant::{quantize_kv_int4, quantize_kv_int8};
use turbomind::util::proptest::run_prop;

fn cfg(
    layout: &str,
    policy: SchedulerPolicy,
    mode: PreemptionMode,
    ladder: LadderPolicy,
    cache: bool,
    block_tokens: usize,
    pool_blocks: usize,
) -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        kv_block_tokens: block_tokens,
        kv_pool_tokens: block_tokens * pool_blocks,
        prefill_chunk: 32,
        scheduler: policy,
        enable_prefix_cache: cache,
        preemption_mode: mode,
        ladder_policy: ladder,
        kv_layout: Some(layout.to_string()),
        ..EngineConfig::default()
    }
}

/// Submit every request up front (a burst), run to drain, return outputs
/// sorted by id alongside the engine for post-mortem accounting checks.
fn run_burst(cfg: EngineConfig, reqs: &[(Vec<i32>, usize)]) -> (Engine, Vec<RequestOutput>) {
    let mut e = Engine::new(cfg).unwrap();
    for (prompt, gen) in reqs {
        e.submit(Request::new(prompt.clone(), *gen)).unwrap();
    }
    let mut outs = e.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    (e, outs)
}

/// Drain-time accounting: only prefix-index-pinned blocks may remain in
/// the pool, the swap store must be empty with entry-level conservation,
/// and the preemption buckets must partition the total exactly.
fn assert_drained(e: &Engine, ctx: &str) {
    let pool = e.kv_pool();
    assert_eq!(
        pool.used_blocks(),
        e.prefix_cached_blocks(),
        "{ctx}: non-index blocks leaked at drain"
    );
    assert!(
        (0..pool.total_blocks()).all(|b| pool.block_ref_count(b) <= 1),
        "{ctx}: stray references at drain"
    );
    let swap = e.swap_store();
    assert!(swap.is_empty(), "{ctx}: swap store must drain");
    assert_eq!(
        swap.stats().swap_outs,
        swap.stats().swap_ins + swap.stats().dropped,
        "{ctx}: every swap-out is either restored or downgraded"
    );
    let p = e.preempt_stats;
    assert_eq!(
        p.preemptions,
        p.swap_preemptions + p.recompute_preemptions + p.ladder_preemptions,
        "{ctx}: mechanism buckets must partition preemptions"
    );
}

/// Replay `reqs` unpressured (roomy pool, ladder off) admitted at each
/// distinct final layout seen in `outs`, and demand every pressured output
/// is bit-identical to its replay — the determinism contract, stated
/// against the *final* per-layer precision assignment.
fn assert_replays_at_final_layout(
    outs: &[RequestOutput],
    reqs: &[(Vec<i32>, usize)],
    policy: SchedulerPolicy,
    block_tokens: usize,
    ctx: &str,
) {
    let mut layouts: Vec<&str> = outs.iter().map(|o| o.final_kv_layout.as_str()).collect();
    layouts.sort_unstable();
    layouts.dedup();
    for layout in layouts {
        let (be, base) = run_burst(
            cfg(layout, policy, PreemptionMode::Abort, LadderPolicy::Off, false, block_tokens, 512),
            reqs,
        );
        assert_eq!(be.preempt_stats.preemptions, 0, "{ctx}: roomy replay must not preempt");
        assert_eq!(be.preempt_stats.ladder_events, 0, "{ctx}: replay must not ladder");
        for (o, b) in outs.iter().zip(&base) {
            if o.final_kv_layout == layout {
                assert_eq!(
                    o.tokens, b.tokens,
                    "{ctx}: req {} diverged from its final-layout ({layout}) replay",
                    o.id
                );
                assert_eq!(o.finish, b.finish, "{ctx}: req {}", o.id);
            }
        }
    }
}

#[test]
fn randomized_ladder_overload_loses_nothing_and_replays_at_final_layout() {
    // Admission layout × both scheduler policies × prefix-cache × (ladder
    // vs auto-on-swap) against ~3× oversubscribed pools. Aggregated
    // counters prove the harness genuinely took ladder rungs.
    let mut ladder_events = 0usize;
    let mut ladder_preemptions = 0usize;
    run_prop("ladder-overload", 0x1ADD_3600, 6, |g| {
        let admit =
            *g.choose(&["kv16", "l0:kv16,l1:kv8,l2:kv8,l3:kv4", "kv8"]);
        let cache = g.bool();
        // mode Ladder prefers the rung explicitly; mode Swap + policy Auto
        // is the `--kv-ladder auto` path — both must be lossless.
        let mode = if g.bool() { PreemptionMode::Ladder } else { PreemptionMode::Swap };
        let n = g.usize_in(4, 6);
        let mut reqs: Vec<(Vec<i32>, usize)> = Vec::new();
        for _ in 0..n {
            let p_len = g.usize_in(8, 15);
            let gen = g.usize_in(16, 40);
            let prompt: Vec<i32> = (0..p_len).map(|_| g.usize_in(0, 2047) as i32).collect();
            reqs.push((prompt, gen));
        }
        let bt = 8usize;
        let need = |r: &(Vec<i32>, usize)| (r.0.len() + r.1).div_ceil(bt);
        let max_need = reqs.iter().map(need).max().unwrap();
        let sum_need: usize = reqs.iter().map(need).sum();
        let pool_blocks = max_need.max(sum_need / 3).max(2);

        for policy in [SchedulerPolicy::Continuous, SchedulerPolicy::Static] {
            let ctx = format!(
                "{admit} {policy:?} {mode:?} cache={cache} pool={pool_blocks}blk (case {:#x})",
                g.seed
            );
            let (e, outs) = run_burst(
                cfg(admit, policy, mode, LadderPolicy::Auto, cache, bt, pool_blocks),
                &reqs,
            );
            // (a) zero request loss.
            assert_eq!(outs.len(), n, "{ctx}: outputs lost");
            assert_eq!(e.preempt_stats.oom_aborts, 0, "{ctx}");
            for o in &outs {
                assert_ne!(o.finish, FinishReason::Aborted, "{ctx}: req {} aborted", o.id);
            }
            // (b) accounting drains to zero, buckets partition.
            assert_drained(&e, &ctx);
            // (c) bit-identical to an unpressured run admitted at the
            // final assignment — on this scheduler.
            assert_replays_at_final_layout(&outs, &reqs, policy, bt, &ctx);
            ladder_events += e.preempt_stats.ladder_events;
            ladder_preemptions += e.preempt_stats.ladder_preemptions;
        }
    });
    assert!(ladder_events > 0, "harness never took a ladder rung — pools too roomy");
    assert!(ladder_preemptions > 0, "harness never restarted a decoding victim via ladder");
}

/// Three 17-prompt/32-gen requests against an 8×16-token kv16 pool: all
/// three admit holding 2 blocks, then cross block boundaries in lockstep.
/// The single-rung gain (+1 block) cannot cover the later 3-block
/// shortfall, so the deepened rung search must descend multiple rungs in
/// one relayout — and the run still completes with zero loss.
#[test]
fn engineered_overflow_descends_multiple_rungs_and_stays_deterministic() {
    let reqs: Vec<(Vec<i32>, usize)> = (0..3)
        .map(|i| {
            let prompt: Vec<i32> = (0..17).map(|j| ((i * 211 + j * 7) % 2048) as i32).collect();
            (prompt, 32usize)
        })
        .collect();
    for policy in [SchedulerPolicy::Continuous, SchedulerPolicy::Static] {
        let ctx = format!("engineered ladder {policy:?}");
        let (e, outs) = run_burst(
            cfg("kv16", policy, PreemptionMode::Ladder, LadderPolicy::Auto, false, 16, 8),
            &reqs,
        );
        assert_eq!(outs.len(), 3, "{ctx}");
        for o in &outs {
            assert_eq!(o.finish, FinishReason::Length, "{ctx}: req {}", o.id);
            assert_eq!(o.tokens.len(), 32, "{ctx}: req {}", o.id);
        }
        let p = e.preempt_stats;
        assert!(p.ladder_events >= 1, "{ctx}: the rung must fire");
        assert!(p.ladder_preemptions >= 1, "{ctx}: decoding victims restart via ladder");
        assert!(p.ladder_dropped_tokens > 0, "{ctx}: restarts re-decode dropped tokens");
        assert!(p.ladder_transcoded_bytes > 0, "{ctx}");
        assert!(p.ladder_freed_bytes > 0, "{ctx}");
        assert_eq!(p.oom_aborts, 0, "{ctx}");
        // All three drained together after the last rung: one final layout,
        // narrower than admission, and it is what the pool now holds.
        let fin = outs[0].final_kv_layout.clone();
        assert_ne!(fin, "kv16", "{ctx}: pool must have laddered down");
        for o in &outs {
            assert_eq!(o.final_kv_layout, fin, "{ctx}: req {}", o.id);
        }
        assert_eq!(e.kv_pool().layout().to_string(), fin, "{ctx}");
        assert!(outs.iter().any(|o| o.ladder_count >= 1), "{ctx}: ladder_count must surface");
        assert_drained(&e, &ctx);
        assert_replays_at_final_layout(&outs, &reqs, policy, 16, &ctx);
    }
}

/// Encode one float row at `prec` exactly as the sim graphs emit it: kv16
/// rows are little-endian f32 with scale 1.0, kv8/kv4 are the per-row
/// max-abs quantizers.
fn encode_row(prec: KvPrecision, row: &[f32]) -> (Vec<u8>, f32) {
    match prec {
        KvPrecision::F32 => (row.iter().flat_map(|v| v.to_le_bytes()).collect(), 1.0),
        KvPrecision::Int8 => {
            let (c, s) = quantize_kv_int8(row);
            (c.iter().map(|&x| x as u8).collect(), s)
        }
        KvPrecision::Int4 => quantize_kv_int4(row),
    }
}

/// Flatten one token's per-(layer, head) float rows into the pool's
/// `[L, Hkv, rb_l]` append payload at `layout`.
fn token_payload(
    layout: &KvLayout,
    head_dim: usize,
    heads: usize,
    rows: &[Vec<f32>],
) -> (Vec<u8>, Vec<f32>) {
    let layers = layout.n_layers();
    let mut codes = Vec::new();
    let mut scales = Vec::with_capacity(layers * heads);
    for l in 0..layers {
        for hh in 0..heads {
            let (c, s) = encode_row(layout.prec(l), &rows[l * heads + hh]);
            assert_eq!(c.len(), layout.row_bytes(l, head_dim));
            codes.extend_from_slice(&c);
            scales.push(s);
        }
    }
    (codes, scales)
}

fn append_all(
    pool: &mut KvPool,
    h: SeqHandle,
    head_dim: usize,
    heads: usize,
    k_rows: &[Vec<Vec<f32>>],
    v_rows: &[Vec<Vec<f32>>],
) {
    for (kr, vr) in k_rows.iter().zip(v_rows) {
        let layout = pool.layout().clone();
        let (kc, ks) = token_payload(&layout, head_dim, heads, kr);
        let (vc, vs) = token_payload(&layout, head_dim, heads, vr);
        pool.append_token(h, &kc, &ks, &vc, &vs).unwrap();
    }
}

/// Gather one sequence and return (codes, scale bit patterns) for K and V.
fn gather_bits(
    pool: &KvPool,
    h: SeqHandle,
    t: usize,
    layers: usize,
    heads: usize,
    head_dim: usize,
) -> (Vec<u8>, Vec<u32>, Vec<u8>, Vec<u32>) {
    let n = heads * t * pool.layout().sum_row_bytes(head_dim);
    let mut k = vec![0u8; n];
    let mut v = vec![0u8; n];
    let mut ks = vec![0f32; layers * heads * t];
    let mut vs = vec![0f32; layers * heads * t];
    pool.gather_batch(&[Some(h)], t, &mut k, &mut ks, &mut v, &mut vs).unwrap();
    let kb = ks.iter().map(|s| s.to_bits()).collect();
    let vb = vs.iter().map(|s| s.to_bits()).collect();
    (k, kb, v, vb)
}

#[test]
fn relayout_transcode_matches_direct_admission_bitwise() {
    // Three pools fed the same float rows: (A) admitted wide, laddered
    // down in two rungs; (C) admitted wide, laddered straight to the final
    // layout; (B) admitted at the final layout directly. All three must
    // hold byte-identical codes and bit-identical scales — the transcode
    // invariant, including multi-rung transitivity, that lets the engine's
    // deepened rung search execute one relayout to a distant target.
    run_prop("ladder-transcode-bitwise", 0x1ADD_B175, 25, |g| {
        let layers = 4usize;
        let heads = 2usize;
        let head_dim = *g.choose(&[7usize, 8, 32]);
        let bt = 4usize;
        let pool_tokens = 16usize;
        let admit = KvLayout::parse("kv16", layers).unwrap();
        let mid = KvLayout::parse(
            *g.choose(&["kv8", "l0:kv16,l1:kv8,l2:kv8,l3:kv4"]),
            layers,
        )
        .unwrap();
        let fin = KvLayout::parse(
            *g.choose(&["kv4", "l0:kv8,l1:kv4,l2:kv4,l3:kv4"]),
            layers,
        )
        .unwrap();
        let t = g.usize_in(1, pool_tokens);
        let row = |g: &mut turbomind::util::proptest::Gen| {
            (0..layers * heads).map(|_| g.f32_vec(head_dim, -8.0, 8.0)).collect::<Vec<_>>()
        };
        let k_rows: Vec<Vec<Vec<f32>>> = (0..t).map(|_| row(g)).collect();
        let v_rows: Vec<Vec<Vec<f32>>> = (0..t).map(|_| row(g)).collect();

        let mut a = KvPool::with_layout(admit.clone(), heads, head_dim, bt, pool_tokens).unwrap();
        let ha = a.alloc_seq();
        append_all(&mut a, ha, head_dim, heads, &k_rows, &v_rows);
        a.relayout(&mid).unwrap();
        a.relayout(&fin).unwrap();

        let mut c = KvPool::with_layout(admit, heads, head_dim, bt, pool_tokens).unwrap();
        let hc = c.alloc_seq();
        append_all(&mut c, hc, head_dim, heads, &k_rows, &v_rows);
        c.relayout(&fin).unwrap();

        let mut b = KvPool::with_layout(fin.clone(), heads, head_dim, bt, pool_tokens).unwrap();
        let hb = b.alloc_seq();
        append_all(&mut b, hb, head_dim, heads, &k_rows, &v_rows);

        let ga = gather_bits(&a, ha, t, layers, heads, head_dim);
        let gb = gather_bits(&b, hb, t, layers, heads, head_dim);
        let gc = gather_bits(&c, hc, t, layers, heads, head_dim);
        assert_eq!(ga, gb, "two-rung transcode != direct admission (seed {:#x})", g.seed);
        assert_eq!(gc, gb, "one-shot transcode != direct admission (seed {:#x})", g.seed);
        assert_eq!(a.layout().fingerprint(), fin.fingerprint());
    });
}

#[test]
fn prefix_cache_never_serves_stale_precision_blocks_after_ladder() {
    // Phase 1: a 32-token prompt P caches two full kv16 blocks. Phase 2:
    // an engineered overload ladders the pool down — which must drop P's
    // kv16-keyed entries wholesale. Phase 3: resubmitting P gets ZERO hit
    // tokens (the stale blocks are gone, not served) and decodes
    // bit-identically to a fresh engine admitted at the final layout.
    // Phase 4: a second resubmit hits the freshly registered new-layout
    // blocks — legal reuse still works, with identical tokens.
    let mut e = Engine::new(cfg(
        "kv16",
        SchedulerPolicy::Continuous,
        PreemptionMode::Ladder,
        LadderPolicy::Auto,
        true,
        16,
        8,
    ))
    .unwrap();
    let p: Vec<i32> = (0..32).map(|i| ((i * 3 + 5) % 2048) as i32).collect();
    e.submit(Request::new(p.clone(), 4)).unwrap();
    let out1 = e.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(out1.finish, FinishReason::Length);
    assert_eq!(out1.final_kv_layout, "kv16", "no pressure yet — admission layout holds");
    assert_eq!(e.prefix_cached_blocks(), 2, "P's two full prompt blocks are cached");
    assert_eq!(e.prefix_cache_summary().unwrap().invalidated_blocks, 0);

    // Disjoint prompts, lockstep growth: forces the ladder while P's
    // blocks are still resident in the index.
    for i in 0..3 {
        let prompt: Vec<i32> =
            (0..17).map(|j| ((1000 + i * 211 + j * 7) % 2048) as i32).collect();
        e.submit(Request::new(prompt, 32)).unwrap();
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 3);
    assert!(outs.iter().all(|o| o.finish == FinishReason::Length), "overload must be lossless");
    assert!(e.preempt_stats.ladder_events >= 1, "the rung must fire");
    let s = e.prefix_cache_summary().unwrap();
    assert!(
        s.invalidated_blocks >= 2,
        "ladder must invalidate the stale kv16-keyed prefix blocks (got {})",
        s.invalidated_blocks
    );

    // Phase 3: the stale entries must not serve.
    e.submit(Request::new(p.clone(), 4)).unwrap();
    let out2 = e.run_to_completion().unwrap().pop().unwrap();
    assert_eq!(out2.finish, FinishReason::Length);
    assert_eq!(
        out2.prefix_hit_tokens, 0,
        "invalidated kv16 blocks must never serve a hit at the laddered layout"
    );
    assert_ne!(out2.final_kv_layout, "kv16");
    let (be, base) = run_burst(
        cfg(
            &out2.final_kv_layout,
            SchedulerPolicy::Continuous,
            PreemptionMode::Abort,
            LadderPolicy::Off,
            false,
            16,
            512,
        ),
        &[(p.clone(), 4)],
    );
    assert_eq!(be.preempt_stats.ladder_events, 0);
    assert_eq!(
        out2.tokens, base[0].tokens,
        "post-ladder decode of P must match a fresh run admitted at the final layout"
    );

    // Phase 4: P's blocks re-registered at the new layout hit legally.
    e.submit(Request::new(p.clone(), 4)).unwrap();
    let out3 = e.run_to_completion().unwrap().pop().unwrap();
    assert!(out3.prefix_hit_tokens > 0, "fresh same-layout blocks must still be reusable");
    assert_eq!(out3.tokens, out2.tokens, "cache hits never change tokens");
    assert_drained(&e, "prefix negative test");
}

//! Flight-recorder integration tests (DESIGN.md §12) on the hermetic sim
//! backend: a randomized overload harness proving the trace is not merely
//! plausible but **exactly** reconciles with the engine's own counters —
//! every byte the telemetry attributes to a precision rung appears in some
//! typed event, and vice versa — plus the determinism contract
//! (bit-identical traces for same-seed runs), exact ring-wraparound drop
//! accounting, per-request span nesting, and Chrome-export validity.
//!
//! The load-bearing claims:
//!   (a) summed trace fields `==` engine counters (no sampling, no drift):
//!       prompt/generated tokens, decode iterations, padded slots, per-rung
//!       gather HBM bytes, per-rung transcode bytes, per-rung swap PCIe
//!       bytes, prefix-cache hit tokens, swap-out/-in event counts;
//!   (b) every request's events nest inside its admit → finish span, with
//!       exactly one admit and one finish each;
//!   (c) two runs of the same seed produce bit-identical dumps and exports;
//!   (d) a tiny ring drops exactly `recorded − capacity` oldest events and
//!       keeps the newest `capacity` verbatim.

use std::collections::BTreeMap;

use turbomind::config::engine::{LadderPolicy, PreemptionMode, SchedulerPolicy};
use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request, RequestOutput};
use turbomind::kvcache::SwapBackend;
use turbomind::trace::{chrome_trace, validate, EventKind, TraceTrack};
use turbomind::util::proptest::run_prop;

fn cfg(
    precision: &str,
    mode: PreemptionMode,
    cache: bool,
    block_tokens: usize,
    pool_blocks: usize,
) -> EngineConfig {
    EngineConfig {
        precision: precision.parse().unwrap(),
        max_batch: 4,
        kv_block_tokens: block_tokens,
        kv_pool_tokens: block_tokens * pool_blocks,
        prefill_chunk: 32,
        scheduler: SchedulerPolicy::Continuous,
        enable_prefix_cache: cache,
        preemption_mode: mode,
        trace: true,
        // Roomy ring: reconciliation needs every event resident.
        trace_ring_capacity: 1 << 14,
        ..EngineConfig::default()
    }
}

/// Ladder-capable variant: uniform kv16 admission layout so the pool has
/// two rungs of headroom to transcode through.
fn ladder_cfg(cache: bool, block_tokens: usize, pool_blocks: usize) -> EngineConfig {
    EngineConfig {
        kv_layout: Some("kv16".into()),
        ladder_policy: LadderPolicy::Auto,
        ..cfg("W4A16KV16", PreemptionMode::Ladder, cache, block_tokens, pool_blocks)
    }
}

fn run_burst(cfg: EngineConfig, reqs: &[(Vec<i32>, usize)]) -> (Engine, Vec<RequestOutput>) {
    let mut e = Engine::new(cfg).unwrap();
    for (prompt, gen) in reqs {
        e.submit(Request::new(prompt.clone(), *gen)).unwrap();
    }
    let mut outs = e.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    (e, outs)
}

/// Exhaustive trace ↔ counter reconciliation. Every equality is exact
/// (`==`, not `≤`): the events and the counters are written by the same
/// code paths, so any drift is a bug in one of them.
fn reconcile(e: &Engine, outs: &[RequestOutput], ctx: &str) {
    let dump = e.trace_dump();
    assert_eq!(dump.torn, 0, "{ctx}: quiescent dump can never tear");
    assert_eq!(dump.dropped, 0, "{ctx}: ring sized to hold the whole run");
    assert_eq!(dump.recorded as usize, dump.events.len(), "{ctx}");

    let mut prompt_tokens = 0u64;
    let mut generated = 0u64;
    let mut decode_iters = 0usize;
    let mut padded = 0u64;
    let mut gather = [0u64; 3];
    let mut transcode = [0u64; 3];
    let mut swap_bytes = [0u64; 3];
    let mut prefix_hit_tokens = 0u64;
    let mut ladder_events = 0usize;
    let mut ladder_decisions = 0usize;
    let mut evict_decisions = 0usize;
    let mut swap_outs = 0usize;
    let mut swap_ins = 0usize;
    let mut admit_ts: BTreeMap<u64, f64> = BTreeMap::new();
    let mut finish_ts: BTreeMap<u64, f64> = BTreeMap::new();
    let mut finish_info: BTreeMap<u64, (u8, u64)> = BTreeMap::new();

    for ev in &dump.events {
        match &ev.kind {
            EventKind::Admit { id, .. } => {
                let prev = admit_ts.insert(*id, ev.sim_time_s);
                assert!(prev.is_none(), "{ctx}: req {id} admitted twice");
            }
            EventKind::PrefixLookup { hit, tokens, .. } => {
                assert_eq!(*hit, *tokens > 0, "{ctx}: hit flag must match tokens");
                prefix_hit_tokens += tokens;
            }
            EventKind::PrefillChunk { tokens, gather_by_rung, generated: g, .. } => {
                prompt_tokens += tokens;
                generated += g;
                for (acc, b) in gather.iter_mut().zip(gather_by_rung) {
                    *acc += b;
                }
            }
            EventKind::DecodeIter { padded_slots, generated: g, gather_by_rung, .. } => {
                decode_iters += 1;
                padded += padded_slots;
                generated += g;
                for (acc, b) in gather.iter_mut().zip(gather_by_rung) {
                    *acc += b;
                }
            }
            EventKind::Preempt { mechanism, .. } => {
                if *mechanism == 2 {
                    ladder_decisions += 1;
                } else {
                    evict_decisions += 1;
                }
            }
            EventKind::Ladder { bytes_by_rung, .. } => {
                ladder_events += 1;
                for (acc, b) in transcode.iter_mut().zip(bytes_by_rung) {
                    *acc += b;
                }
            }
            EventKind::SwapOut { bytes_by_rung, .. } => {
                swap_outs += 1;
                for (acc, b) in swap_bytes.iter_mut().zip(bytes_by_rung) {
                    *acc += b;
                }
            }
            EventKind::SwapIn { bytes_by_rung, .. } => {
                swap_ins += 1;
                for (acc, b) in swap_bytes.iter_mut().zip(bytes_by_rung) {
                    *acc += b;
                }
            }
            EventKind::Finish { id, reason, tokens, latency_s } => {
                assert!(*latency_s >= 0.0, "{ctx}");
                let prev = finish_ts.insert(*id, ev.sim_time_s);
                assert!(prev.is_none(), "{ctx}: req {id} finished twice");
                finish_info.insert(*id, (*reason, *tokens));
            }
        }
    }

    // (a) exact counter reconciliation.
    let s = &e.stats;
    assert_eq!(prompt_tokens, s.prompt_tokens as u64, "{ctx}: prefill tokens");
    assert_eq!(generated, s.tokens_generated as u64, "{ctx}: generated tokens");
    assert_eq!(decode_iters, s.decode_iters, "{ctx}: decode iterations");
    assert_eq!(padded, s.padded_slots as u64, "{ctx}: padded decode slots");
    assert_eq!(gather, s.gather_hbm_bytes_by_rung.map(|b| b as u64), "{ctx}: gather by rung");
    assert_eq!(
        gather.iter().sum::<u64>(),
        s.gather_hbm_bytes as u64,
        "{ctx}: rung buckets must sum to the headline gather counter"
    );
    assert_eq!(transcode, s.transcode_bytes_by_rung.map(|b| b as u64), "{ctx}: transcode");
    assert_eq!(swap_bytes, s.swap_pcie_bytes_by_rung.map(|b| b as u64), "{ctx}: swap PCIe");
    assert_eq!(prefix_hit_tokens, s.prefill_tokens_skipped as u64, "{ctx}: prefix hits");
    let p = e.preemption_summary();
    assert_eq!(ladder_events, p.ladder_events, "{ctx}: ladder rungs");
    assert_eq!(ladder_decisions, p.ladder_events, "{ctx}: one decision per rung");
    assert_eq!(
        transcode.iter().sum::<u64>(),
        p.ladder_transcoded_bytes as u64,
        "{ctx}: transcode buckets sum to the preemption counter"
    );
    assert_eq!(
        evict_decisions,
        p.preemptions - p.ladder_preemptions,
        "{ctx}: one swap/recompute decision per evicted victim"
    );
    assert_eq!(swap_outs, e.swap_store().stats().swap_outs, "{ctx}: swap-out events");
    assert_eq!(swap_ins, e.swap_store().stats().swap_ins, "{ctx}: swap-in events");

    // Telemetry is the same arrays re-exported (plus live pool occupancy).
    let t = e.telemetry();
    assert_eq!(t.gather_hbm_bytes_by_rung, s.gather_hbm_bytes_by_rung, "{ctx}");
    assert_eq!(t.transcode_bytes_by_rung, s.transcode_bytes_by_rung, "{ctx}");
    assert_eq!(t.swap_pcie_bytes_by_rung, s.swap_pcie_bytes_by_rung, "{ctx}");
    assert_eq!(t.occupancy_layers_by_rung, e.kv_pool().layout().rung_histogram(), "{ctx}");

    // (b) span nesting: exactly one admit + one finish per request, every
    // id-carrying event inside [admit, finish] on the modeled clock.
    assert_eq!(finish_ts.len(), outs.len(), "{ctx}: one finish per output");
    for o in outs {
        let a = *admit_ts.get(&o.id).unwrap_or_else(|| panic!("{ctx}: req {} no admit", o.id));
        let f = *finish_ts.get(&o.id).unwrap_or_else(|| panic!("{ctx}: req {} no finish", o.id));
        assert!(a <= f, "{ctx}: req {} finish precedes admit", o.id);
        let (reason, tokens) = finish_info[&o.id];
        let want = match o.finish {
            FinishReason::Length => 0u8,
            FinishReason::Stop => 1,
            FinishReason::Aborted => 2,
        };
        assert_eq!(reason, want, "{ctx}: req {} finish reason", o.id);
        assert_eq!(tokens, o.tokens.len() as u64, "{ctx}: req {} token count", o.id);
    }
    for ev in &dump.events {
        if let Some(id) = ev.kind.request_id() {
            assert!(
                ev.sim_time_s >= admit_ts[&id] && ev.sim_time_s <= finish_ts[&id],
                "{ctx}: req {id} {} event at t={} escapes its [admit, finish] span",
                ev.kind.name(),
                ev.sim_time_s
            );
        }
    }
}

#[test]
fn randomized_overload_trace_reconciles_exactly_with_engine_counters() {
    // Sampled acceptance matrix: precision × prefix-cache × random bursty
    // request sets against a ~3× oversubscribed pool, across all three
    // lossless preemption mechanisms (swap, recompute, pool-wide ladder).
    // Aggregated counters prove the harness genuinely drove every event
    // class the reconciliation claims to cover.
    let mut swaps = 0usize;
    let mut recomputes = 0usize;
    let mut ladders = 0usize;
    run_prop("trace-reconcile", 0x7ACE_5EED, 8, |g| {
        let precision = *g.choose(&["W4A16KV16", "W4A16KV8", "W4A16KV4"]);
        let cache = g.bool();
        let n = g.usize_in(4, 6);
        let mut reqs: Vec<(Vec<i32>, usize)> = Vec::new();
        for _ in 0..n {
            let p_len = g.usize_in(8, 15);
            let gen = g.usize_in(16, 40);
            let prompt: Vec<i32> = (0..p_len).map(|_| g.usize_in(0, 2047) as i32).collect();
            reqs.push((prompt, gen));
        }
        let bt = 8usize;
        let need = |r: &(Vec<i32>, usize)| (r.0.len() + r.1).div_ceil(bt);
        let max_need = reqs.iter().map(need).max().unwrap();
        let pool_blocks =
            max_need.max(reqs.iter().map(need).sum::<usize>() / 3).max(2);

        for mode in [PreemptionMode::Swap, PreemptionMode::Recompute] {
            let ctx = format!("{precision} {mode:?} cache={cache} (case {:#x})", g.seed);
            let (e, outs) = run_burst(cfg(precision, mode, cache, bt, pool_blocks), &reqs);
            assert_eq!(outs.len(), n, "{ctx}: outputs lost");
            reconcile(&e, &outs, &ctx);
            swaps += e.preempt_stats.swap_preemptions;
            recomputes += e.preempt_stats.recompute_preemptions;
        }

        // Ladder mode admits at kv16 so rungs exist to descend; same
        // oversubscribed pool arithmetic as the eviction cases.
        let ctx = format!("ladder cache={cache} (case {:#x})", g.seed);
        let (e, outs) = run_burst(ladder_cfg(cache, bt, pool_blocks), &reqs);
        assert_eq!(outs.len(), n, "{ctx}: outputs lost");
        reconcile(&e, &outs, &ctx);
        ladders += e.preemption_summary().ladder_events;
    });
    assert!(swaps > 0, "harness never exercised swap events");
    assert!(recomputes > 0, "harness never exercised recompute");
    assert!(ladders > 0, "harness never exercised the ladder");
}

/// Three 17-prompt/32-gen requests against an 8×16-token pool overflow by
/// arithmetic, not timing (the engineered shape from the preemption tests).
fn engineered_overflow() -> Vec<(Vec<i32>, usize)> {
    (0..3)
        .map(|i| {
            let prompt: Vec<i32> = (0..17).map(|j| ((i * 211 + j * 7) % 2048) as i32).collect();
            (prompt, 32usize)
        })
        .collect()
}

#[test]
fn same_seed_runs_produce_bit_identical_traces() {
    // The determinism contract: the trace is a pure function of
    // (requests, config) — modeled clock stamps, byte attributions, and
    // decision records all derive from the sim, never from wall time.
    // Ladder mode exercises the richest event mix (preempt decisions,
    // transcodes, restarts) on top of prefill/decode/finish.
    let reqs = engineered_overflow();
    let (e1, o1) = run_burst(ladder_cfg(false, 16, 8), &reqs);
    let (e2, o2) = run_burst(ladder_cfg(false, 16, 8), &reqs);
    for (a, b) in o1.iter().zip(&o2) {
        assert_eq!(a.tokens, b.tokens, "req {} diverged", a.id);
    }
    let (d1, d2) = (e1.trace_dump(), e2.trace_dump());
    assert!(!d1.events.is_empty(), "engineered overload must record events");
    assert_eq!(d1.recorded, d2.recorded);
    assert_eq!(d1.events, d2.events, "same seed must replay the identical event stream");

    // And the exported documents are byte-identical too.
    let t1 = [TraceTrack { tid: 0, label: "engine".into(), dump: &d1 }];
    let t2 = [TraceTrack { tid: 0, label: "engine".into(), dump: &d2 }];
    let (c1, c2) = (chrome_trace(&t1), chrome_trace(&t2));
    validate(&c1).unwrap();
    assert_eq!(c1.dump(), c2.dump(), "Chrome exports must be bit-identical");
}

#[test]
fn tiny_ring_wraparound_drop_count_is_exact() {
    // Same run, two ring sizes: the tiny ring keeps exactly the newest 8
    // events of the big ring's stream and reports every older one dropped.
    let reqs = engineered_overflow();
    let (big, _) = run_burst(cfg("W4A16KV8", PreemptionMode::Swap, false, 16, 8), &reqs);
    let tiny_cfg = EngineConfig {
        trace_ring_capacity: 8,
        ..cfg("W4A16KV8", PreemptionMode::Swap, false, 16, 8)
    };
    let (tiny, _) = run_burst(tiny_cfg, &reqs);

    let full = big.trace_dump();
    let wrapped = tiny.trace_dump();
    assert!(full.recorded > 8, "run must overflow the tiny ring");
    assert_eq!(wrapped.recorded, full.recorded, "recorded never windows");
    assert_eq!(wrapped.events.len(), 8);
    assert_eq!(wrapped.dropped, full.recorded - 8, "drops are exact, not approximate");
    assert_eq!(
        wrapped.events[..],
        full.events[full.events.len() - 8..],
        "the survivors are the newest events, verbatim"
    );
    // dump_last windows the view without changing the drop accounting.
    let last3 = tiny.trace_dump_last(3);
    assert_eq!(last3.events[..], wrapped.events[wrapped.events.len() - 3..]);
    assert_eq!(last3.dropped, wrapped.dropped);
}

#[test]
fn mixed_layout_swap_bytes_split_per_rung_and_match_headline_exactly() {
    // Regression: swap PCIe bytes are attributed from each snapshot's own
    // recorded extents, not from the pool's current (uniform) rung. On a
    // mixed kv16/kv4 pool every swap event must split its bytes across
    // both resident rungs, leave the absent kv8 rung untouched, and the
    // per-rung split must sum to exactly the bytes the event's modeled
    // duration was priced on.
    let c = EngineConfig {
        kv_layout: Some("l0:kv16,l1:kv16,l2:kv4,l3:kv4".into()),
        ..cfg("W4A16KV8", PreemptionMode::Swap, false, 16, 8)
    };
    let (e, outs) = run_burst(c, &engineered_overflow());
    assert_eq!(outs.len(), 3, "lossless swap mode must complete everything");
    let p = e.preemption_summary();
    assert!(p.swap_preemptions > 0, "the engineered shape must force swap-outs");
    assert!(e.swap_store().stats().swap_ins > 0, "and restore at least one victim");

    use turbomind::kvcache::swap::transfer_time_s;
    let mut by_rung = [0u64; 3];
    let mut events = 0usize;
    for ev in &e.trace_dump().events {
        let (bytes, dur) = match &ev.kind {
            EventKind::SwapOut { bytes_by_rung, dur_s, .. }
            | EventKind::SwapIn { bytes_by_rung, dur_s, .. } => (*bytes_by_rung, *dur_s),
            _ => continue,
        };
        events += 1;
        let total: u64 = bytes.iter().sum();
        assert_eq!(
            transfer_time_s(total as usize),
            dur,
            "event's rung split must sum to the bytes its duration was modeled on"
        );
        for (acc, b) in by_rung.iter_mut().zip(bytes) {
            *acc += b;
        }
    }
    assert!(events > 0);
    assert_eq!(by_rung, e.stats.swap_pcie_bytes_by_rung.map(|b| b as u64));
    assert_eq!(by_rung[1], 0, "no kv8 layers exist in this pool");
    assert!(
        by_rung[0] > 0 && by_rung[2] > 0,
        "traffic must split across both resident rungs, got {by_rung:?}"
    );
}

#[test]
fn tracing_off_records_nothing_and_dumps_empty() {
    let reqs = engineered_overflow();
    let off = EngineConfig { trace: false, ..cfg("W4A16KV8", PreemptionMode::Swap, false, 16, 8) };
    let (e, outs) = run_burst(off, &reqs);
    assert_eq!(outs.len(), 3, "tracing off must not change behavior");
    assert!(e.trace_recorder().is_none());
    let d = e.trace_dump();
    assert_eq!((d.recorded, d.dropped, d.torn, d.events.len()), (0, 0, 0, 0));
}

#[test]
fn prefix_cache_hits_are_traced_and_reconcile() {
    // Two back-to-back identical prompts through a roomy cached pool: the
    // second admission adopts the first's indexed blocks, and the trace's
    // prefix_lookup events carry the exact adopted-token count.
    let c = cfg("W4A16KV8", PreemptionMode::Abort, true, 16, 512);
    let mut e = Engine::new(c).unwrap();
    let prompt: Vec<i32> = (0..40).map(|j| (j * 13 % 2048) as i32).collect();
    e.submit(Request::new(prompt.clone(), 8)).unwrap();
    let mut outs = e.run_to_completion().unwrap();
    e.submit(Request::new(prompt, 8)).unwrap();
    outs.extend(e.run_to_completion().unwrap());
    assert!(e.stats.prefill_tokens_skipped > 0, "second admission must hit the index");

    let dump = e.trace_dump();
    let lookups: Vec<_> = dump
        .events
        .iter()
        .filter_map(|ev| match &ev.kind {
            EventKind::PrefixLookup { hit, tokens, .. } => Some((*hit, *tokens)),
            _ => None,
        })
        .collect();
    assert_eq!(lookups.len(), 2, "one lookup per admission");
    assert_eq!(lookups[0], (false, 0), "cold cache misses");
    assert!(lookups[1].0, "warm cache hits");
    assert_eq!(lookups[1].1, e.stats.prefill_tokens_skipped as u64);
    reconcile(&e, &outs, "prefix round-trip");
}

#[test]
fn chrome_export_is_valid_and_carries_one_track_per_replica() {
    let reqs = engineered_overflow();
    let (e1, _) = run_burst(ladder_cfg(false, 16, 8), &reqs);
    let (e2, _) = run_burst(cfg("W4A16KV8", PreemptionMode::Swap, false, 16, 8), &reqs);
    let (d1, d2) = (e1.trace_dump(), e2.trace_dump());
    let tracks = [
        TraceTrack { tid: 0, label: "replica-0 (kv16 ladder)".into(), dump: &d1 },
        TraceTrack { tid: 1, label: "replica-1 (kv8 swap)".into(), dump: &d2 },
    ];
    let doc = chrome_trace(&tracks);
    validate(&doc).unwrap();
    let text = doc.dump();
    // Both thread-name metadata records and both tids appear.
    assert!(text.contains("replica-0 (kv16 ladder)"));
    assert!(text.contains("replica-1 (kv8 swap)"));
    assert!(text.contains("\"displayTimeUnit\":\"ms\""), "{}", &text[..200.min(text.len())]);
}

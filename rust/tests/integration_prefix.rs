//! End-to-end tests of the prefix-sharing KV cache on the hermetic sim
//! backend: block reuse across requests, prefill skipping, bit-identical
//! outputs vs the cache-disabled engine across KV precisions and scheduler
//! policies, and LRU eviction under pool pressure.

use turbomind::config::engine::SchedulerPolicy;
use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request, RequestOutput};

/// 32-token prefill chunks over 16-token blocks: a 64-token shared prefix
/// spans 4 blocks and 2 chunks.
fn cfg(precision: &str, policy: SchedulerPolicy, cache: bool, pool_blocks: usize) -> EngineConfig {
    EngineConfig {
        precision: precision.parse().unwrap(),
        max_batch: 4,
        kv_block_tokens: 16,
        kv_pool_tokens: 16 * pool_blocks,
        prefill_chunk: 32,
        scheduler: policy,
        enable_prefix_cache: cache,
        ..EngineConfig::default()
    }
}

fn shared_prefix() -> Vec<i32> {
    (0..64).map(|i| (i * 7 + 11) % 2048).collect()
}

/// `shared ++ [base, base+1, …]` — two requests built with different
/// `base` share exactly the 64-token prefix.
fn prompt_with_suffix(base: i32) -> Vec<i32> {
    let mut p = shared_prefix();
    p.extend((0..8).map(|i| (base + i) % 2048));
    p
}

/// Submit → drain one request at a time; returns (output, sim-time delta).
fn run_one(e: &mut Engine, req: Request) -> (RequestOutput, f64) {
    let before = e.stats.sim_time_s;
    e.submit(req).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    (outs.into_iter().next().unwrap(), e.stats.sim_time_s - before)
}

#[test]
fn shared_prefix_reuses_blocks_and_outputs_stay_bit_identical() {
    // The acceptance matrix: kv16 / kv8 / kv4 × both scheduler policies.
    for prec in ["W4A16KV16", "W4A16KV8", "W4A16KV4"] {
        for policy in [SchedulerPolicy::Continuous, SchedulerPolicy::Static] {
            let ctx = format!("{prec} {policy:?}");
            let req1 = || Request::new(prompt_with_suffix(1000), 6);
            let req2 = || Request::new(prompt_with_suffix(1500), 6);

            // Cache-disabled baseline.
            let mut base = Engine::new(cfg(prec, policy, false, 32)).unwrap();
            let (b1, _) = run_one(&mut base, req1());
            let (b2, t2_base) = run_one(&mut base, req2());
            assert_eq!(base.kv_pool().free_blocks(), 32, "{ctx}: baseline reclaims all");

            // Cache-enabled run of the identical workload.
            let mut e = Engine::new(cfg(prec, policy, true, 32)).unwrap();
            let (c1, _) = run_one(&mut e, req1());
            assert_eq!(c1.prefix_hit_tokens, 0, "{ctx}: cold cache");
            assert_eq!(
                e.kv_pool().used_blocks(),
                4,
                "{ctx}: the 4 full prompt blocks stay resident"
            );
            let (c2, t2_cached) = run_one(&mut e, req2());

            // The shared 64 tokens (4 blocks, capped at the final chunk
            // boundary) are served from the cache…
            assert_eq!(c2.prefix_hit_tokens, 64, "{ctx}");
            assert_eq!(e.stats.prefill_tokens_skipped, 64, "{ctx}");
            // …so the second request's prefill is strictly cheaper in
            // modeled device time (1 chunk instead of 3).
            assert!(
                t2_cached < t2_base,
                "{ctx}: cached sim time {t2_cached} !< uncached {t2_base}"
            );
            // …and decoded outputs are bit-identical to the uncached run.
            assert_eq!(b1.tokens, c1.tokens, "{ctx}: request 1 diverged");
            assert_eq!(b2.tokens, c2.tokens, "{ctx}: request 2 diverged");
            assert_eq!(c1.finish, FinishReason::Length, "{ctx}");
            assert_eq!(c2.finish, FinishReason::Length, "{ctx}");

            // Only the same 4 shared blocks remain resident afterwards:
            // request 2 duplicated nothing.
            assert_eq!(e.kv_pool().used_blocks(), 4, "{ctx}");
            assert_eq!(e.prefix_cached_blocks(), 4, "{ctx}");
            let summary = e.prefix_cache_summary().unwrap();
            assert_eq!(summary.lookups, 2, "{ctx}");
            assert_eq!(summary.hits, 1, "{ctx}");
            assert_eq!(summary.blocks_saved, 4, "{ctx}");
            assert_eq!(summary.prefill_tokens_skipped, 64, "{ctx}");
        }
    }
}

#[test]
fn free_block_count_proves_sharing_mid_flight() {
    let mut e = Engine::new(cfg("W4A16KV8", SchedulerPolicy::Continuous, true, 32)).unwrap();
    let (_, _) = run_one(&mut e, Request::new(prompt_with_suffix(1000), 6));
    assert_eq!(e.kv_pool().used_blocks(), 4);

    // One prefill step of the second request: it adopts the 4 resident
    // blocks (ref count 2: index + sequence) and allocates exactly one
    // block of its own for the 8-token suffix — not the 5 a private copy
    // of the prompt would need.
    e.submit(Request::new(prompt_with_suffix(1500), 6)).unwrap();
    e.step().unwrap();
    assert_eq!(e.kv_pool().used_blocks(), 5, "4 shared + 1 own");
    let shared: usize = (0..e.kv_pool().total_blocks())
        .filter(|&b| e.kv_pool().block_ref_count(b) >= 2)
        .count();
    assert_eq!(shared, 4, "exactly the prefix blocks are multiply-owned");
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].prefix_hit_tokens, 64);
}

#[test]
fn concurrent_submissions_share_and_match_baseline() {
    // Both requests in flight together: request 1's blocks are indexed
    // chunk-by-chunk during its prefill, so request 2 hits mid-flight.
    for policy in [SchedulerPolicy::Continuous, SchedulerPolicy::Static] {
        let run = |cache: bool| {
            let mut e = Engine::new(cfg("W4A16KV4", policy, cache, 32)).unwrap();
            e.submit(Request::new(prompt_with_suffix(1000), 6)).unwrap();
            e.submit(Request::new(prompt_with_suffix(1500), 6)).unwrap();
            let mut outs = e.run_to_completion().unwrap();
            outs.sort_by_key(|o| o.id);
            let hits: Vec<usize> = outs.iter().map(|o| o.prefix_hit_tokens).collect();
            let toks: Vec<Vec<i32>> = outs.iter().map(|o| o.tokens.clone()).collect();
            (toks, hits)
        };
        let (toks_off, hits_off) = run(false);
        let (toks_on, hits_on) = run(true);
        assert_eq!(toks_off, toks_on, "{policy:?}: caching changed greedy outputs");
        assert_eq!(hits_off, vec![0, 0], "{policy:?}");
        assert_eq!(hits_on, vec![0, 64], "{policy:?}: second request hits mid-flight");
    }
}

#[test]
fn lru_eviction_frees_cached_blocks_under_pressure() {
    // 6-block pool. Request 1 leaves 4 cached blocks; request 2 (different
    // prompt, needs all 6 blocks) can only run by evicting them — and the
    // engine admits it because unreferenced cached blocks count as free.
    let mut e = Engine::new(cfg("W4A16KV8", SchedulerPolicy::Continuous, true, 6)).unwrap();
    let p1: Vec<i32> = (0..64).map(|i| (i * 3 + 5) % 2048).collect();
    let (o1, _) = run_one(&mut e, Request::new(p1, 4));
    assert_eq!(o1.finish, FinishReason::Length);
    assert_eq!(e.prefix_cached_blocks(), 4);
    assert_eq!(e.kv_pool().free_blocks(), 2);

    let p2: Vec<i32> = (0..80).map(|i| (i * 13 + 1) % 2048).collect();
    let (o2, _) = run_one(&mut e, Request::new(p2, 16));
    assert_eq!(o2.finish, FinishReason::Length, "eviction must make room");
    assert_eq!(o2.tokens.len(), 16);
    assert_eq!(o2.prefix_hit_tokens, 0, "different prefix: no reuse");
    let summary = e.prefix_cache_summary().unwrap();
    assert_eq!(summary.evicted_blocks, 4, "request 1's cached chain fully evicted");
    // Request 2's own 5 full prompt blocks are the cache now.
    assert_eq!(e.prefix_cached_blocks(), 5);
    assert_eq!(e.kv_pool().free_blocks(), 1);
}

#[test]
fn admission_counts_resident_prefix_blocks() {
    // 6-block pool, identical 64-token prompt twice. Without the prefix
    // credit the second request would reserve blocks_for(64 + 4) = 5 > 2
    // free and stall the engine; with it, the 4 resident blocks cover the
    // prompt and only the tail + generation need allocating.
    let mut e = Engine::new(cfg("W4A16KV8", SchedulerPolicy::Continuous, true, 6)).unwrap();
    let p: Vec<i32> = (0..64).map(|i| (i * 5 + 2) % 2048).collect();
    let (o1, _) = run_one(&mut e, Request::new(p.clone(), 4));
    assert_eq!(o1.finish, FinishReason::Length);
    assert_eq!(e.kv_pool().free_blocks(), 2);

    let (o2, _) = run_one(&mut e, Request::new(p, 4));
    assert_eq!(o2.finish, FinishReason::Length, "must not stall");
    // Prompt of exactly 64 → the final 32-token chunk reruns, so the hit
    // is capped at 32 tokens (2 blocks).
    assert_eq!(o2.prefix_hit_tokens, 32);
    assert_eq!(o1.tokens, o2.tokens, "same prompt, same greedy outputs");
}

#[test]
fn cache_disabled_engine_is_unchanged() {
    // With the flag off there is no index: nothing stays resident and
    // responses report zero hits.
    let mut e = Engine::new(cfg("W4A16KV8", SchedulerPolicy::Continuous, false, 32)).unwrap();
    let (o1, _) = run_one(&mut e, Request::new(prompt_with_suffix(1000), 6));
    let (o2, _) = run_one(&mut e, Request::new(prompt_with_suffix(1000), 6));
    assert_eq!(o1.prefix_hit_tokens, 0);
    assert_eq!(o2.prefix_hit_tokens, 0);
    assert_eq!(o1.tokens, o2.tokens);
    assert!(e.prefix_cache_summary().is_none());
    assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());
}

#[test]
fn prefix_cache_budget_bounds_resident_blocks() {
    let mut c = cfg("W4A16KV8", SchedulerPolicy::Continuous, true, 32);
    c.prefix_cache_blocks = 2;
    let mut e = Engine::new(c).unwrap();
    let (_, _) = run_one(&mut e, Request::new(prompt_with_suffix(1000), 6));
    assert!(e.prefix_cached_blocks() <= 2, "budget respected");
    // A matching request still reuses what fits the budget.
    let (o2, _) = run_one(&mut e, Request::new(prompt_with_suffix(1500), 6));
    assert_eq!(o2.prefix_hit_tokens, 32, "2 cached blocks of the shared prefix");
}

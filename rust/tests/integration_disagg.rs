//! Disaggregated prefill/decode integration tests (DESIGN.md §13).
//!
//! Acceptance: the randomized harness (fixed base seed 0xD15A_6600, also
//! pinned in CI) drives random tier shapes, layouts, policies, and
//! workloads through `run_disagg` and asserts zero request loss, every
//! request answered exactly once, fleet accounting drained to zero, and
//! outputs bit-identical to a single replica running at the decode
//! layout. Plus the two directed paths the harness cannot hit by
//! construction: a mismatched-layout import must be rejected before
//! admission, and a migrate-in that cannot fit must downgrade to
//! re-prefill without ever touching (or underflowing) the preemption
//! counters.

use std::collections::HashMap;

use turbomind::cluster::{migrate_all, run_disagg, DisaggConfig, ReplicaSpec, RouterPolicy};
use turbomind::config::{EngineConfig, PreemptionMode};
use turbomind::coordinator::{Engine, FinishReason, Request};
use turbomind::util::proptest::{run_prop, Gen};

fn base_cfg() -> EngineConfig {
    EngineConfig {
        precision: "W4A16KV8".parse().unwrap(),
        kv_pool_tokens: 16 * 64,
        prefill_chunk: 32,
        ..EngineConfig::default()
    }
}

/// Run every request through a standalone engine of `cfg` and return its
/// tokens keyed by the caller's index — the bit-identity oracle.
fn reference_tokens(cfg: EngineConfig, reqs: &[(usize, Request)]) -> HashMap<usize, Vec<i32>> {
    let mut engine = Engine::new(cfg).expect("reference engine");
    let mut id_to_idx = HashMap::new();
    for (idx, req) in reqs {
        let id = engine.submit(req.clone()).expect("reference submit");
        id_to_idx.insert(id, *idx);
    }
    engine
        .run_to_completion()
        .expect("reference run")
        .into_iter()
        .map(|o| (id_to_idx[&o.id], o.tokens))
        .collect()
}

/// Acceptance harness: random prefill tiers (kv16 or kv8), random decode
/// tiers (kv8 or kv4), all router policies, lossless preemption modes,
/// bursty shared-prefix workloads with 1-token terminal requests mixed
/// in. Every iteration asserts: no loss, no duplication, byte-accounted
/// migration, drained pools, and token-for-token agreement with a single
/// replica at each decode layout.
#[test]
fn randomized_disagg_harness_zero_loss_bit_identical() {
    run_prop("disagg-harness", 0xD15A_6600, 8, |g: &mut Gen| {
        let mut base = base_cfg();
        base.enable_prefix_cache = g.bool();
        base.preemption_mode =
            *g.choose(&[PreemptionMode::Swap, PreemptionMode::Recompute]);
        let policy = *g.choose(&[
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ]);
        // Prefill admits wide (kv16) or at the base kv8; decode holds the
        // base kv8 or a narrowed kv4 — every pairing transcodes downward.
        let pre_pool = ["w4a16,kv8,a100,layout=kv16", "w4a16,kv8,a100"];
        let dec_pool = ["w4a16,kv8,a100", "w4a16,kv8,h100,layout=kv4"];
        let pre_specs: Vec<ReplicaSpec> =
            (0..g.usize_in(1, 2)).map(|_| g.choose(&pre_pool).parse().unwrap()).collect();
        let dec_specs: Vec<ReplicaSpec> =
            (0..g.usize_in(1, 2)).map(|_| g.choose(&dec_pool).parse().unwrap()).collect();

        // Bursty multi-tenant mix: shared 32-token tenant prefixes plus
        // random suffixes; max_new == 1 requests finish at prefill and
        // must never cross tiers.
        let n_requests = g.usize_in(6, 14);
        let n_tenants = g.usize_in(1, 3);
        let tenant_prefix: Vec<Vec<i32>> = (0..n_tenants)
            .map(|t| (0..32).map(|j| ((t * 531 + j * 17 + 11) % 2048) as i32).collect())
            .collect();
        let reqs: Vec<Request> = (0..n_requests)
            .map(|_| {
                let mut prompt = tenant_prefix[g.usize_in(0, n_tenants - 1)].clone();
                for _ in 0..g.usize_in(1, 40) {
                    prompt.push(g.usize_in(0, 2047) as i32);
                }
                Request::new(prompt, g.usize_in(1, 8))
            })
            .collect();

        let cfg = DisaggConfig::new(base.clone(), pre_specs, dec_specs.clone(), policy);
        let run = run_disagg(&cfg, &reqs).expect("disagg run");

        // Every request answered exactly once, none lost: the outputs
        // come back sorted and cover 0..n exactly.
        let got: Vec<usize> = run.outputs.iter().map(|o| o.request).collect();
        assert_eq!(got, (0..n_requests).collect::<Vec<_>>(), "exactly one output per request");
        assert_eq!(run.completed(), n_requests, "lossless modes must complete everything");

        // Migration accounting: every decoded-on-the-other-tier request
        // either shipped KV or fell back to recompute, bytes add up, and
        // the merged telemetry sees the PCIe traffic.
        let crossed = run.outputs.iter().filter(|o| o.decode_replica.is_some()).count();
        assert_eq!(run.migrated + run.recompute_migrations, crossed);
        let by_output: usize = run.outputs.iter().map(|o| o.migrated_bytes).sum();
        assert_eq!(by_output, run.migrated_bytes, "per-output bytes must sum to the run total");
        if run.migrated > 0 {
            assert!(run.fleet_telemetry().migrate_pcie_bytes() > 0);
        }

        // Terminal requests (a single sampled token) never cross tiers.
        for o in &run.outputs {
            if o.decode_replica.is_none() {
                assert!(
                    reqs[o.request].max_new_tokens <= 1,
                    "request {} stayed on the prefill tier with max_new {}",
                    o.request,
                    reqs[o.request].max_new_tokens
                );
            }
            assert_ne!(o.output.finish, FinishReason::Aborted);
        }

        // Fleet accounting drains to zero on both tiers: pools empty but
        // for intentional prefix residency, nothing left on the host.
        for s in run.prefill_snapshots.iter().chain(&run.decode_snapshots) {
            assert_eq!((s.outstanding_reqs, s.outstanding_tokens), (0, 0), "{}", s.label);
            assert_eq!(
                s.pool_total_blocks - s.pool_free_blocks,
                s.prefix_resident_blocks,
                "{}: pool holds only intentional prefix residency",
                s.label
            );
            assert_eq!(s.swap_blocks_used, 0, "{}: host store must drain", s.label);
        }

        // Bit-identity: each migrated request matches a single replica
        // running the decode spec (its layout included) end to end;
        // terminal requests match the plain base engine.
        for (j, spec) in dec_specs.iter().enumerate() {
            let mine: Vec<(usize, Request)> = run
                .outputs
                .iter()
                .filter(|o| o.decode_replica == Some(j))
                .map(|o| (o.request, reqs[o.request].clone()))
                .collect();
            if mine.is_empty() {
                continue;
            }
            let want = reference_tokens(spec.engine_config(&base), &mine);
            for o in run.outputs.iter().filter(|o| o.decode_replica == Some(j)) {
                assert_eq!(
                    o.output.tokens, want[&o.request],
                    "request {} diverges from a single replica at the decode layout",
                    o.request
                );
            }
        }
        let terminal: Vec<(usize, Request)> = run
            .outputs
            .iter()
            .filter(|o| o.decode_replica.is_none())
            .map(|o| (o.request, reqs[o.request].clone()))
            .collect();
        if !terminal.is_empty() {
            let want = reference_tokens(base.clone(), &terminal);
            for o in run.outputs.iter().filter(|o| o.decode_replica.is_none()) {
                assert_eq!(o.output.tokens, want[&o.request], "terminal request {}", o.request);
            }
        }
    });
}

/// A snapshot shipped at the wrong layout must be rejected at submit —
/// before admission, with the routing-level message — and the same
/// artifact lands cleanly once transcoded, finishing bit-identically to
/// an undisturbed engine.
#[test]
fn mismatched_layout_import_rejected_then_accepted_after_transcode() {
    let wide: ReplicaSpec = "w4a16,kv8,a100,layout=kv16".parse().unwrap();
    let mut a = Engine::new(wide.engine_config(&base_cfg())).unwrap();
    let prompt: Vec<i32> = (0..40).map(|j| (j * 13 + 7) % 2048).collect();
    a.submit(Request::new(prompt.clone(), 8)).unwrap();
    for _ in 0..6 {
        a.step().unwrap();
    }
    let mut artifacts = a.drain_resumables().unwrap();
    assert_eq!(artifacts.len(), 1);
    let art = artifacts.remove(0);
    let snap = art.snapshot.expect("six steps sample at least one token");
    assert!(!art.generated.is_empty());

    let mut b = Engine::new(base_cfg()).unwrap(); // kv8 pool
    let err = b
        .submit_migrated(art.request.clone(), art.generated.clone(), Some(snap.clone()))
        .expect_err("kv16 snapshot must not land in a kv8 pool untranscoded");
    assert!(
        err.to_string().contains("transcode before shipping"),
        "unexpected rejection: {err}"
    );

    let transcoded = snap.transcode_to(b.kv_pool().layout()).unwrap();
    b.submit_migrated(art.request, art.generated, Some(transcoded)).unwrap();
    let out = b.run_to_completion().unwrap().remove(0);
    assert_eq!(out.finish, FinishReason::Length);
    assert_eq!(b.migration_stats.migrated_in, 1);

    let want = reference_tokens(base_cfg(), &[(0, Request::new(prompt, 8))]);
    assert_eq!(out.tokens, want[&0], "resumed tokens diverge from an undisturbed run");
}

/// Migrate-in under pressure: a target pool too small to import every
/// shipped snapshot downgrades the overflow arrivals to re-prefill.
/// The downgrade is placement, not preemption — it must not touch (or
/// underflow) the swap counters, the per-mechanism buckets must still
/// sum to `preemptions`, and every request still finishes bit-identical.
#[test]
fn migrate_in_downgrade_keeps_counters_consistent_and_outputs_exact() {
    let mut a = Engine::new(base_cfg()).unwrap();
    // Distinct prompt lengths (58/60/62) key each output back to its
    // request regardless of drain order.
    let reqs: Vec<Request> = (0..3)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..58 + 2 * i).map(|j| ((i * 101 + j * 13 + 7) % 2048) as i32).collect();
            Request::new(prompt, 16)
        })
        .collect();
    for r in &reqs {
        a.submit(r.clone()).unwrap();
    }
    // Past prefill (2 chunks × 3 requests) and into decode, so every
    // sequence ships a live snapshot.
    for _ in 0..8 {
        a.step().unwrap();
    }

    // Six 16-token blocks: each 60-token prompt + 16 generated fits
    // (5 blocks), but three ~4-block imports cannot coexist — only the
    // first lands, the rest must downgrade.
    let mut b = Engine::new(EngineConfig {
        kv_pool_tokens: 16 * 6,
        preemption_mode: PreemptionMode::Recompute,
        ..base_cfg()
    })
    .unwrap();
    let moved = migrate_all(&mut a, &mut b).unwrap();
    assert_eq!(moved, 3);
    assert!(!a.has_work(), "source must be fully drained");
    assert_eq!(a.kv_pool().used_blocks(), 0, "drained source pool must be empty");

    let outs = b.run_to_completion().unwrap();
    assert_eq!(outs.len(), 3);
    for o in &outs {
        assert_eq!(o.finish, FinishReason::Length);
        assert_eq!(o.tokens.len(), 16);
    }
    let want =
        reference_tokens(base_cfg(), &reqs.iter().cloned().enumerate().collect::<Vec<_>>());
    for o in &outs {
        let i = reqs
            .iter()
            .position(|r| r.prompt.len() == o.prompt_len)
            .expect("prompt lengths are distinct by construction");
        assert_eq!(o.tokens, want[&i], "request {i} diverges after downgrade");
    }

    // Every artifact hit the import gate exactly once; the pool only had
    // room for one resident import at a time.
    let m = b.migration_stats;
    assert_eq!(m.migrated_in + m.migrate_in_downgrades, 3);
    assert!(m.migrated_in >= 1, "at least the first import fits");
    assert!(m.migrate_in_downgrades >= 1, "the overflow arrivals must downgrade");

    // Downgrades are not preemptions: swap buckets stay untouched under
    // Recompute (an underflow would wrap and break the sum), and the
    // per-mechanism buckets still account for every preemption.
    let p = b.preemption_summary();
    assert_eq!(p.swap_preemptions, 0, "migrate-in downgrade must not touch swap counters");
    assert_eq!(
        p.preemptions,
        p.swap_preemptions + p.recompute_preemptions + p.ladder_preemptions,
        "per-mechanism buckets must sum to total preemptions"
    );
}

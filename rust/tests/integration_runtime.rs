//! Execution-layer integration tests.
//!
//! The default build exercises the [`ExecutionBackend`] surface through the
//! hermetic `SimBackend` — the same prefill/decode contract the engine
//! drives — plus the bench-table generators. The PJRT artifact tests (HLO
//! parse → compile → execute → numerics vs Rust references) live in the
//! `pjrt_artifacts` module behind the `pjrt` feature and still skip
//! gracefully when `make artifacts` has not run.

use turbomind::config::PrecisionFormat;
use turbomind::kvcache::KvPrecision;
use turbomind::runtime::{DecodeArgs, ExecutionBackend, ModelSpec, PrefillArgs, SimBackend};

fn backend(prec: &str) -> SimBackend {
    let precision: PrecisionFormat = prec.parse().unwrap();
    SimBackend::new(ModelSpec::tiny(), precision, 0, 8).unwrap()
}

/// KV row bytes for a backend's configured KV precision (the pool's own
/// storage math — single source of truth).
fn row_bytes(be: &SimBackend) -> usize {
    KvPrecision::from_dtype(be.precision().kv).unwrap().row_bytes(be.model().head_dim)
}

/// Empty gathered-cache buffers for a batch of `b` at `t_pad`.
fn empty_cache(be: &SimBackend, b: usize, t_pad: usize) -> (Vec<u8>, Vec<f32>) {
    let m = be.model();
    let n = m.n_layers * b * m.n_kv_heads * t_pad;
    (vec![0u8; n * row_bytes(be)], vec![1f32; n])
}

#[test]
fn backend_reports_model_plan_and_precision() {
    let be = backend("W4A16KV8");
    assert_eq!(be.name(), "sim");
    assert_eq!(be.model().vocab_size, 2048);
    assert_eq!(be.model().max_seq_len, 512);
    assert_eq!(be.precision().to_string(), "W4A16KV8");
    let p = be.plan();
    assert!(p.decode_batches.windows(2).all(|w| w[0] < w[1]), "ascending buckets");
    assert!(p.decode_batches.contains(&8));
    assert_eq!(*p.decode_t.last().unwrap(), 512);
    assert!(p.prefill_chunks.contains(&128));
    be.warmup().unwrap();
}

#[test]
fn prefill_then_decode_through_the_contract() {
    // Drive the backend exactly as the engine does: prefill a prompt with
    // an empty past, then decode with the emitted codes as the gathered
    // cache — shapes and layouts must line up end to end.
    let be = backend("W4A16KV8");
    let m = be.model().clone();
    let rb = row_bytes(&be);
    let t_pad = 64;
    let prompt = [7i32, 30, 400, 1999];
    let bucket = 32;

    let (kc0, ks0) = empty_cache(&be, 1, t_pad);
    let mut toks = prompt.to_vec();
    toks.resize(bucket, 0);
    let pre = be
        .prefill(&PrefillArgs {
            tokens: &toks,
            real: prompt.len(),
            pos: 0,
            t_pad,
            k_codes: &kc0,
            k_scales: &ks0,
            v_codes: &kc0,
            v_scales: &ks0,
        })
        .unwrap();
    assert_eq!(pre.logits.len(), bucket * m.vocab_size);
    assert_eq!(pre.k_codes.len(), m.n_layers * m.n_kv_heads * bucket * rb);
    assert!(pre.sim_time_s > 0.0);

    // Re-pack the prefill chunk [L,Hkv,S,rb] into the gathered decode
    // layout [L,1,Hkv,T,rb] (what the pool does via append + gather).
    let n = m.n_layers * m.n_kv_heads * t_pad;
    let mut kc = vec![0u8; n * rb];
    let mut ks = vec![1f32; n];
    let mut vc = kc.clone();
    let mut vs = ks.clone();
    for l in 0..m.n_layers {
        for h in 0..m.n_kv_heads {
            for t in 0..prompt.len() {
                let src = ((l * m.n_kv_heads + h) * bucket + t) * rb;
                let dst = ((l * m.n_kv_heads + h) * t_pad + t) * rb;
                kc[dst..dst + rb].copy_from_slice(&pre.k_codes[src..src + rb]);
                vc[dst..dst + rb].copy_from_slice(&pre.v_codes[src..src + rb]);
                let ssrc = (l * m.n_kv_heads + h) * bucket + t;
                let sdst = (l * m.n_kv_heads + h) * t_pad + t;
                ks[sdst] = pre.k_scales[ssrc];
                vs[sdst] = pre.v_scales[ssrc];
            }
        }
    }

    let dec = be
        .decode(&DecodeArgs {
            tokens: &[55],
            kv_len: &[prompt.len() as i32],
            t_pad,
            k_codes: &kc,
            k_scales: &ks,
            v_codes: &vc,
            v_scales: &vs,
        })
        .unwrap();
    assert_eq!(dec.logits.len(), m.vocab_size);
    assert_eq!(dec.k_codes.len(), m.n_layers * m.n_kv_heads * rb);
    assert_eq!(dec.k_scales.len(), m.n_layers * m.n_kv_heads);
    assert!(dec.sim_time_s > 0.0);
    assert!(dec.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn backend_validates_inputs() {
    let be = backend("W4A16KV8");
    let (kc, ks) = empty_cache(&be, 1, 64);
    // Wrong cache extent for the declared t_pad.
    let err = be
        .prefill(&PrefillArgs {
            tokens: &[1; 32],
            real: 2,
            pos: 0,
            t_pad: 128,
            k_codes: &kc,
            k_scales: &ks,
            v_codes: &kc,
            v_scales: &ks,
        })
        .unwrap_err();
    assert!(err.to_string().contains("cache size"), "{err}");
    // kv_len / batch mismatch.
    let err = be
        .decode(&DecodeArgs {
            tokens: &[1, 2],
            kv_len: &[1],
            t_pad: 64,
            k_codes: &kc,
            k_scales: &ks,
            v_codes: &kc,
            v_scales: &ks,
        })
        .unwrap_err();
    assert!(err.to_string().contains("kv_len"), "{err}");
}

#[test]
fn precision_formats_change_kv_code_width() {
    let tok = [3i32; 32];
    let mut widths = vec![];
    for prec in ["W4A16KV4", "W4A16KV8", "W4A16KV16"] {
        let be = backend(prec);
        let t_pad = 64;
        let (kc, ks) = empty_cache(&be, 1, t_pad);
        let out = be
            .prefill(&PrefillArgs {
                tokens: &tok,
                real: 1,
                pos: 0,
                t_pad,
                k_codes: &kc,
                k_scales: &ks,
                v_codes: &kc,
                v_scales: &ks,
            })
            .unwrap();
        widths.push(out.k_codes.len());
    }
    assert_eq!(widths[0] * 2, widths[1], "kv4 packs two codes per byte");
    assert_eq!(widths[1] * 4, widths[2], "kv16 stores f32 rows");
}

#[test]
fn bench_tables_generate_and_assert() {
    // The kernel-model exhibits are cheap enough for the default test run;
    // each generator's own unit tests assert the paper-direction bands, so
    // here we assert the registry dispatch + table integrity end to end.
    for name in ["fig13", "table2", "fig26"] {
        let t = turbomind::bench::run(name).expect(name);
        assert!(!t.rows.is_empty(), "{name} produced no rows");
        for row in &t.rows {
            assert_eq!(row.len(), t.headers.len(), "{name} ragged row");
        }
        assert!(!t.render().is_empty());
    }
    assert!(turbomind::bench::run("fig99").is_none());
}

/// The original PJRT artifact tests: HLO text parses, compiles, executes,
/// and the numerics match Rust-side references for the Layer-1 kernels.
/// Require `--features pjrt` AND `make artifacts`; skip with a message
/// otherwise.
#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use turbomind::quant::{self, GroupwiseQuant, QuantizedMatrix};
    use turbomind::runtime::{Dt, HostTensor, Runtime};
    use turbomind::util::rng::Rng;

    fn artifacts_dir() -> Option<String> {
        let dir = std::env::var("TM_ARTIFACTS")
            .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
        std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
    }

    macro_rules! runtime_or_skip {
        () => {
            match artifacts_dir() {
                Some(dir) => Runtime::load(&dir).expect("runtime load"),
                None => {
                    eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                    return;
                }
            }
        };
    }

    #[test]
    fn manifest_loads_and_lists_graphs() {
        let rt = runtime_or_skip!();
        assert!(rt.manifest.graphs.len() >= 20, "got {}", rt.manifest.graphs.len());
        assert!(rt.manifest.graphs.contains_key("decode_w4_kv8_b1_t128"));
        assert!(rt.manifest.graphs.contains_key("prefill_w4_kv8_s32"));
        assert!(rt.manifest.graphs.contains_key("kernel_gemm_w4"));
        assert_eq!(rt.manifest.model.vocab_size, 2048);
    }

    #[test]
    fn gemm_w8_kernel_matches_rust_reference() {
        let rt = runtime_or_skip!();
        let (m, k, n, g) = (8usize, 256usize, 256usize, 64usize);
        let mut rng = Rng::new(42);
        let x: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
        let w: Vec<f32> = (0..k * n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
        let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int8(g));

        let codes_i8: Vec<i8> = (0..k)
            .flat_map(|r| (0..n).map(move |c| (r, c)))
            .map(|(r, c)| q.code_at(r, c))
            .collect();

        let out = rt
            .execute(
                "kernel_gemm_w8",
                &[
                    HostTensor::from_f32(vec![m, k], &x).unwrap(),
                    HostTensor::from_i8(vec![k, n], &codes_i8).unwrap(),
                    HostTensor::from_f32(vec![k / g, n], &q.scales).unwrap(),
                ],
            )
            .expect("execute");
        assert_eq!(out.len(), 1);
        let got = out[0].as_f32().unwrap();

        // Rust reference: dequantize + naive matmul.
        let wd = q.dequantize();
        for row in 0..m {
            for col in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += x[row * k + kk] * wd[kk * n + col];
                }
                let gotv = got[row * n + col];
                assert!(
                    (gotv - acc).abs() <= 1e-3 + 1e-4 * acc.abs(),
                    "({row},{col}): {gotv} vs {acc}"
                );
            }
        }
    }

    #[test]
    fn gemm_w4_kernel_matches_rust_reference() {
        let rt = runtime_or_skip!();
        let (m, k, n, g) = (8usize, 256usize, 256usize, 64usize);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
        let w: Vec<f32> = (0..k * n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
        let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(g));

        // Pack along K as the kernel expects (python quantize.pack_int4_along_k).
        let mut packed = vec![0u8; (k / 2) * n];
        for kk in 0..k / 2 {
            for c in 0..n {
                let lo = (q.code_at(2 * kk, c) as u8) & 0x0F;
                let hi = (q.code_at(2 * kk + 1, c) as u8) & 0x0F;
                packed[kk * n + c] = lo | (hi << 4);
            }
        }

        let out = rt
            .execute(
                "kernel_gemm_w4",
                &[
                    HostTensor::from_f32(vec![m, k], &x).unwrap(),
                    HostTensor::from_u8(vec![k / 2, n], &packed).unwrap(),
                    HostTensor::from_f32(vec![k / g, n], &q.scales).unwrap(),
                ],
            )
            .expect("execute");
        let got = out[0].as_f32().unwrap();

        let wd = q.dequantize();
        for row in [0usize, 3, 7] {
            for col in 0..n {
                let mut acc = 0f32;
                for kk in 0..k {
                    acc += x[row * k + kk] * wd[kk * n + col];
                }
                let gotv = got[row * n + col];
                assert!(
                    (gotv - acc).abs() <= 1e-3 + 1e-4 * acc.abs(),
                    "({row},{col}): {gotv} vs {acc}"
                );
            }
        }
    }

    #[test]
    fn attention_kv8_kernel_matches_rust_reference() {
        let rt = runtime_or_skip!();
        // Shapes fixed by the microkernel artifact: B=2, H=8, Hkv=4, T=128, D=32.
        let (b, h, hkv, t, d) = (2usize, 8usize, 4usize, 128usize, 32usize);
        let group = h / hkv;
        let mut rng = Rng::new(3);
        let q: Vec<f32> = (0..b * h * d).map(|_| rng.next_f32() - 0.5).collect();
        let kf: Vec<f32> = (0..b * hkv * t * d).map(|_| rng.next_f32() - 0.5).collect();
        let vf: Vec<f32> = (0..b * hkv * t * d).map(|_| rng.next_f32() - 0.5).collect();
        let kv_len = [37i32, 128i32];

        // Quantize per (b, hkv, t) row with the Rust KV quantizer.
        let mut kq = vec![0i8; b * hkv * t * d];
        let mut ks = vec![0f32; b * hkv * t];
        let mut vq = vec![0i8; b * hkv * t * d];
        let mut vs = vec![0f32; b * hkv * t];
        for row in 0..b * hkv * t {
            let (c, s) = quant::quantize_kv_int8(&kf[row * d..(row + 1) * d]);
            kq[row * d..(row + 1) * d].copy_from_slice(&c);
            ks[row] = s;
            let (c, s) = quant::quantize_kv_int8(&vf[row * d..(row + 1) * d]);
            vq[row * d..(row + 1) * d].copy_from_slice(&c);
            vs[row] = s;
        }

        let out = rt
            .execute(
                "kernel_attn_kv8",
                &[
                    HostTensor::from_f32(vec![b, h, d], &q).unwrap(),
                    HostTensor::from_i8(vec![b, hkv, t, d], &kq).unwrap(),
                    HostTensor::from_f32(vec![b, hkv, t], &ks).unwrap(),
                    HostTensor::from_i8(vec![b, hkv, t, d], &vq).unwrap(),
                    HostTensor::from_f32(vec![b, hkv, t], &vs).unwrap(),
                    HostTensor::from_i32(vec![b], &kv_len).unwrap(),
                ],
            )
            .expect("execute");
        let got = out[0].as_f32().unwrap();

        // Rust reference attention over the dequantized KV.
        let scale = 1.0 / (d as f32).sqrt();
        for bi in 0..b {
            for hi in 0..h {
                let kvh = hi / group;
                let len = kv_len[bi] as usize;
                let qv = &q[(bi * h + hi) * d..(bi * h + hi + 1) * d];
                let mut scores = vec![0f32; len];
                for ti in 0..len {
                    let row = (bi * hkv + kvh) * t + ti;
                    let s = ks[row];
                    let mut dot = 0f32;
                    for di in 0..d {
                        dot += qv[di] * (kq[row * d + di] as f32 * s);
                    }
                    scores[ti] = dot * scale;
                }
                let m = scores.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let mut denom = 0f32;
                for s in scores.iter_mut() {
                    *s = (*s - m).exp();
                    denom += *s;
                }
                for di in 0..d {
                    let mut acc = 0f32;
                    for ti in 0..len {
                        let row = (bi * hkv + kvh) * t + ti;
                        acc += scores[ti] * (vq[row * d + di] as f32 * vs[row]);
                    }
                    acc /= denom;
                    let gotv = got[(bi * h + hi) * d + di];
                    assert!(
                        (gotv - acc).abs() < 2e-4,
                        "b{bi} h{hi} d{di}: {gotv} vs {acc}"
                    );
                }
            }
        }
    }

    #[test]
    fn execute_validates_input_shapes() {
        let rt = runtime_or_skip!();
        let bad = HostTensor::zeros(Dt::F32, vec![1, 1]);
        let err = rt.execute("kernel_gemm_w8", &[bad]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("dynamic inputs"), "{msg}");
    }

    #[test]
    fn unknown_graph_is_helpful() {
        let rt = runtime_or_skip!();
        let err = rt.execute("no_such_graph", &[]).unwrap_err();
        assert!(err.to_string().contains("not in manifest"));
    }
}

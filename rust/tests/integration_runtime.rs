//! Integration tests: the PJRT runtime against real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skipped with a clear message
//! otherwise). These tests prove the Python-AOT → Rust-PJRT bridge end to
//! end: HLO text parses, compiles, executes, and the numerics match
//! Rust-side references for the Layer-1 kernels.

use turbomind::quant::{self, GroupwiseQuant, QuantizedMatrix};
use turbomind::runtime::{Dt, HostTensor, Runtime};
use turbomind::util::rng::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TM_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    });
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

macro_rules! runtime_or_skip {
    () => {
        match artifacts_dir() {
            Some(dir) => Runtime::load(&dir).expect("runtime load"),
            None => {
                eprintln!("SKIP: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_and_lists_graphs() {
    let rt = runtime_or_skip!();
    assert!(rt.manifest.graphs.len() >= 20, "got {}", rt.manifest.graphs.len());
    assert!(rt.manifest.graphs.contains_key("decode_w4_kv8_b1_t128"));
    assert!(rt.manifest.graphs.contains_key("prefill_w4_kv8_s32"));
    assert!(rt.manifest.graphs.contains_key("kernel_gemm_w4"));
    assert_eq!(rt.manifest.model.vocab_size, 2048);
}

#[test]
fn gemm_w8_kernel_matches_rust_reference() {
    let rt = runtime_or_skip!();
    let (m, k, n, g) = (8usize, 256usize, 256usize, 64usize);
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
    let w: Vec<f32> = (0..k * n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
    let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int8(g));

    let codes_i8: Vec<i8> = (0..k)
        .flat_map(|r| (0..n).map(move |c| (r, c)))
        .map(|(r, c)| q.code_at(r, c))
        .collect();

    let out = rt
        .execute(
            "kernel_gemm_w8",
            &[
                HostTensor::from_f32(vec![m, k], &x).unwrap(),
                HostTensor::from_i8(vec![k, n], &codes_i8).unwrap(),
                HostTensor::from_f32(vec![k / g, n], &q.scales).unwrap(),
            ],
        )
        .expect("execute");
    assert_eq!(out.len(), 1);
    let got = out[0].as_f32().unwrap();

    // Rust reference: dequantize + naive matmul.
    let wd = q.dequantize();
    for row in 0..m {
        for col in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += x[row * k + kk] * wd[kk * n + col];
            }
            let gotv = got[row * n + col];
            assert!(
                (gotv - acc).abs() <= 1e-3 + 1e-4 * acc.abs(),
                "({row},{col}): {gotv} vs {acc}"
            );
        }
    }
}

#[test]
fn gemm_w4_kernel_matches_rust_reference() {
    let rt = runtime_or_skip!();
    let (m, k, n, g) = (8usize, 256usize, 256usize, 64usize);
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
    let w: Vec<f32> = (0..k * n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();
    let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(g));

    // Pack along K as the kernel expects: byte [kk, c] = row 2kk (lo) | row
    // 2kk+1 (hi) — the same convention as python quantize.pack_int4_along_k.
    let mut packed = vec![0u8; (k / 2) * n];
    for kk in 0..k / 2 {
        for c in 0..n {
            let lo = (q.code_at(2 * kk, c) as u8) & 0x0F;
            let hi = (q.code_at(2 * kk + 1, c) as u8) & 0x0F;
            packed[kk * n + c] = lo | (hi << 4);
        }
    }

    let out = rt
        .execute(
            "kernel_gemm_w4",
            &[
                HostTensor::from_f32(vec![m, k], &x).unwrap(),
                HostTensor::from_u8(vec![k / 2, n], &packed).unwrap(),
                HostTensor::from_f32(vec![k / g, n], &q.scales).unwrap(),
            ],
        )
        .expect("execute");
    let got = out[0].as_f32().unwrap();

    let wd = q.dequantize();
    for row in [0usize, 3, 7] {
        for col in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += x[row * k + kk] * wd[kk * n + col];
            }
            let gotv = got[row * n + col];
            assert!(
                (gotv - acc).abs() <= 1e-3 + 1e-4 * acc.abs(),
                "({row},{col}): {gotv} vs {acc}"
            );
        }
    }
}

#[test]
fn attention_kv8_kernel_matches_rust_reference() {
    let rt = runtime_or_skip!();
    // Shapes fixed by the microkernel artifact: B=2, H=8, Hkv=4, T=128, D=32.
    let (b, h, hkv, t, d) = (2usize, 8usize, 4usize, 128usize, 32usize);
    let group = h / hkv;
    let mut rng = Rng::new(3);
    let q: Vec<f32> = (0..b * h * d).map(|_| rng.next_f32() - 0.5).collect();
    let kf: Vec<f32> = (0..b * hkv * t * d).map(|_| rng.next_f32() - 0.5).collect();
    let vf: Vec<f32> = (0..b * hkv * t * d).map(|_| rng.next_f32() - 0.5).collect();
    let kv_len = [37i32, 128i32];

    // Quantize per (b, hkv, t) row with the Rust KV quantizer.
    let mut kq = vec![0i8; b * hkv * t * d];
    let mut ks = vec![0f32; b * hkv * t];
    let mut vq = vec![0i8; b * hkv * t * d];
    let mut vs = vec![0f32; b * hkv * t];
    for row in 0..b * hkv * t {
        let (c, s) = quant::quantize_kv_int8(&kf[row * d..(row + 1) * d]);
        kq[row * d..(row + 1) * d].copy_from_slice(&c);
        ks[row] = s;
        let (c, s) = quant::quantize_kv_int8(&vf[row * d..(row + 1) * d]);
        vq[row * d..(row + 1) * d].copy_from_slice(&c);
        vs[row] = s;
    }

    let out = rt
        .execute(
            "kernel_attn_kv8",
            &[
                HostTensor::from_f32(vec![b, h, d], &q).unwrap(),
                HostTensor::from_i8(vec![b, hkv, t, d], &kq).unwrap(),
                HostTensor::from_f32(vec![b, hkv, t], &ks).unwrap(),
                HostTensor::from_i8(vec![b, hkv, t, d], &vq).unwrap(),
                HostTensor::from_f32(vec![b, hkv, t], &vs).unwrap(),
                HostTensor::from_i32(vec![b], &kv_len).unwrap(),
            ],
        )
        .expect("execute");
    let got = out[0].as_f32().unwrap();

    // Rust reference attention over the dequantized KV.
    let scale = 1.0 / (d as f32).sqrt();
    for bi in 0..b {
        for hi in 0..h {
            let kvh = hi / group;
            let len = kv_len[bi] as usize;
            let qv = &q[(bi * h + hi) * d..(bi * h + hi + 1) * d];
            let mut scores = vec![0f32; len];
            for ti in 0..len {
                let row = (bi * hkv + kvh) * t + ti;
                let s = ks[row];
                let mut dot = 0f32;
                for di in 0..d {
                    dot += qv[di] * (kq[row * d + di] as f32 * s);
                }
                scores[ti] = dot * scale;
            }
            let m = scores.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut denom = 0f32;
            for s in scores.iter_mut() {
                *s = (*s - m).exp();
                denom += *s;
            }
            for di in 0..d {
                let mut acc = 0f32;
                for ti in 0..len {
                    let row = (bi * hkv + kvh) * t + ti;
                    acc += scores[ti] * (vq[row * d + di] as f32 * vs[row]);
                }
                acc /= denom;
                let gotv = got[(bi * h + hi) * d + di];
                assert!(
                    (gotv - acc).abs() < 2e-4,
                    "b{bi} h{hi} d{di}: {gotv} vs {acc}"
                );
            }
        }
    }
}

#[test]
fn execute_validates_input_shapes() {
    let rt = runtime_or_skip!();
    let bad = HostTensor::zeros(Dt::F32, vec![1, 1]);
    let err = rt.execute("kernel_gemm_w8", &[bad]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("dynamic inputs"), "{msg}");
}

#[test]
fn unknown_graph_is_helpful() {
    let rt = runtime_or_skip!();
    let err = rt.execute("no_such_graph", &[]).unwrap_err();
    assert!(err.to_string().contains("not in manifest"));
}

//! Cluster-tier integration tests: cross-replica determinism, the
//! randomized router harness (acceptance: bursty multi-tenant load over a
//! heterogeneous fleet loses and duplicates nothing, and per-replica
//! queue accounting drains to zero), and a live `serve_cluster` TCP
//! round-trip with the merged fleet stats probe.

use std::sync::mpsc;
use std::thread;

use turbomind::cluster::{
    run_fleet, Cluster, ClusterConfig, ReplicaSpec, RouterPolicy,
};
use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request};
use turbomind::server::{serve_cluster, Client};
use turbomind::util::proptest::{run_prop, Gen};
use turbomind::workload::MultiTenantGen;

fn base_cfg() -> EngineConfig {
    EngineConfig {
        precision: "W4A16KV8".parse().unwrap(),
        kv_pool_tokens: 16 * 64,
        prefill_chunk: 32,
        ..EngineConfig::default()
    }
}

/// Same request + same precision ⇒ bit-identical tokens on every replica,
/// and identical to a standalone engine of the same config — routing is a
/// performance decision, never a correctness one. Devices may differ:
/// the profile only scales modeled time.
#[test]
fn cross_replica_determinism_same_precision_any_replica() {
    let specs: Vec<ReplicaSpec> = vec![
        "w4a16,kv8,a100".parse().unwrap(),
        "w4a16,kv8,h100".parse().unwrap(), // different device, same format
        "w4a16,kv8,a100".parse().unwrap(),
    ];
    let cfg = ClusterConfig::heterogeneous(base_cfg(), specs, RouterPolicy::RoundRobin);
    let cluster = Cluster::start(cfg).unwrap();

    let prompt: Vec<i32> = (0..50).map(|j| (j * 13 + 7) % 2048).collect();
    let mut replies = Vec::new();
    for i in 0..3 {
        let (tx, rx) = mpsc::channel();
        cluster.dispatch_to(i, Request::new(prompt.clone(), 8), tx).unwrap();
        replies.push(rx);
    }
    let outs: Vec<_> = replies.iter().map(|rx| rx.recv().unwrap()).collect();
    for o in &outs {
        assert_eq!(o.finish, FinishReason::Length);
        assert_eq!(o.tokens.len(), 8);
    }
    assert_eq!(outs[0].tokens, outs[1].tokens, "replica 0 vs 1 (A100 vs H100)");
    assert_eq!(outs[0].tokens, outs[2].tokens, "replica 0 vs 2");

    // …and a standalone engine of the same config decodes the same.
    let mut reference = Engine::new(base_cfg()).unwrap();
    reference.submit(Request::new(prompt, 8)).unwrap();
    let ref_out = reference.run_to_completion().unwrap().remove(0);
    assert_eq!(ref_out.tokens, outs[0].tokens, "cluster vs single engine");

    for snap in cluster.shutdown().unwrap() {
        assert_eq!(snap.completed, 1);
        assert_eq!((snap.outstanding_reqs, snap.outstanding_tokens), (0, 0));
    }
}

/// The offline runner and the live threaded cluster agree token-for-token
/// under prefix_affinity — the bench's closed-loop numbers describe the
/// same fleet `serve_cluster` runs.
#[test]
fn offline_and_live_cluster_agree_on_outputs() {
    let g = MultiTenantGen {
        tenants: 2,
        users: 2,
        turns: 2,
        shared_tokens: 64,
        turn_tokens: 8,
        gen_tokens: 5,
        rate: 10.0,
        seed: 77,
    };
    let reqs: Vec<Request> = g
        .generate()
        .iter()
        .enumerate()
        .map(|(i, r)| Request::new(g.prompt_tokens(i, 2048), r.gen_tokens))
        .collect();
    let mut cfg = ClusterConfig::homogeneous(base_cfg(), 2, RouterPolicy::PrefixAffinity);
    cfg.base.enable_prefix_cache = true;

    let offline = run_fleet(&cfg, &reqs).unwrap();
    assert_eq!(offline.completed(), reqs.len());

    let mut live = Cluster::start(cfg).unwrap();
    let mut replies = Vec::new();
    for (gi, req) in reqs.iter().enumerate() {
        let (idx, rx) = live.submit(req.clone()).unwrap();
        assert_eq!(idx, offline.assignments[gi], "policy must route identically");
        replies.push(rx);
    }
    for (gi, rx) in replies.iter().enumerate() {
        let out = rx.recv().unwrap();
        assert_eq!(
            out.tokens, offline.outputs[gi].output.tokens,
            "request {gi}: live tokens diverge from offline run"
        );
    }
    live.shutdown().unwrap();
}

/// Acceptance (b): randomized bursty multi-tenant traffic over random
/// fleets (homogeneous and heterogeneous, all three policies, tight
/// bounded inboxes for real backpressure) — every request is answered
/// exactly once, and at drain every replica's queue accounting returns to
/// zero with the pool empty except for intentionally-resident prefix
/// blocks.
#[test]
fn randomized_router_harness_no_loss_no_dup_drains_to_zero() {
    run_prop("router-harness", 0x2007_C1A5, 10, |g: &mut Gen| {
        let n_replicas = g.usize_in(1, 3);
        let policy = *g.choose(&[
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::PrefixAffinity,
        ]);
        let spec_pool = ["w4a16,kv8,a100", "w8a8,kv16,h100", "w4a16,kv4,l40s"];
        let specs: Vec<ReplicaSpec> = (0..n_replicas)
            .map(|_| g.choose(&spec_pool).parse().unwrap())
            .collect();
        let mut base = base_cfg();
        base.enable_prefix_cache = g.bool();
        base.preemption_mode = *g.choose(&[
            turbomind::config::PreemptionMode::Abort,
            turbomind::config::PreemptionMode::Swap,
            turbomind::config::PreemptionMode::Recompute,
        ]);
        let mut cfg = ClusterConfig::heterogeneous(base, specs, policy);
        cfg.queue_depth = g.usize_in(2, 8); // tight: dispatch must block

        let mut cluster = Cluster::start(cfg).unwrap();
        let n_requests = g.usize_in(8, 24);
        // A few shared tenant prefixes + per-request random suffixes: the
        // multi-tenant mix, bursty because everything submits at once.
        let n_tenants = g.usize_in(1, 4);
        let tenant_prefix: Vec<Vec<i32>> = (0..n_tenants)
            .map(|t| (0..32).map(|j| ((t * 531 + j * 17 + 11) % 2048) as i32).collect())
            .collect();
        let mut replies = Vec::new();
        for _ in 0..n_requests {
            let mut prompt = tenant_prefix[g.usize_in(0, n_tenants - 1)].clone();
            let extra = g.usize_in(1, 40);
            for _ in 0..extra {
                prompt.push(g.usize_in(0, 2047) as i32);
            }
            let max_new = g.usize_in(1, 8);
            let (_, rx) = cluster.submit(Request::new(prompt, max_new)).unwrap();
            replies.push(rx);
        }
        // Every request answered exactly once: one output per receiver…
        let mut answered = 0usize;
        for rx in &replies {
            let out = rx.recv().expect("request lost");
            assert!(out.tokens.len() <= 8);
            answered += 1;
            // …and no duplicate reply ever arrives.
            assert!(
                rx.try_recv().is_err(),
                "duplicate reply for a single request"
            );
        }
        assert_eq!(answered, n_requests);

        let snaps = cluster.shutdown().unwrap();
        let completed: usize = snaps.iter().map(|s| s.completed).sum();
        assert_eq!(completed, n_requests, "per-replica completions must sum up");
        for s in &snaps {
            assert_eq!(
                (s.outstanding_reqs, s.outstanding_tokens),
                (0, 0),
                "replica {} queue accounting must drain to zero",
                s.id
            );
            assert_eq!(
                s.pool_total_blocks - s.pool_free_blocks,
                s.prefix_resident_blocks,
                "replica {}: pool holds only intentional prefix residency",
                s.id
            );
        }
    });
}

/// Live TCP round-trip through `serve_cluster`: concurrent clients over a
/// heterogeneous 2-replica fleet, responses per protocol, and the
/// `{"stats": true}` probe answering the merged fleet line (which rides
/// free on the `--max-requests` budget, like the single-engine server).
#[test]
fn serve_cluster_tcp_round_trip_with_fleet_stats() {
    let specs: Vec<ReplicaSpec> =
        vec!["w4a16,kv8,a100".parse().unwrap(), "w8a8,kv16,h100".parse().unwrap()];
    let cfg = ClusterConfig::heterogeneous(base_cfg(), specs, RouterPolicy::RoundRobin);
    let cluster = Cluster::start(cfg).unwrap();
    let addr = "127.0.0.1:7397";

    let mk_client = |tag: i32, probe: bool| {
        thread::spawn(move || {
            let mut client = loop {
                match Client::connect(addr) {
                    Ok(cl) => break cl,
                    Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
                }
            };
            let prompt: Vec<i32> = (0..20).map(|j| (tag * 97 + j) % 2048).collect();
            let r1 = client.generate(&prompt, 4).unwrap();
            assert_eq!(r1.req_str("finish").unwrap(), "length");
            assert_eq!(r1.req_arr("tokens").unwrap().len(), 4);
            assert!(r1.get("latency_sim_s").unwrap().as_f64().unwrap() > 0.0);
            if probe {
                let stats = client.stats().unwrap();
                assert_eq!(stats.get("cluster").unwrap().as_bool(), Some(true));
                assert_eq!(stats.req_usize("replicas").unwrap(), 2);
                assert_eq!(stats.req_str("policy").unwrap(), "round_robin");
                assert_eq!(stats.req_arr("per_replica").unwrap().len(), 2);
                assert!(stats.req_usize("completed_requests").unwrap() >= 1);
            }
            let r2 = client.generate(&prompt, 4).unwrap();
            assert_eq!(r2.req_str("finish").unwrap(), "length");
        })
    };
    let h1 = mk_client(1, true);
    let h2 = mk_client(2, false);
    serve_cluster(cluster, addr, Some(4)).unwrap();
    h1.join().unwrap();
    h2.join().unwrap();
}

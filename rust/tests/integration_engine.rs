//! End-to-end engine integration tests: submit → prefill → decode → finish
//! against the real AOT artifacts, across precision variants and scheduler
//! policies.

use turbomind::config::engine::SchedulerPolicy;
use turbomind::config::{DType, EngineConfig, PrecisionFormat};
use turbomind::coordinator::{Engine, FinishReason, Request};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TM_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

fn cfg(precision: &str) -> Option<EngineConfig> {
    let dir = artifacts_dir()?;
    Some(EngineConfig {
        artifacts_dir: dir,
        precision: precision.parse().unwrap(),
        max_batch: 4,
        kv_block_tokens: 16,
        kv_pool_tokens: 16 * 256,
        max_new_tokens: 8,
        prefill_chunk: 128,
        ..EngineConfig::default()
    })
}

macro_rules! engine_or_skip {
    ($prec:expr) => {
        match cfg($prec) {
            Some(c) => Engine::new(c).expect("engine"),
            None => {
                eprintln!("SKIP: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn single_request_completes() {
    let mut e = engine_or_skip!("W4A16KV8");
    let id = e.submit(Request::new(vec![5, 17, 99, 3], 6)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    let o = &outs[0];
    assert_eq!(o.id, id);
    assert_eq!(o.tokens.len(), 6);
    assert_eq!(o.finish, FinishReason::Length);
    assert_eq!(o.prompt_len, 4);
    assert!(o.ttft > 0.0 && o.ttft <= o.latency);
    // All tokens in vocab.
    assert!(o.tokens.iter().all(|&t| (0..2048).contains(&t)));
    // Pool fully reclaimed.
    assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());
}

#[test]
fn batch_of_requests_all_complete() {
    let mut e = engine_or_skip!("W4A16KV8");
    let mut ids = vec![];
    for i in 0..6 {
        ids.push(e.submit(Request::new(vec![i as i32 + 1, 40, 7], 5)).unwrap());
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 6);
    for o in &outs {
        assert_eq!(o.tokens.len(), 5, "req {}", o.id);
    }
    assert!(e.stats.decode_iters > 0);
    assert!(e.stats.prefill_iters >= 6);
}

#[test]
fn deterministic_given_seed_and_greedy() {
    let run = || {
        let mut e = engine_or_skip_val().expect("artifacts");
        e.submit(Request::new(vec![11, 22, 33, 44, 55], 8)).unwrap();
        e.run_to_completion().unwrap()[0].tokens.clone()
    };
    fn engine_or_skip_val() -> Option<Engine> {
        cfg("W4A16KV8").map(|c| Engine::new(c).unwrap())
    }
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    assert_eq!(run(), run());
}

#[test]
fn kv_precisions_agree_on_early_tokens() {
    // The same greedy request under KV8 / KV4 / KV16 should agree on at
    // least the first generated token (accuracy-equivalence smoke; the
    // Table 1 analogue lives in the accuracy bench).
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let tok_of = |prec: &str| {
        let mut e = Engine::new(cfg(prec).unwrap()).unwrap();
        e.submit(Request::new(vec![9, 8, 7, 6, 5, 4], 3)).unwrap();
        e.run_to_completion().unwrap()[0].tokens.clone()
    };
    let t16 = tok_of("W4A16KV16");
    let t8 = tok_of("W4A16KV8");
    let t4 = tok_of("W4A16KV4");
    assert_eq!(t16[0], t8[0], "kv8 diverged at the first token");
    assert_eq!(t16[0], t4[0], "kv4 diverged at the first token");
}

#[test]
fn w16_baseline_runs() {
    let mut e = engine_or_skip!("W16A16KV16");
    e.submit(Request::new(vec![100, 200, 300], 4)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs[0].tokens.len(), 4);
}

#[test]
fn long_prompt_uses_chunked_prefill() {
    let mut e = engine_or_skip!("W4A16KV8");
    let prompt: Vec<i32> = (0..200).map(|i| (i * 7 + 3) % 2048).collect();
    e.submit(Request::new(prompt, 4)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs[0].tokens.len(), 4);
    // 200 tokens at chunk 128 → 2 prefill iterations (128 + 72-pad-to-128).
    assert!(e.stats.prefill_iters >= 2, "prefill iters {}", e.stats.prefill_iters);
    assert_eq!(e.stats.prompt_tokens, 200);
}

#[test]
fn stop_token_ends_generation() {
    let mut e = engine_or_skip!("W4A16KV8");
    // Discover the greedy continuation, then rerun with it as stop token.
    e.submit(Request::new(vec![42, 43, 44], 4)).unwrap();
    let first = e.run_to_completion().unwrap()[0].tokens.clone();

    let mut e2 = Engine::new(cfg("W4A16KV8").unwrap()).unwrap();
    let mut req = Request::new(vec![42, 43, 44], 10);
    req.stop_token = Some(first[1]);
    e2.submit(req).unwrap();
    let outs = e2.run_to_completion().unwrap();
    assert_eq!(outs[0].finish, FinishReason::Stop);
    assert_eq!(outs[0].tokens.len(), 2);
}

#[test]
fn rejects_invalid_requests() {
    let mut e = engine_or_skip!("W4A16KV8");
    assert!(e.submit(Request::new(vec![], 4)).is_err(), "empty prompt");
    assert!(e.submit(Request::new(vec![1; 600], 4)).is_err(), "over context");
    assert!(e.submit(Request::new(vec![5000], 4)).is_err(), "token out of vocab");
    assert!(e.submit(Request::new(vec![-1], 4)).is_err(), "negative token");
}

#[test]
fn static_scheduler_completes_all() {
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut c = cfg("W4A16KV8").unwrap();
    c.scheduler = SchedulerPolicy::Static;
    let mut e = Engine::new(c).unwrap();
    for i in 0..5 {
        e.submit(Request::new(vec![i + 1, 2, 3], 4)).unwrap();
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 5);
}

#[test]
fn greedy_outputs_match_across_schedulers() {
    // Iteration-level batching must not change greedy results.
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let run = |policy| {
        let mut c = cfg("W4A16KV8").unwrap();
        c.scheduler = policy;
        let mut e = Engine::new(c).unwrap();
        for i in 0..3 {
            e.submit(Request::new(vec![50 + i, 60, 70, 80], 5)).unwrap();
        }
        let mut outs = e.run_to_completion().unwrap();
        outs.sort_by_key(|o| o.id);
        outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(
        run(SchedulerPolicy::Continuous),
        run(SchedulerPolicy::Static),
        "scheduler changed greedy outputs"
    );
}

#[test]
fn precision_formats_parse_to_variants() {
    // Engine creation must fail cleanly for formats with no artifacts.
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut c = cfg("W4A16KV8").unwrap();
    c.precision = PrecisionFormat::new(DType::Int8, DType::F16, DType::F16);
    assert!(Engine::new(c).is_err(), "w8 has no compiled graphs");
}

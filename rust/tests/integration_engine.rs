//! End-to-end engine integration tests: submit → prefill → decode → finish
//! on the hermetic sim backend, across precision variants and scheduler
//! policies. These run in every default `cargo test` — no artifacts, no
//! Python, no network.

use turbomind::config::engine::SchedulerPolicy;
use turbomind::config::{DType, EngineConfig, PrecisionFormat};
use turbomind::coordinator::{Engine, FinishReason, Request};

fn cfg(precision: &str) -> EngineConfig {
    EngineConfig {
        precision: precision.parse().unwrap(),
        max_batch: 4,
        kv_block_tokens: 16,
        kv_pool_tokens: 16 * 256,
        max_new_tokens: 8,
        prefill_chunk: 128,
        ..EngineConfig::default()
    }
}

fn engine(precision: &str) -> Engine {
    Engine::new(cfg(precision)).expect("engine")
}

#[test]
fn single_request_completes() {
    let mut e = engine("W4A16KV8");
    let id = e.submit(Request::new(vec![5, 17, 99, 3], 6)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 1);
    let o = &outs[0];
    assert_eq!(o.id, id);
    assert_eq!(o.tokens.len(), 6);
    assert_eq!(o.finish, FinishReason::Length);
    assert_eq!(o.prompt_len, 4);
    assert!(o.ttft > 0.0 && o.ttft <= o.latency);
    // All tokens in vocab.
    assert!(o.tokens.iter().all(|&t| (0..2048).contains(&t)));
    // Pool fully reclaimed.
    assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());
    // The sim backend attaches gpusim-modeled iteration time.
    assert!(e.stats.sim_time_s > 0.0, "sim time {}", e.stats.sim_time_s);
}

#[test]
fn batch_of_requests_all_complete() {
    let mut e = engine("W4A16KV8");
    for i in 0..6 {
        e.submit(Request::new(vec![i as i32 + 1, 40, 7], 5)).unwrap();
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 6);
    for o in &outs {
        assert_eq!(o.tokens.len(), 5, "req {}", o.id);
    }
    assert!(e.stats.decode_iters > 0);
    assert!(e.stats.prefill_iters >= 6);
}

#[test]
fn deterministic_given_seed_and_greedy() {
    let run = || {
        let mut e = engine("W4A16KV8");
        e.submit(Request::new(vec![11, 22, 33, 44, 55], 8)).unwrap();
        e.run_to_completion().unwrap()[0].tokens.clone()
    };
    assert_eq!(run(), run());
}

#[test]
fn kv_precisions_agree_on_early_tokens() {
    // The same greedy request under KV8 / KV4 / KV16 must agree on at
    // least the first generated token: chunk-1 prefill never reads the
    // quantized cache (the Table 1 accuracy-equivalence smoke; the full
    // analogue lives in the `table1_accuracy` bench).
    let tok_of = |prec: &str| {
        let mut e = engine(prec);
        e.submit(Request::new(vec![9, 8, 7, 6, 5, 4], 3)).unwrap();
        e.run_to_completion().unwrap()[0].tokens.clone()
    };
    let t16 = tok_of("W4A16KV16");
    let t8 = tok_of("W4A16KV8");
    let t4 = tok_of("W4A16KV4");
    assert_eq!(t16[0], t8[0], "kv8 diverged at the first token");
    assert_eq!(t16[0], t4[0], "kv4 diverged at the first token");
}

#[test]
fn w16_baseline_runs() {
    let mut e = engine("W16A16KV16");
    e.submit(Request::new(vec![100, 200, 300], 4)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs[0].tokens.len(), 4);
}

#[test]
fn long_prompt_uses_chunked_prefill() {
    let mut e = engine("W4A16KV8");
    let prompt: Vec<i32> = (0..200).map(|i| (i * 7 + 3) % 2048).collect();
    e.submit(Request::new(prompt, 4)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs[0].tokens.len(), 4);
    // 200 tokens at chunk 128 → 2 prefill iterations (128 + 72-pad-to-128).
    assert!(e.stats.prefill_iters >= 2, "prefill iters {}", e.stats.prefill_iters);
    assert_eq!(e.stats.prompt_tokens, 200);
}

#[test]
fn stop_token_ends_generation() {
    let mut e = engine("W4A16KV8");
    // Discover the greedy continuation, then rerun with it as stop token.
    e.submit(Request::new(vec![42, 43, 44], 4)).unwrap();
    let first = e.run_to_completion().unwrap()[0].tokens.clone();

    let mut e2 = engine("W4A16KV8");
    let stop = first[1];
    let mut req = Request::new(vec![42, 43, 44], 10);
    req.stop_token = Some(stop);
    e2.submit(req).unwrap();
    let outs = e2.run_to_completion().unwrap();
    assert_eq!(outs[0].finish, FinishReason::Stop);
    // Determinism: the rerun reproduces the same prefix, so generation ends
    // at the stop token's first occurrence.
    let pos = first.iter().position(|&t| t == stop).unwrap();
    assert_eq!(outs[0].tokens.len(), pos + 1);
    assert_eq!(*outs[0].tokens.last().unwrap(), stop);
}

#[test]
fn rejects_invalid_requests() {
    let mut e = engine("W4A16KV8");
    assert!(e.submit(Request::new(vec![], 4)).is_err(), "empty prompt");
    assert!(e.submit(Request::new(vec![1; 600], 4)).is_err(), "over context");
    assert!(e.submit(Request::new(vec![5000], 4)).is_err(), "token out of vocab");
    assert!(e.submit(Request::new(vec![-1], 4)).is_err(), "negative token");
}

#[test]
fn oversized_for_pool_aborts_at_submit_instead_of_stalling() {
    // Regression for the scheduler stall: a request that fits the model
    // context but can never fit the KV pool used to idle the engine
    // forever (`run_to_completion` would bail "engine stalled"). It must
    // now be finished as Aborted at submit time.
    let mut c = cfg("W4A16KV8");
    c.kv_pool_tokens = 16 * 4; // 64 tokens total
    let mut e = Engine::new(c).unwrap();
    let id = e.submit(Request::new(vec![1; 60], 40)).unwrap(); // needs 100 > 64
    let outs = e.run_to_completion().expect("must not stall");
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].id, id);
    assert_eq!(outs[0].finish, FinishReason::Aborted);
    assert!(outs[0].tokens.is_empty());
    assert_eq!(e.stats.aborted, 1);
    assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());

    // …and a feasible request afterwards still completes normally.
    e.submit(Request::new(vec![2, 3, 4], 4)).unwrap();
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs[0].finish, FinishReason::Length);
}

#[test]
fn static_scheduler_completes_all() {
    let mut c = cfg("W4A16KV8");
    c.scheduler = SchedulerPolicy::Static;
    let mut e = Engine::new(c).unwrap();
    for i in 0..5 {
        e.submit(Request::new(vec![i + 1, 2, 3], 4)).unwrap();
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 5);
}

#[test]
fn greedy_outputs_match_across_schedulers() {
    // Iteration-level batching must not change greedy results.
    let run = |policy| {
        let mut c = cfg("W4A16KV8");
        c.scheduler = policy;
        let mut e = Engine::new(c).unwrap();
        for i in 0..3 {
            e.submit(Request::new(vec![50 + i, 60, 70, 80], 5)).unwrap();
        }
        let mut outs = e.run_to_completion().unwrap();
        outs.sort_by_key(|o| o.id);
        outs.iter().map(|o| o.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(
        run(SchedulerPolicy::Continuous),
        run(SchedulerPolicy::Static),
        "scheduler changed greedy outputs"
    );
}

#[test]
fn precision_matrix_runs_end_to_end() {
    // The acceptance matrix: ≥3 precision formats × both scheduler
    // policies, every request completing through the full engine path.
    for prec in ["W4A16KV16", "W4A16KV8", "W4A16KV4", "W16A16KV16", "W8A16KV8"] {
        for policy in [SchedulerPolicy::Continuous, SchedulerPolicy::Static] {
            let mut c = cfg(prec);
            c.scheduler = policy;
            let mut e = Engine::new(c).unwrap();
            for i in 0..4 {
                e.submit(Request::new(vec![10 + i, 20, 30, 40, 50], 6)).unwrap();
            }
            let outs = e.run_to_completion().unwrap();
            assert_eq!(outs.len(), 4, "{prec} {policy:?}");
            for o in &outs {
                assert_eq!(o.finish, FinishReason::Length, "{prec} {policy:?} req {}", o.id);
                assert_eq!(o.tokens.len(), 6);
            }
            assert!(e.stats.sim_time_s > 0.0, "{prec}: no modeled time");
            assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());
        }
    }
}

#[test]
fn unsupported_precision_fails_cleanly() {
    // Engine creation must fail cleanly for formats with no numeric model
    // (fp8 weights on the sim backend).
    let mut c = cfg("W4A16KV8");
    c.precision = PrecisionFormat::new(DType::Fp8, DType::F16, DType::Int8);
    assert!(Engine::new(c).is_err(), "fp8 weights have no sim model");
}

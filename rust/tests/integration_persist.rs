//! Tiered KV persistence integration tests (DESIGN.md §14) on the
//! hermetic sim backend: a randomized crash-recovery harness over the
//! page-file store, end-to-end warm restart (same `--store-path`, fresh
//! engine, bit-identical replay), cross-layout adoption of shared
//! prefix blocks (kv16 on disk re-inflating into a kv4 pool), and the
//! abort-while-swapped accounting regression.
//!
//! The load-bearing claims:
//!   (a) truncating the page file at any page boundary loses only a
//!       suffix of the committed records — every survivor round-trips
//!       byte-exactly, nothing resurrects, nothing corrupt is served;
//!   (b) a reopened store warm-starts a fresh engine: recovered prefix
//!       blocks are adopted at admission and the replay is bit-identical
//!       to the cold run (greedy sampling, byte-exact imports);
//!   (c) adoption transcodes across layouts exactly — a kv4 engine fed
//!       kv16 blocks from disk matches a storeless kv4 run bit-for-bit;
//!   (d) cancelling a swapped-out request drops its host/page-file entry
//!       without pricing a swap-in that never happens: trace events
//!       reconcile exactly with the PCIe and disk byte counters.

use std::sync::Arc;

use turbomind::config::engine::{PreemptionMode, SchedulerPolicy};
use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request, RequestOutput};
use turbomind::kvcache::{KvLayout, KvPrecision, SeqSnapshot, SwapBackend};
use turbomind::store::{PageFileStore, StoreConfig};
use turbomind::trace::EventKind;
use turbomind::util::proptest::{run_prop, Gen};
use turbomind::workload::SharedPrefixGen;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tmkv-itest-persist-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn fresh_store(name: &str) -> (std::path::PathBuf, Arc<PageFileStore>) {
    let path = tmp(name);
    let _ = std::fs::remove_file(&path);
    (path.clone(), PageFileStore::open(StoreConfig::new(path)).unwrap())
}

/// Arbitrary snapshot with deterministic, case-seeded contents.
fn rand_snap(g: &mut Gen) -> SeqSnapshot {
    let prec = *g.choose(&[KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4]);
    let layout = KvLayout::uniform(prec, 2);
    let (kv_heads, head_dim) = (2usize, 8usize);
    let len = g.usize_in(1, 12);
    let tcb = layout.token_code_bytes(kv_heads, head_dim);
    let tag = g.usize_in(0, 255) as u8;
    SeqSnapshot {
        len,
        codes: (0..len * tcb).map(|i| (i as u8).wrapping_mul(13).wrapping_add(tag)).collect(),
        scales: g.f32_vec(len * 2 * 2 * kv_heads, -4.0, 4.0),
        kv_heads,
        head_dim,
        layout,
    }
}

/// What one harness case committed, in write order. In a fresh store with
/// no deletes allocation is append-only, so a page-boundary truncation
/// must leave the survivors forming a *prefix* of this order.
enum Written {
    Snap { id: u64, snap: SeqSnapshot },
    Pfx { key: u64, snap: SeqSnapshot },
}

#[test]
fn randomized_crash_recovery_loses_only_a_suffix_and_serves_survivors_byte_exactly() {
    run_prop("persist-crash", 0x9A6E_F11E, 12, |g: &mut Gen| {
        let page_size = *g.choose(&[512usize, 1024, 2048]);
        let path = tmp("crash.pages");
        let _ = std::fs::remove_file(&path);
        let cfg = StoreConfig::with_geometry(&path, page_size, 0);
        let mut written: Vec<Written> = Vec::new();
        {
            let store = PageFileStore::open(cfg.clone()).unwrap();
            let layout = KvLayout::uniform(KvPrecision::Int8, 2);
            let root = store.register_layout(&layout, 16).unwrap();
            let key_base = g.usize_in(1, 1 << 30) as u64;
            let n = g.usize_in(3, 8);
            for i in 0..n {
                let snap = rand_snap(g);
                if g.bool() {
                    store.put_snapshot(1, 100 + i as u64, &snap).unwrap();
                    written.push(Written::Snap { id: 100 + i as u64, snap });
                } else {
                    let key = key_base + i as u64;
                    assert!(store.publish_prefix_block(root, key, &snap).unwrap().is_some());
                    written.push(Written::Pfx { key, snap });
                }
            }
            store.sync().unwrap();
        }
        // Crash: cut the file at a random page boundary (keeping at least
        // the header page), then reopen.
        let file_len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(file_len % page_size as u64, 0, "extents are whole pages");
        let pages_total = (file_len / page_size as u64) as usize;
        let keep = g.usize_in(1, pages_total);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len((keep * page_size) as u64).unwrap();
        f.sync_all().unwrap();
        drop(f);

        let store = PageFileStore::open(cfg).unwrap();
        let mut alive = 0usize;
        let mut dead_seen = false;
        let (mut live_snaps, mut live_pfx) = (0usize, 0usize);
        for w in &written {
            let got = match w {
                Written::Snap { id, snap } => {
                    store.get_snapshot(1, *id).unwrap().map(|(s, _)| (s, snap))
                }
                Written::Pfx { key, snap } => {
                    store.get_prefix_block(*key).unwrap().map(|(s, _)| (s, snap))
                }
            };
            match got {
                Some((recovered, original)) => {
                    assert!(
                        !dead_seen,
                        "append-only store: a record after a lost one survived (keep={keep}/{pages_total})"
                    );
                    assert_eq!(&recovered, original, "survivor must round-trip byte-exactly");
                    alive += 1;
                    match w {
                        Written::Snap { .. } => live_snaps += 1,
                        Written::Pfx { .. } => live_pfx += 1,
                    }
                }
                None => dead_seen = true,
            }
        }
        let st = store.stats();
        assert_eq!(st.recovered_snapshots, live_snaps, "recovery count vs served snapshots");
        assert_eq!(st.recovered_prefix_blocks, live_pfx, "recovery count vs served prefix blocks");
        if keep == pages_total {
            assert_eq!(alive, written.len(), "nothing cut ⇒ everything recovers");
            assert_eq!(st.quarantined_pages, 0, "clean file must quarantine nothing");
        }
        drop(store);
        let _ = std::fs::remove_file(&path);
    });
}

fn chat_requests(gen: &SharedPrefixGen, vocab: usize) -> Vec<Request> {
    gen.generate()
        .iter()
        .enumerate()
        .map(|(i, r)| Request::new(gen.prompt_tokens(i, vocab), r.gen_tokens))
        .collect()
}

fn run_engine(cfg: EngineConfig, reqs: &[Request]) -> (Engine, Vec<RequestOutput>) {
    let mut e = Engine::new(cfg).unwrap();
    for r in reqs {
        e.submit(r.clone()).unwrap();
    }
    let mut outs = e.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    (e, outs)
}

fn streams(outs: &[RequestOutput]) -> Vec<(u64, Vec<i32>, FinishReason)> {
    outs.iter().map(|o| (o.id, o.tokens.clone(), o.finish)).collect()
}

fn chat_gen() -> SharedPrefixGen {
    SharedPrefixGen {
        shared_tokens: 48,
        users: 3,
        turns: 2,
        turn_tokens: 10,
        gen_tokens: 8,
        rate: 32.0,
        seed: 0xF11E_D00D,
    }
}

#[test]
fn warm_restart_recovers_prefix_blocks_and_replays_bit_identically() {
    let gen = chat_gen();
    let reqs = chat_requests(&gen, 2048);
    let base = EngineConfig {
        enable_prefix_cache: true,
        kv_layout: Some("kv8".into()),
        ..EngineConfig::default()
    };

    let (path, store) = fresh_store("warm.pages");
    let cold_cfg = EngineConfig { store: Some(store.clone()), ..base.clone() };
    let (cold_e, cold_outs) = run_engine(cold_cfg, &reqs);
    assert!(cold_e.stats.store_published_blocks > 0, "cold run must publish prefix blocks");
    let committed = store.stats().prefix_blocks;
    assert!(committed > 0);
    drop(cold_e);
    drop(store);

    // The restart: a brand-new handle on the same page file, a brand-new
    // engine with an empty local prefix cache.
    let warm_store = PageFileStore::open(StoreConfig::new(path.clone())).unwrap();
    assert_eq!(
        warm_store.stats().recovered_prefix_blocks,
        committed,
        "reopen must recover every committed prefix block"
    );
    assert_eq!(warm_store.stats().quarantined_pages, 0);
    let warm_cfg = EngineConfig { store: Some(warm_store.clone()), ..base };
    let (warm_e, warm_outs) = run_engine(warm_cfg, &reqs);
    assert!(warm_e.stats.store_prefix_hits > 0, "warm engine must adopt recovered blocks");
    assert!(warm_e.stats.store_prefix_hit_tokens > 0);
    assert_eq!(streams(&cold_outs), streams(&warm_outs), "warm replay must be bit-identical");
    // The adopted bytes are disk traffic, attributed to the snapshot's
    // recorded rung (kv8 here), and never PCIe-swap traffic.
    assert!(warm_e.stats.store_disk_bytes_by_rung[1] > 0);
    assert_eq!(warm_e.stats.swap_pcie_bytes_by_rung, [0usize; 3]);
    drop(warm_e);
    drop(warm_store);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn kv16_blocks_on_disk_reinflate_into_a_kv4_pool_bit_exactly() {
    // PR 5's warm-restore follow-up: a wide (kv16) snapshot on disk must
    // land in a narrower (kv4) pool via the byte-exact transcode, and —
    // because the sim's codes are a pure function of (token, position) —
    // match a storeless kv4 run exactly.
    let gen = chat_gen();
    let reqs = chat_requests(&gen, 2048);
    let mk = |layout: &str, store: Option<Arc<PageFileStore>>| EngineConfig {
        enable_prefix_cache: true,
        kv_layout: Some(layout.into()),
        store,
        ..EngineConfig::default()
    };

    let (path, store) = fresh_store("xlayout.pages");
    let (pub_e, _) = run_engine(mk("kv16", Some(store.clone())), &reqs);
    assert!(pub_e.stats.store_published_blocks > 0);
    drop(pub_e);

    let (baseline_e, baseline) = run_engine(mk("kv4", None), &reqs);
    assert_eq!(baseline_e.stats.store_prefix_hits, 0);
    let (adopt_e, adopted) = run_engine(mk("kv4", Some(store.clone())), &reqs);
    assert!(adopt_e.stats.store_prefix_hits > 0, "kv4 engine must adopt the kv16 chain");
    // Disk bytes carry the *stored* layout's rung (kv16 = rung 0).
    assert!(adopt_e.stats.store_disk_bytes_by_rung[0] > 0);
    assert_eq!(streams(&adopted), streams(&baseline), "cross-layout adoption must be exact");
    drop(adopt_e);
    drop(store);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn cancelling_a_swapped_victim_drops_its_entry_without_pricing_a_swap_in() {
    // Satellite regression: the old SwapStore leaked the aborted victim's
    // host entry and double-counted nothing back in; with the paged
    // backend the page-file snapshot must also disappear. Engineered
    // overflow (3 × 17-prompt/32-gen against an 8×16-token pool) forces a
    // swap-out; the victim is then cancelled while parked.
    let (path, store) = fresh_store("cancel.pages");
    let cfg = EngineConfig {
        precision: "W4A16KV8".parse().unwrap(),
        max_batch: 4,
        kv_block_tokens: 16,
        kv_pool_tokens: 16 * 8,
        prefill_chunk: 32,
        scheduler: SchedulerPolicy::Continuous,
        preemption_mode: PreemptionMode::Swap,
        store: Some(store.clone()),
        trace: true,
        trace_ring_capacity: 1 << 14,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(cfg).unwrap();
    let mut ids = Vec::new();
    for i in 0..3 {
        let prompt: Vec<i32> = (0..17).map(|j| ((i * 211 + j * 7) % 2048) as i32).collect();
        ids.push(e.submit(Request::new(prompt, 32)).unwrap());
    }
    let mut guard = 0;
    while e.swap_store().is_empty() {
        e.step().unwrap();
        guard += 1;
        assert!(guard < 10_000, "engineered overflow never swapped");
    }
    let victim = ids.iter().copied().find(|&id| e.swap_store().contains(id)).unwrap();
    assert!(store.stats().snapshots > 0, "paged backend must park the victim on disk");

    let pcie_before = e.stats.swap_pcie_bytes_by_rung;
    let disk_before = e.stats.store_disk_bytes_by_rung;
    let ins_before = e.swap_store().stats().swap_ins;
    assert!(e.cancel(victim), "victim is live");
    assert!(!e.swap_store().contains(victim), "cancel must drop the parked entry");
    assert_eq!(e.swap_store().stats().dropped, 1);
    assert_eq!(e.swap_store().stats().swap_ins, ins_before, "no swap-in may be recorded");
    assert_eq!(e.stats.swap_pcie_bytes_by_rung, pcie_before, "no PCIe bytes for a drop");
    assert_eq!(e.stats.store_disk_bytes_by_rung, disk_before, "no disk bytes for a drop");

    let mut outs = e.run_to_completion().unwrap();
    outs.sort_by_key(|o| o.id);
    assert_eq!(outs.len(), 3);
    for o in &outs {
        if o.id == victim {
            assert_eq!(o.finish, FinishReason::Aborted);
            assert_eq!(o.abort_reason.as_deref(), Some("cancelled by client"));
        } else {
            assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
            assert_eq!(o.tokens.len(), 32);
        }
    }
    assert!(e.swap_store().is_empty(), "swap store must drain");
    assert_eq!(e.swap_store().used_blocks(), 0);
    assert_eq!(store.stats().snapshots, 0, "cancel leaked a page-file snapshot");
    let s = e.swap_store().stats();
    assert_eq!(s.swap_outs, s.swap_ins + s.dropped, "entry-level conservation");
    assert!(s.swap_outs > 0);

    // Event ↔ counter reconciliation: Σ SwapOut/SwapIn bytes == the PCIe
    // counter, Σ StoreWrite/StoreRead bytes == the disk counter — the
    // aborted victim's swap-out is in both, its never-run swap-in in
    // neither.
    let dump = e.trace_dump();
    assert_eq!(dump.dropped, 0, "ring sized to hold the whole run");
    let (mut pcie, mut disk) = ([0u64; 3], [0u64; 3]);
    let add = |acc: &mut [u64; 3], b: &[u64; 3]| {
        for (a, v) in acc.iter_mut().zip(b) {
            *a += v;
        }
    };
    for ev in &dump.events {
        match &ev.kind {
            EventKind::SwapOut { bytes_by_rung, .. } | EventKind::SwapIn { bytes_by_rung, .. } => {
                add(&mut pcie, bytes_by_rung)
            }
            EventKind::StoreWrite { bytes_by_rung, .. }
            | EventKind::StoreRead { bytes_by_rung, .. } => add(&mut disk, bytes_by_rung),
            _ => {}
        }
    }
    assert_eq!(pcie, e.stats.swap_pcie_bytes_by_rung.map(|b| b as u64), "PCIe reconciliation");
    assert_eq!(disk, e.stats.store_disk_bytes_by_rung.map(|b| b as u64), "disk reconciliation");
    drop(e);
    drop(store);
    let _ = std::fs::remove_file(&path);
}

//! Server + failure-injection integration tests on the hermetic sim
//! backend: a real TCP listener, real client threads, the real engine loop.

use std::thread;

use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request};
use turbomind::server::{serve, Client};

fn cfg() -> EngineConfig {
    EngineConfig {
        precision: "W4A16KV8".parse().unwrap(),
        max_batch: 4,
        kv_pool_tokens: 16 * 256,
        ..EngineConfig::default()
    }
}

#[test]
fn tcp_roundtrip_two_clients() {
    let engine = Engine::new(cfg()).unwrap();
    let addr = "127.0.0.1:7391";

    let mk_client = |tag: i32| {
        thread::spawn(move || {
            let mut client = loop {
                match Client::connect(addr) {
                    Ok(cl) => break cl,
                    Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
                }
            };
            let prompt: Vec<i32> = (0..10).map(|j| (tag * 100 + j) % 2048).collect();
            let resp = client.generate(&prompt, 4).unwrap();
            assert_eq!(resp.req_str("finish").unwrap(), "length");
            assert_eq!(resp.req_arr("tokens").unwrap().len(), 4);
            assert!(resp.get("ttft_s").unwrap().as_f64().unwrap() > 0.0);
        })
    };
    let h1 = mk_client(1);
    let h2 = mk_client(2);
    serve(engine, addr, Some(2)).unwrap();
    h1.join().unwrap();
    h2.join().unwrap();
}

#[test]
fn tcp_rejects_malformed_and_oversized() {
    let engine = Engine::new(cfg()).unwrap();
    let addr = "127.0.0.1:7392";
    let h = thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = loop {
            match std::net::TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
            }
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        let mut roundtrip = |req: &str, line: &mut String| {
            stream.write_all(req.as_bytes()).unwrap();
            line.clear();
            reader.read_line(line).unwrap();
        };
        // Malformed JSON → structured error line, connection stays usable.
        roundtrip("this is not json\n", &mut line);
        assert!(line.contains("error"), "{line}");
        // Empty prompt and zero budget → protocol errors, not engine work.
        roundtrip("{\"prompt\": []}\n", &mut line);
        assert!(line.contains("error") && line.contains("empty prompt"), "{line}");
        roundtrip("{\"prompt\": [1], \"max_new_tokens\": 0}\n", &mut line);
        assert!(line.contains("error") && line.contains("max_new_tokens"), "{line}");
        // Oversized request (over model context) → aborted output.
        let toks: Vec<String> = (0..600).map(|i| (i % 2048).to_string()).collect();
        let req = format!("{{\"prompt\": [{}], \"max_new_tokens\": 4}}\n", toks.join(","));
        roundtrip(&req, &mut line);
        assert!(line.contains("aborted"), "{line}");
        // A good request still works on the same connection.
        roundtrip("{\"prompt\": [5, 6, 7], \"max_new_tokens\": 3}\n", &mut line);
        assert!(line.contains("length"), "{line}");
    });
    serve(engine, addr, Some(1)).unwrap();
    h.join().unwrap();
}

#[test]
fn kv_pool_exhaustion_admission_control() {
    // A pool that can only hold ~2 concurrent sequences: the engine must
    // still finish everything (queuing, not crashing) and reclaim blocks.
    let mut c = cfg();
    c.kv_pool_tokens = 16 * 8; // 128 tokens total
    let mut e = Engine::new(c).unwrap();
    for i in 0..4 {
        // Each request needs 40 + 8 = 48 tokens → only 2 fit at once.
        let prompt: Vec<i32> = (0..40).map(|j| (i * 37 + j) % 2048).collect();
        e.submit(Request::new(prompt, 8)).unwrap();
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 4);
    for o in &outs {
        assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
        assert_eq!(o.tokens.len(), 8);
    }
    assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());
    assert_eq!(e.stats.aborted, 0);
}

#[test]
fn request_larger_than_pool_aborts_at_submit() {
    // Regression for the scheduler stall (see coordinator::scheduler): a
    // request whose KV footprint exceeds the whole pool is finished as
    // Aborted at submit time instead of idling the engine forever.
    let mut c = cfg();
    c.kv_pool_tokens = 16 * 4; // 64 tokens
    let mut e = Engine::new(c).unwrap();
    let id = e.submit(Request::new(vec![1; 100], 8)).unwrap();
    assert!(!e.has_work(), "aborted request must not occupy the queue");
    let outs = e.take_outputs();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].id, id);
    assert_eq!(outs[0].finish, FinishReason::Aborted);
    assert_eq!(e.stats.aborted, 1);
}

#[test]
fn prefix_hit_tokens_and_stats_round_trip_over_tcp() {
    // Prefix cache on, 32-token chunks: the same 72-token prompt twice on
    // one connection. The second response must report the 64 shared tokens
    // as a hit, and the `{"stats": true}` probe must expose pool
    // utilization plus the cache hit rate.
    let mut c = cfg();
    c.prefill_chunk = 32;
    c.kv_block_tokens = 16;
    c.enable_prefix_cache = true;
    let engine = Engine::new(c).unwrap();
    let addr = "127.0.0.1:7394";
    let h = thread::spawn(move || {
        let mut client = loop {
            match Client::connect(addr) {
                Ok(cl) => break cl,
                Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
            }
        };
        let prompt: Vec<i32> = (0..72).map(|j| (j * 11 + 3) % 2048).collect();
        let r1 = client.generate(&prompt, 4).unwrap();
        assert_eq!(r1.req_str("finish").unwrap(), "length");
        assert_eq!(r1.req_usize("prefix_hit_tokens").unwrap(), 0, "cold cache");

        let r2 = client.generate(&prompt, 4).unwrap();
        assert_eq!(r2.req_str("finish").unwrap(), "length");
        // 72-token prompt, 32-token chunks: the final chunk reruns, so the
        // hit is the first 64 tokens (4 full blocks).
        assert_eq!(r2.req_usize("prefix_hit_tokens").unwrap(), 64);
        // Identical prompt + greedy sampling ⇒ identical tokens either way.
        assert_eq!(
            r1.req_arr("tokens").unwrap(),
            r2.req_arr("tokens").unwrap(),
            "cache reuse changed outputs"
        );

        let stats = client.stats().unwrap();
        assert_eq!(stats.get("prefix_cache_enabled").unwrap().as_bool(), Some(true));
        assert_eq!(stats.req_usize("prefix_cache_lookups").unwrap(), 2);
        assert_eq!(stats.req_usize("prefix_cache_hits").unwrap(), 1);
        assert_eq!(stats.get("prefix_cache_hit_rate").unwrap().as_f64(), Some(0.5));
        assert_eq!(stats.req_usize("prefix_cache_blocks_saved").unwrap(), 4);
        assert_eq!(stats.req_usize("prefill_tokens_skipped").unwrap(), 64);
        // The cached blocks keep the pool partially utilized.
        let total = stats.req_usize("pool_blocks_total").unwrap();
        let free = stats.req_usize("pool_blocks_free").unwrap();
        assert_eq!(total - free, 4, "4 prefix blocks resident");
        assert!(stats.get("pool_utilization").unwrap().as_f64().unwrap() > 0.0);
        // Completed-request percentiles ride on the same probe line.
        assert_eq!(stats.req_usize("completed_requests").unwrap(), 2);
        assert!(stats.get("latency_p95_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(stats.get("ttft_p50_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(stats.get("tpot_p99_s").unwrap().as_f64().unwrap() > 0.0);

        // A third generation after the probe: with `--max-requests 3`
        // the probe must NOT have eaten the budget (regression for the
        // probes-burn-shutdown-budget bug).
        let r3 = client.generate(&prompt, 4).unwrap();
        assert_eq!(r3.req_usize("prefix_hit_tokens").unwrap(), 64);
    });
    // Three generations; the stats probe rides for free.
    serve(engine, addr, Some(3)).unwrap();
    h.join().unwrap();
}

#[test]
fn probes_garbage_and_extra_connections_do_not_burn_shutdown_budget() {
    // Regression: the accept loop used to cap *connections* and the serve
    // loop counted stats probes, so `{"stats": true}` monitors and idle
    // connections starved a bounded run. Now only completed generation
    // requests count toward `--max-requests`.
    let engine = Engine::new(cfg()).unwrap();
    let addr = "127.0.0.1:7395";
    let h = thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let connect = || loop {
            match std::net::TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
            }
        };
        // Two extra connections that send no generation work: an idle one
        // and a monitoring probe (old code: these two alone exhausted the
        // accept budget of a 2-request run).
        let _idle = connect();
        let mut probe = Client::connect(addr).unwrap();
        assert_eq!(probe.stats().unwrap().req_usize("completed_requests").unwrap(), 0);

        // The real client on a third connection: garbage, a probe, and
        // two generations — all on one stream.
        let mut stream = connect();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        let mut roundtrip = |req: &str, line: &mut String| {
            stream.write_all(req.as_bytes()).unwrap();
            line.clear();
            reader.read_line(line).unwrap();
        };
        roundtrip("garbage\n", &mut line);
        assert!(line.contains("error"), "{line}");
        roundtrip("{\"stats\": true}\n", &mut line);
        assert!(line.contains("pool_blocks_total"), "{line}");
        roundtrip("{\"prompt\": [1, 2, 3], \"max_new_tokens\": 2}\n", &mut line);
        assert!(line.contains("length"), "{line}");
        roundtrip("{\"prompt\": [4, 5, 6], \"max_new_tokens\": 2}\n", &mut line);
        assert!(line.contains("length"), "{line}");
    });
    // Exactly the two generations end the run — everything else is free.
    serve(engine, addr, Some(2)).unwrap();
    h.join().unwrap();
}

#[test]
fn oversized_for_pool_reported_as_aborted_over_tcp() {
    // The TCP surface of the same regression: the client gets a normal
    // response line with "finish": "aborted", not a dropped connection.
    let mut c = cfg();
    c.kv_pool_tokens = 16 * 4; // 64 tokens
    let engine = Engine::new(c).unwrap();
    let addr = "127.0.0.1:7393";
    let h = thread::spawn(move || {
        let mut client = loop {
            match Client::connect(addr) {
                Ok(cl) => break cl,
                Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
            }
        };
        let prompt: Vec<i32> = (0..100).map(|j| j % 2048).collect();
        let resp = client.generate(&prompt, 8).unwrap();
        assert_eq!(resp.req_str("finish").unwrap(), "aborted");
        // The aborted line is structured: it says *why* (the KV-blocks
        // arithmetic), instead of an opaque finish + a server-side
        // eprintln. Successful lines carry a null reason.
        assert!(
            resp.req_str("abort_reason").unwrap().contains("KV blocks"),
            "{resp:?}"
        );
        // …and the connection still serves a feasible request.
        let resp = client.generate(&[5, 6, 7], 3).unwrap();
        assert_eq!(resp.req_str("finish").unwrap(), "length");
        assert!(resp.req_str("abort_reason").is_err(), "null reason on success");
        assert_eq!(resp.req_usize("preempt_count").unwrap(), 0);
    });
    serve(engine, addr, Some(2)).unwrap();
    h.join().unwrap();
}

//! Server + failure-injection integration tests (need artifacts).

use std::thread;

use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, FinishReason, Request};
use turbomind::server::{serve, Client};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TM_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

fn cfg() -> Option<EngineConfig> {
    Some(EngineConfig {
        artifacts_dir: artifacts_dir()?,
        precision: "W4A16KV8".parse().unwrap(),
        max_batch: 4,
        kv_pool_tokens: 16 * 256,
        ..EngineConfig::default()
    })
}

#[test]
fn tcp_roundtrip_two_clients() {
    let Some(c) = cfg() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let engine = Engine::new(c).unwrap();
    let addr = "127.0.0.1:7391";

    let mk_client = |tag: i32| {
        thread::spawn(move || {
            let mut client = loop {
                match Client::connect(addr) {
                    Ok(cl) => break cl,
                    Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
                }
            };
            let prompt: Vec<i32> = (0..10).map(|j| (tag * 100 + j) % 2048).collect();
            let resp = client.generate(&prompt, 4).unwrap();
            assert_eq!(resp.req_str("finish").unwrap(), "length");
            assert_eq!(resp.req_arr("tokens").unwrap().len(), 4);
            assert!(resp.get("ttft_s").unwrap().as_f64().unwrap() > 0.0);
        })
    };
    let h1 = mk_client(1);
    let h2 = mk_client(2);
    serve(engine, addr, Some(2)).unwrap();
    h1.join().unwrap();
    h2.join().unwrap();
}

#[test]
fn tcp_rejects_malformed_and_oversized() {
    let Some(c) = cfg() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let engine = Engine::new(c).unwrap();
    let addr = "127.0.0.1:7392";
    let h = thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = loop {
            match std::net::TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(_) => thread::sleep(std::time::Duration::from_millis(30)),
            }
        };
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // Malformed JSON → error response, connection stays usable.
        stream.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        // Oversized request → aborted output.
        let toks: Vec<String> = (0..600).map(|i| (i % 2048).to_string()).collect();
        let req = format!("{{\"prompt\": [{}], \"max_new_tokens\": 4}}\n", toks.join(","));
        stream.write_all(req.as_bytes()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("aborted"), "{line}");
        // A good request still works on the same connection.
        stream.write_all(b"{\"prompt\": [5, 6, 7], \"max_new_tokens\": 3}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("length"), "{line}");
    });
    serve(engine, addr, Some(1)).unwrap();
    h.join().unwrap();
}

#[test]
fn kv_pool_exhaustion_admission_control() {
    // A pool that can only hold ~2 concurrent sequences: the engine must
    // still finish everything (queuing, not crashing) and reclaim blocks.
    let Some(mut c) = cfg() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    c.kv_pool_tokens = 16 * 8; // 128 tokens total
    let mut e = Engine::new(c).unwrap();
    for i in 0..4 {
        // Each request needs 40 + 8 = 48 tokens → only 2 fit at once.
        let prompt: Vec<i32> = (0..40).map(|j| (i * 37 + j) % 2048).collect();
        e.submit(Request::new(prompt, 8)).unwrap();
    }
    let outs = e.run_to_completion().unwrap();
    assert_eq!(outs.len(), 4);
    for o in &outs {
        assert_eq!(o.finish, FinishReason::Length, "req {}", o.id);
        assert_eq!(o.tokens.len(), 8);
    }
    assert_eq!(e.kv_pool().free_blocks(), e.kv_pool().total_blocks());
    assert_eq!(e.stats.aborted, 0);
}

#[test]
fn request_larger_than_pool_rejected_at_submit() {
    let Some(mut c) = cfg() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    c.kv_pool_tokens = 16 * 4; // 64 tokens
    let mut e = Engine::new(c).unwrap();
    let err = e.submit(Request::new(vec![1; 100], 8)).unwrap_err();
    assert!(err.to_string().contains("pool"), "{err}");
}

//! Plain-text result tables (the bench harness's output format).

use std::fmt::Write as _;

/// A titled table of rows, printed with aligned columns.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form footnotes (paper reference values, caveats).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], w: &[usize]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:<width$}  ", c, width = w[i]);
            }
            s.trim_end().to_string()
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        for n in &self.notes {
            let _ = writeln!(out, "  * {n}");
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds as milliseconds with 3 significant decimals.
pub fn ms(t: f64) -> String {
    format!("{:.3}", t * 1e3)
}

/// Format a ratio as a percentage improvement ("+23.4%").
pub fn pct_improvement(baseline: f64, ours: f64) -> String {
    let p = (baseline / ours - 1.0) * 100.0;
    format!("{p:+.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["x".into(), "yyyyyyyyyyyyyy".into(), "z".into()]);
        t.note("a note");
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long_header"));
        assert!(s.contains("* a note"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_row() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(ms(0.0123), "12.300");
        assert_eq!(pct_improvement(2.0, 1.0), "+100.0%");
        assert_eq!(pct_improvement(1.0, 2.0), "-50.0%");
    }
}

//! `bench persist` — tiered KV persistence on the page-file store
//! (DESIGN.md §14): per-rung on-disk footprint, warm restart from the
//! same `--store-path`, and a host-global prefix store shared by two
//! replicas.
//!
//! Three sections, one shared-prefix chat workload:
//!
//! * **footprint** — the same trace served at kv16 / kv8 / kv4 against a
//!   fresh store each; the live on-disk payload must shrink with the
//!   rung (kv4 ≤ 0.3 × kv16 — codes shrink 4×, the f32 scale rows keep
//!   the ratio just under 0.3 for the tiny model).
//! * **restart** — run, drop the engine, reopen the *same* page file
//!   with a fresh engine and replay the trace: the reopen must recover
//!   the published prefix blocks, the warm engine must adopt them
//!   (`store_prefix_hits > 0`), and its outputs must be bit-identical
//!   to the cold run's.
//! * **fleet** — two replicas, round-robin router. With per-replica
//!   caches only, each replica pays its own cold miss on the shared
//!   system prompt; with one shared store the second replica adopts the
//!   first's published blocks, so the effective fleet hit rate
//!   `(local hits + store hits) / (local lookups + store hits)` is
//!   strictly above the baseline's.
//!
//! Rows are mirrored to `BENCH_persist.json`; `BENCH_ASSERT=1` (CI) and
//! the unit test below run [`assert_persist_table`].

use std::sync::Arc;

use super::table::Table;
use crate::cluster::{run_fleet, ClusterConfig, ReplicaSpec, RouterPolicy};
use crate::config::EngineConfig;
use crate::coordinator::{Engine, FinishReason, Request, RequestOutput};
use crate::store::{PageFileStore, StoreConfig};
use crate::util::json::{arr, obj, Json};
use crate::workload::SharedPrefixGen;

/// Fresh page file under the OS temp dir (unique per process + tag);
/// any stale file from a crashed earlier run is removed first.
fn fresh_store(tag: &str) -> (std::path::PathBuf, Arc<PageFileStore>) {
    let path = std::env::temp_dir()
        .join(format!("turbomind-bench-persist-{}-{tag}.pgf", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let store = PageFileStore::open(StoreConfig::new(path.clone())).expect("bench persist store");
    (path, store)
}

fn chat_requests(gen: &SharedPrefixGen, vocab: usize) -> Vec<Request> {
    gen.generate()
        .iter()
        .enumerate()
        .map(|(i, r)| Request::new(gen.prompt_tokens(i, vocab), r.gen_tokens))
        .collect()
}

/// Submit the whole trace, run to drain, return outputs in id order.
fn run_engine(cfg: EngineConfig, reqs: &[Request]) -> (Vec<RequestOutput>, Engine) {
    let mut e = Engine::new(cfg).expect("bench persist engine");
    for r in reqs {
        e.submit(r.clone()).expect("bench persist submit");
    }
    let mut outs = e.run_to_completion().expect("hermetic bench run");
    outs.sort_by_key(|o| o.id);
    (outs, e)
}

fn token_streams(outs: &[RequestOutput]) -> Vec<(u64, Vec<i32>)> {
    outs.iter().map(|o| (o.id, o.tokens.clone())).collect()
}

fn completed(outs: &[RequestOutput]) -> usize {
    outs.iter().filter(|o| o.finish != FinishReason::Aborted).count()
}

pub fn fig_persist() -> Table {
    let mut t = Table::new(
        "bench persist — page-file KV store: per-rung footprint, warm restart, shared fleet prefix",
        &["section", "config", "completed", "on-disk B", "pages", "recovered", "store hits", "check"],
    );
    let gen = SharedPrefixGen {
        shared_tokens: 64,
        users: 4,
        turns: 2,
        turn_tokens: 12,
        gen_tokens: 8,
        rate: 32.0,
        seed: 0x9E51,
    };
    let base = EngineConfig { enable_prefix_cache: true, ..EngineConfig::default() };
    let vocab = 2048;
    let reqs = chat_requests(&gen, vocab);
    let mut json_rows: Vec<Json> = Vec::new();
    let push_json = |section: &str,
                         config: &str,
                         metrics: Vec<(&str, Json, &str)>,
                         json_rows: &mut Vec<Json>| {
        for (metric, value, unit) in metrics {
            json_rows.push(obj([
                ("bench", Json::from("persist")),
                ("metric", Json::from(metric)),
                ("value", value),
                ("unit", Json::from(unit)),
                ("section", Json::from(section)),
                ("config", Json::from(config)),
            ]));
        }
    };

    // ---- footprint: one fresh store per rung, same trace -------------
    for layout in ["kv16", "kv8", "kv4"] {
        let (path, store) = fresh_store(&format!("footprint-{layout}"));
        let cfg = EngineConfig {
            kv_layout: Some(layout.to_string()),
            store: Some(store.clone()),
            ..base.clone()
        };
        let (outs, _e) = run_engine(cfg, &reqs);
        let s = store.stats();
        t.row(vec![
            "footprint".into(),
            layout.into(),
            format!("{}/{}", completed(&outs), reqs.len()),
            s.on_disk_bytes().to_string(),
            s.used_pages.to_string(),
            "-".into(),
            "-".into(),
            format!("{} prefix blocks", s.prefix_blocks),
        ]);
        push_json(
            "footprint",
            layout,
            vec![
                ("on_disk_bytes", Json::from(s.on_disk_bytes()), "bytes"),
                ("used_pages", Json::from(s.used_pages), "pages"),
                ("prefix_blocks", Json::from(s.prefix_blocks), "blocks"),
            ],
            &mut json_rows,
        );
        let _ = std::fs::remove_file(&path);
    }

    // ---- restart: cold run, reopen the same file, replay -------------
    let (path, store) = fresh_store("restart");
    let cold_cfg =
        EngineConfig { kv_layout: Some("kv8".into()), store: Some(store.clone()), ..base.clone() };
    let (cold_outs, cold_e) = run_engine(cold_cfg, &reqs);
    let cold_s = store.stats();
    t.row(vec![
        "restart".into(),
        "cold".into(),
        format!("{}/{}", completed(&cold_outs), reqs.len()),
        cold_s.on_disk_bytes().to_string(),
        cold_s.used_pages.to_string(),
        "0".into(),
        cold_e.stats.store_prefix_hits.to_string(),
        "-".into(),
    ]);
    push_json(
        "restart",
        "cold",
        vec![
            ("on_disk_bytes", Json::from(cold_s.on_disk_bytes()), "bytes"),
            ("store_prefix_hits", Json::from(cold_e.stats.store_prefix_hits), "admissions"),
        ],
        &mut json_rows,
    );
    drop(cold_e);
    drop(store);
    // The reopen is the restart: a new handle on the same page file must
    // recover every committed prefix block from the header scan.
    let warm_store =
        PageFileStore::open(StoreConfig::new(path.clone())).expect("bench persist reopen");
    let warm_cfg = EngineConfig {
        kv_layout: Some("kv8".into()),
        store: Some(warm_store.clone()),
        ..base.clone()
    };
    let (warm_outs, warm_e) = run_engine(warm_cfg, &reqs);
    let warm_s = warm_store.stats();
    let identical = token_streams(&cold_outs) == token_streams(&warm_outs)
        && cold_outs.iter().map(|o| o.finish).eq(warm_outs.iter().map(|o| o.finish));
    t.row(vec![
        "restart".into(),
        "warm".into(),
        format!("{}/{}", completed(&warm_outs), reqs.len()),
        warm_s.on_disk_bytes().to_string(),
        warm_s.used_pages.to_string(),
        warm_s.recovered_prefix_blocks.to_string(),
        warm_e.stats.store_prefix_hits.to_string(),
        if identical { "bit-identical".into() } else { "DIVERGED".to_string() },
    ]);
    push_json(
        "restart",
        "warm",
        vec![
            ("recovered_prefix_blocks", Json::from(warm_s.recovered_prefix_blocks), "blocks"),
            ("store_prefix_hits", Json::from(warm_e.stats.store_prefix_hits), "admissions"),
            ("store_prefix_hit_tokens", Json::from(warm_e.stats.store_prefix_hit_tokens), "tokens"),
            ("bit_identical", Json::from(identical as usize), "bool"),
        ],
        &mut json_rows,
    );
    drop(warm_e);
    drop(warm_store);
    let _ = std::fs::remove_file(&path);

    // ---- fleet: two replicas, per-replica caches vs one shared store -
    let specs: Vec<ReplicaSpec> = ["w4a16,kv8,a100", "w4a16,kv8,a100"]
        .iter()
        .map(|s| s.parse().expect("bench replica spec"))
        .collect();
    let fleet_gen = SharedPrefixGen {
        shared_tokens: 64,
        users: 6,
        turns: 2,
        turn_tokens: 12,
        gen_tokens: 10,
        rate: 8.0,
        seed: 0x9E51,
    };
    let fleet_reqs = chat_requests(&fleet_gen, vocab);
    let fleet_base = EngineConfig { max_batch: 4, prefill_chunk: 32, ..base.clone() };
    for shared in [false, true] {
        let (config, store_path, store) = if shared {
            let (p, st) = fresh_store("fleet");
            ("shared-store", Some(p), Some(st))
        } else {
            ("per-replica", None, None)
        };
        let mut b = fleet_base.clone();
        b.store = store.clone();
        let cfg = ClusterConfig::heterogeneous(b, specs.clone(), RouterPolicy::RoundRobin);
        let run = run_fleet(&cfg, &fleet_reqs).expect("hermetic fleet run");
        let pfx = run.fleet_prefix();
        let store_hits: usize = run.snapshots.iter().map(|s| s.stats.store_prefix_hits).sum();
        // Store adoptions replace the local lookup at admission, so the
        // effective denominator counts them back in.
        let rate = (pfx.hits + store_hits) as f64 / (pfx.lookups + store_hits).max(1) as f64;
        let disk = store.as_ref().map(|st| st.stats().on_disk_bytes());
        t.row(vec![
            "fleet".into(),
            config.into(),
            format!("{}/{}", run.completed(), fleet_reqs.len()),
            disk.map(|b| b.to_string()).unwrap_or_else(|| "-".into()),
            store.as_ref().map(|st| st.stats().used_pages.to_string()).unwrap_or_else(|| "-".into()),
            "-".into(),
            store_hits.to_string(),
            format!("{rate:.4}"),
        ]);
        push_json(
            "fleet",
            config,
            vec![
                ("completed", Json::from(run.completed()), "requests"),
                ("local_lookups", Json::from(pfx.lookups), "admissions"),
                ("local_hits", Json::from(pfx.hits), "admissions"),
                ("store_prefix_hits", Json::from(store_hits), "admissions"),
                ("effective_hit_rate", Json::from(rate), "ratio"),
                ("on_disk_bytes", Json::from(disk.unwrap_or(0)), "bytes"),
            ],
            &mut json_rows,
        );
        drop(run);
        drop(store);
        if let Some(p) = store_path {
            let _ = std::fs::remove_file(&p);
        }
    }

    let doc = obj([
        ("bench", Json::from("persist")),
        (
            "workload",
            Json::from("SharedPrefixGen, 64-token shared prefix; 4 users × 2 turns (single engine), 6 users × 2 turns (fleet)"),
        ),
        ("rows", arr(json_rows)),
    ]);
    // Repo root, independent of the invoking cwd. Best-effort: a
    // read-only checkout must not fail the bench itself.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_persist.json");
    if let Err(e) = std::fs::write(path, doc.dump() + "\n") {
        eprintln!("bench persist: could not write {path}: {e}");
    }
    if std::env::var("BENCH_ASSERT").as_deref() == Ok("1") {
        assert_persist_table(&t);
        eprintln!("bench persist: BENCH_ASSERT checks passed");
    }
    t.note("repo extension: page-file-backed KV persistence with a host-global prefix store (DESIGN.md §14); kv4's live on-disk payload ≤ 0.3× kv16's, a reopened store warm-starts a fresh engine with store prefix hits and bit-identical outputs, and two replicas sharing one store beat the per-replica-cache fleet hit rate — asserted by bench::persist tests (and at runtime with BENCH_ASSERT=1); rows mirrored to BENCH_persist.json");
    t
}

/// The `bench persist` acceptance checks, shared by the unit test and
/// the generator's `BENCH_ASSERT=1` CI mode.
pub fn assert_persist_table(t: &Table) {
    assert_eq!(t.rows.len(), 7, "3 footprint + 2 restart + 2 fleet rows");
    let col = |name: &str| t.headers.iter().position(|h| h == name).unwrap();
    let (sec_c, cfg_c, done_c) = (col("section"), col("config"), col("completed"));
    let (bytes_c, rec_c, hits_c, check_c) =
        (col("on-disk B"), col("recovered"), col("store hits"), col("check"));
    for row in &t.rows {
        let (served, total) = row[done_c].split_once('/').unwrap();
        assert_eq!(served, total, "row lost requests: {row:?}");
    }
    let get = |section: &str, config: &str| {
        t.rows
            .iter()
            .find(|r| r[sec_c] == section && r[cfg_c] == config)
            .unwrap_or_else(|| panic!("{section}/{config} row missing"))
    };
    let bytes = |section: &str, config: &str| -> usize {
        get(section, config)[bytes_c].parse().unwrap()
    };
    let (b16, b8, b4) = (
        bytes("footprint", "kv16"),
        bytes("footprint", "kv8"),
        bytes("footprint", "kv4"),
    );
    assert!(b4 > 0 && b4 < b8 && b8 < b16, "footprint must shrink with the rung: {b16}/{b8}/{b4}");
    // The ISSUE's gate: kv4 live payload ≤ 0.3 × kv16 (exact integer
    // arithmetic — per-token 640 B vs 2176 B for the tiny model).
    assert!(b4 * 10 <= b16 * 3, "kv4 on-disk bytes {b4} exceed 0.3 × kv16 {b16}");
    let warm = get("restart", "warm");
    assert!(warm[rec_c].parse::<usize>().unwrap() > 0, "reopen recovered no prefix blocks");
    assert!(warm[hits_c].parse::<usize>().unwrap() > 0, "warm engine adopted nothing");
    assert_eq!(warm[check_c], "bit-identical", "warm restart outputs diverged from cold run");
    let shared = get("fleet", "shared-store");
    assert!(shared[hits_c].parse::<usize>().unwrap() > 0, "shared fleet never hit the store");
    let (sr, br) = (
        shared[check_c].parse::<f64>().unwrap(),
        get("fleet", "per-replica")[check_c].parse::<f64>().unwrap(),
    );
    assert!(
        sr > br,
        "shared-store fleet hit rate {sr} not strictly above per-replica baseline {br}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn persist_bench_invariants() {
        assert_persist_table(&fig_persist());
    }
}

//! `bench hotpath` — the measured perf trajectory of the decode hot-path
//! rewrites, each vectorized/lock-free implementation timed against the
//! scalar or locked reference the repo retains (and property-tests
//! bit-identical):
//!
//! * word-at-a-time INT4/INT8 quant codecs ([`crate::quant::word`]) vs
//!   the `*_scalar` per-element loops;
//! * the plan/execute KV gather ([`crate::kvcache::pool::GatherPlan`])
//!   vs the pre-refactor per-token scalar walk;
//! * wait-free per-replica fleet accounting
//!   ([`crate::cluster::accounting`]) vs a shared
//!   `Mutex<MetricsCollector>` on the completion path;
//! * the flight recorder's disabled path ([`crate::trace`], DESIGN.md
//!   §12) vs the same bookkeeping with no trace plumbing — gated the
//!   *other* way (≥ 0.98×): recording off must cost nothing.
//!
//! Rows are mirrored to `BENCH_hotpath.json` in the flat
//! `{bench, metric, value, unit, ratio_vs_scalar}` schema. With
//! `BENCH_ASSERT=1` the two headline speedups — `int4_unpack` and
//! `gather_planned` — are asserted ≥ 1.5× in-run (release builds; debug
//! ratios are not meaningful and are not asserted by unit tests).

use std::hint::black_box;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::table::Table;
use crate::cluster::accounting::{self, ReplicaRecorder};
use crate::kvcache::{KvLayout, KvPool};
use crate::metrics::MetricsCollector;
use crate::quant::fragment::FRAG_ELEMS_PER_LANE;
use crate::quant::kv::{
    dequantize_kv_int4, dequantize_kv_int4_scalar, int4_from_int8, int4_from_int8_scalar,
};
use crate::quant::packing::{
    compress_lane_word, compress_lane_word_scalar, i2f_extract, i2f_extract_scalar,
};
use crate::quant::transcode::{int8_row_to_int4, int8_row_to_int4_scalar};
use crate::util::json::{arr, obj, Json};
use crate::util::rng::Rng;

/// Median over `reps` timing samples of `iters` calls each, seconds per
/// call. One untimed call first warms caches and fills lazy LUTs; the
/// median discards scheduler noise without hiding a consistently slow
/// implementation the way a min would.
fn median_secs(iters: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[reps / 2]
}

struct HotRow {
    metric: &'static str,
    scalar_s: f64,
    vector_s: f64,
    /// What one timed call covers, e.g. "4096-code row".
    unit: &'static str,
}

impl HotRow {
    fn ratio(&self) -> f64 {
        self.scalar_s / self.vector_s
    }
}

fn bench_codecs(rows: &mut Vec<HotRow>) {
    let mut rng = Rng::new(0x407_9A7);
    let n = 4096usize;
    let codes: Vec<i8> = (0..n).map(|_| (rng.next_u64() as u8) as i8).collect();

    rows.push(HotRow {
        metric: "int4_pack",
        scalar_s: median_secs(64, 9, || {
            black_box(int4_from_int8_scalar(black_box(&codes), 1.0));
        }),
        vector_s: median_secs(64, 9, || {
            black_box(int4_from_int8(black_box(&codes), 1.0));
        }),
        unit: "4096-code row",
    });

    let (packed, scale) = int4_from_int8(&codes, 1.0);
    rows.push(HotRow {
        metric: "int4_unpack",
        scalar_s: median_secs(64, 9, || {
            black_box(dequantize_kv_int4_scalar(black_box(&packed), n, scale));
        }),
        vector_s: median_secs(64, 9, || {
            black_box(dequantize_kv_int4(black_box(&packed), n, scale));
        }),
        unit: "4096-code row",
    });

    let bytes: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
    let mut dst = vec![0u8; n.div_ceil(2)];
    rows.push(HotRow {
        metric: "int8_to_int4_transcode",
        scalar_s: median_secs(64, 9, || {
            black_box(int8_row_to_int4_scalar(black_box(&bytes), 0.02, &mut dst));
        }),
        vector_s: median_secs(64, 9, || {
            black_box(int8_row_to_int4(black_box(&bytes), 0.02, &mut dst));
        }),
        unit: "4096-byte row",
    });

    // Weight-path fragment codec: one warp's worth of lane words per call.
    let frags: Vec<[u16; FRAG_ELEMS_PER_LANE]> = (0..256)
        .map(|_| {
            let mut f = [0u16; FRAG_ELEMS_PER_LANE];
            for e in f.iter_mut() {
                *e = rng.next_u64() as u16;
            }
            f
        })
        .collect();
    rows.push(HotRow {
        metric: "weight_compress",
        scalar_s: median_secs(256, 9, || {
            for f in &frags {
                black_box(compress_lane_word_scalar(black_box(f)));
            }
        }),
        vector_s: median_secs(256, 9, || {
            for f in &frags {
                black_box(compress_lane_word(black_box(f)));
            }
        }),
        unit: "256 lane words",
    });
    let words: Vec<u32> = frags.iter().map(compress_lane_word).collect();
    rows.push(HotRow {
        metric: "weight_extract",
        scalar_s: median_secs(256, 9, || {
            for &w in &words {
                black_box(i2f_extract_scalar(black_box(w)));
            }
        }),
        vector_s: median_secs(256, 9, || {
            for &w in &words {
                black_box(i2f_extract(black_box(w)));
            }
        }),
        unit: "256 lane words",
    });
}

fn bench_gather(rows: &mut Vec<HotRow>) {
    // Deep mixed-precision stack, small rows: the regime where the old
    // walk's per-(token, layer) prefix recomputation (O(L) each, O(L²)
    // per token) dominated the actual byte movement.
    let n_layers = 12usize;
    let spec: String = (0..n_layers)
        .map(|l| {
            let p = ["kv16", "kv16", "kv8", "kv8", "kv4", "kv4"][l % 6];
            format!("l{l}:{p}")
        })
        .collect::<Vec<_>>()
        .join(",");
    let layout = KvLayout::parse(&spec, n_layers).unwrap();
    let (kv_heads, head_dim, block_tokens) = (4usize, 32usize, 16usize);
    let (b, t_pad, seq_len) = (4usize, 256usize, 240usize);
    let mut pool = KvPool::with_layout(
        layout,
        kv_heads,
        head_dim,
        block_tokens,
        b * t_pad + 4 * block_tokens,
    )
    .unwrap();
    let per_side = kv_heads * pool.layout().sum_row_bytes(head_dim);
    let scales = vec![0.5f32; n_layers * kv_heads];
    let mut rng = Rng::new(0x6A7_8E4);
    let mut handles = Vec::new();
    for _ in 0..b {
        let h = pool.alloc_seq();
        for _ in 0..seq_len {
            let row: Vec<u8> = (0..per_side).map(|_| rng.next_u64() as u8).collect();
            pool.append_token(h, &row, &scales, &row, &scales).unwrap();
        }
        handles.push(Some(h));
    }
    let code_bytes = b * kv_heads * t_pad * pool.layout().sum_row_bytes(head_dim);
    let scale_len = n_layers * b * kv_heads * t_pad;
    let mut k_out = vec![0u8; code_bytes];
    let mut v_out = vec![0u8; code_bytes];
    let mut ks = vec![0f32; scale_len];
    let mut vs = vec![0f32; scale_len];

    let scalar_s = median_secs(4, 9, || {
        pool.gather_batch_scalar(&handles, t_pad, &mut k_out, &mut ks, &mut v_out, &mut vs)
            .unwrap();
        black_box(&k_out);
    });
    let vector_s = median_secs(4, 9, || {
        black_box(
            pool.gather_batch(&handles, t_pad, &mut k_out, &mut ks, &mut v_out, &mut vs)
                .unwrap(),
        );
    });
    rows.push(HotRow {
        metric: "gather_planned",
        scalar_s,
        vector_s,
        unit: "B=4 T=256 L=12 batch",
    });
}

fn bench_accounting(rows: &mut Vec<HotRow>) {
    const THREADS: usize = 4;
    const RECORDS: usize = 5_000;

    // Old design: every completion on every replica takes one fleet-wide
    // mutex around the collector.
    let scalar_s = median_secs(1, 5, || {
        let fleet = Arc::new(Mutex::new(MetricsCollector::new()));
        let workers: Vec<_> = (0..THREADS)
            .map(|ti| {
                let f = Arc::clone(&fleet);
                std::thread::spawn(move || {
                    for i in 0..RECORDS {
                        let lat = 1e-6 * (ti * RECORDS + i) as f64;
                        f.lock().unwrap().record(lat, lat / 2.0, lat, 32, 8);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        black_box(fleet.lock().unwrap().count());
    });

    // New design: one wait-free recorder per replica; the probe-time
    // merge is charged to this side too — it is the work we moved off
    // the completion path, not work that disappeared.
    let vector_s = median_secs(1, 5, || {
        let recorders: Vec<_> = (0..THREADS)
            .map(|_| Arc::new(ReplicaRecorder::new()))
            .collect();
        let workers: Vec<_> = recorders
            .iter()
            .enumerate()
            .map(|(ti, r)| {
                let r = Arc::clone(r);
                std::thread::spawn(move || {
                    for i in 0..RECORDS {
                        let lat = 1e-6 * (ti * RECORDS + i) as f64;
                        r.record(lat, lat / 2.0, lat, 32, 8);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let (m, exact, _) = accounting::collect(&recorders);
        black_box((m.count(), exact));
    });

    rows.push(HotRow {
        metric: "fleet_accounting",
        scalar_s,
        vector_s,
        unit: "4 threads × 5k records",
    });
}

fn bench_trace_off(rows: &mut Vec<HotRow>) {
    use crate::trace::{EventKind, TraceEvent, TraceRecorder};
    const EMITS: usize = 4096;

    // Baseline: the decode-iteration bookkeeping with no trace plumbing
    // at all — per-rung byte accumulation, the work `step_decode` does
    // around every would-be emit site.
    let scalar_s = median_secs(64, 9, || {
        let mut stats = [0u64; 3];
        for i in 0..EMITS {
            let by = [i as u64, (i * 3) as u64, (i * 7) as u64];
            for (a, b) in stats.iter_mut().zip(&by) {
                *a += *b;
            }
        }
        black_box(stats);
    });

    // Recorder-off path: identical work plus the engine's actual guard —
    // one `Option` branch per would-be event (`Engine::emit` with
    // `cfg.trace = false`). The hotpath gate holds this ≥ 0.98× baseline:
    // tracing must be free when it is off.
    let trace: Option<Arc<TraceRecorder>> = black_box(None);
    let vector_s = median_secs(64, 9, || {
        let mut stats = [0u64; 3];
        for i in 0..EMITS {
            let by = [i as u64, (i * 3) as u64, (i * 7) as u64];
            for (a, b) in stats.iter_mut().zip(&by) {
                *a += *b;
            }
            if let Some(t) = &trace {
                t.record(&TraceEvent {
                    sim_time_s: i as f64 * 1e-6,
                    kind: EventKind::DecodeIter {
                        batch: 4,
                        padded_slots: 0,
                        t_pad: 256,
                        generated: 4,
                        gather_by_rung: by,
                        dur_s: 1e-6,
                    },
                });
            }
        }
        black_box(stats);
    });

    rows.push(HotRow {
        metric: "trace_off_guard",
        scalar_s,
        vector_s,
        unit: "4096 guarded emits",
    });
}

pub fn fig_hotpath() -> Table {
    let mut t = Table::new(
        "bench hotpath — vectorized codecs, planned KV gather, lock-free accounting (vs retained references)",
        &["metric", "scalar µs", "vectorized µs", "ratio", "per"],
    );
    let mut rows = Vec::new();
    bench_codecs(&mut rows);
    bench_gather(&mut rows);
    bench_accounting(&mut rows);
    bench_trace_off(&mut rows);

    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.metric.into(),
            format!("{:.3}", r.scalar_s * 1e6),
            format!("{:.3}", r.vector_s * 1e6),
            format!("{:.2}", r.ratio()),
            r.unit.into(),
        ]);
        json_rows.push(obj([
            ("bench", Json::from("hotpath")),
            ("metric", Json::from(r.metric)),
            ("value", Json::from(r.vector_s * 1e6)),
            ("unit", Json::from("us_per_call")),
            ("ratio_vs_scalar", Json::from(r.ratio())),
            ("scalar_us", Json::from(r.scalar_s * 1e6)),
            ("per", Json::from(r.unit)),
        ]));
    }
    let doc = obj([
        ("bench", Json::from("hotpath")),
        (
            "workload",
            Json::from("4096-element codec rows; B=4 T=256 L=12 mixed-layout gather; 4×5k-record fleet"),
        ),
        ("rows", arr(json_rows)),
    ]);
    // Repo root, independent of the invoking cwd. Best-effort: a read-only
    // checkout must not fail the bench itself.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    if let Err(e) = std::fs::write(path, doc.dump() + "\n") {
        eprintln!("bench hotpath: could not write {path}: {e}");
    }
    if std::env::var("BENCH_ASSERT").as_deref() == Ok("1") {
        assert_hotpath_table(&t);
        eprintln!("bench hotpath: BENCH_ASSERT checks passed");
    }
    t.note("repo extension (DESIGN.md §11): every vectorized path is property-tested bit-identical to the scalar column it replaces; BENCH_ASSERT=1 additionally requires int4_unpack and gather_planned ≥ 1.5× and trace_off_guard ≥ 0.98× in release builds; rows mirrored to BENCH_hotpath.json");
    t
}

/// The `bench hotpath` acceptance checks (CI runs these via
/// `BENCH_ASSERT=1`, release profile only): the two headline rewrites —
/// the word-level INT4 decode and the planned gather — must beat their
/// scalar references by at least 1.5×, and the flight recorder's
/// disabled path must stay within noise of the recorder-free baseline
/// (≥ 0.98×, DESIGN.md §12). The remaining rows are reported as
/// trajectory, not gated: their win depends on workload shape.
pub fn assert_hotpath_table(t: &Table) {
    let col = |name: &str| t.headers.iter().position(|h| h == name).unwrap();
    let (metric_c, ratio_c) = (col("metric"), col("ratio"));
    let ratio_of = |metric: &str| -> f64 {
        t.rows
            .iter()
            .find(|r| r[metric_c] == metric)
            .unwrap_or_else(|| panic!("{metric} row missing"))[ratio_c]
            .parse()
            .unwrap()
    };
    for gated in ["int4_unpack", "gather_planned"] {
        let ratio = ratio_of(gated);
        assert!(
            ratio >= 1.5,
            "{gated}: vectorized path only {ratio:.2}× scalar (need ≥ 1.5×)"
        );
    }
    let ratio = ratio_of("trace_off_guard");
    assert!(
        ratio >= 0.98,
        "trace_off_guard: events-off path is {ratio:.3}× baseline (need ≥ 0.98×)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assert_gate_reads_the_table_shape() {
        let mut t = Table::new("fake", &["metric", "scalar µs", "vectorized µs", "ratio", "per"]);
        t.row(vec!["int4_unpack".into(), "3.0".into(), "1.0".into(), "3.00".into(), "row".into()]);
        t.row(vec!["gather_planned".into(), "9.0".into(), "4.0".into(), "2.25".into(), "batch".into()]);
        t.row(vec!["fleet_accounting".into(), "2.0".into(), "1.9".into(), "1.05".into(), "run".into()]);
        t.row(vec!["trace_off_guard".into(), "1.0".into(), "1.0".into(), "0.99".into(), "emits".into()]);
        assert_hotpath_table(&t); // ungated rows may be < 1.5×
    }

    #[test]
    #[should_panic(expected = "need ≥ 0.98×")]
    fn assert_gate_rejects_a_costly_disabled_recorder() {
        let mut t = Table::new("fake", &["metric", "scalar µs", "vectorized µs", "ratio", "per"]);
        t.row(vec!["int4_unpack".into(), "3.0".into(), "1.0".into(), "3.00".into(), "row".into()]);
        t.row(vec!["gather_planned".into(), "9.0".into(), "4.0".into(), "2.25".into(), "batch".into()]);
        t.row(vec!["trace_off_guard".into(), "1.0".into(), "1.2".into(), "0.83".into(), "emits".into()]);
        assert_hotpath_table(&t);
    }

    #[test]
    #[should_panic(expected = "need ≥ 1.5×")]
    fn assert_gate_rejects_a_regressed_headline_row() {
        let mut t = Table::new("fake", &["metric", "scalar µs", "vectorized µs", "ratio", "per"]);
        t.row(vec!["int4_unpack".into(), "1.0".into(), "1.0".into(), "1.00".into(), "row".into()]);
        t.row(vec!["gather_planned".into(), "9.0".into(), "4.0".into(), "2.25".into(), "batch".into()]);
        t.row(vec!["trace_off_guard".into(), "1.0".into(), "1.0".into(), "0.99".into(), "emits".into()]);
        assert_hotpath_table(&t);
    }

    #[test]
    fn median_is_robust_to_one_outlier_sample() {
        let mut calls = 0usize;
        let s = median_secs(1, 5, || calls += 1);
        assert_eq!(calls, 6, "warmup + reps×iters");
        assert!(s >= 0.0);
    }
}

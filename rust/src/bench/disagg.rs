//! `bench disagg` — monolithic vs disaggregated prefill/decode serving
//! on the real engine (sim backend, hermetic; DESIGN.md §13).
//!
//! One shared-prefix chat workload, four deployments: {homogeneous,
//! heterogeneous} × {monolithic fleet, disaggregated tiers}, every
//! deployment two engines wide per tier. `max_batch` is deliberately
//! binding (4 slots for 6 requests per replica) because that is where
//! disaggregation bites: a monolithic slot stays occupied for the whole
//! generation, while a prefill-tier slot frees as soon as the first
//! token is sampled — so queued prompts start sooner and tail TTFT
//! drops. The decode tiers import the prefill tier's KV as layout-tagged
//! snapshots (transcoded kv16 → kv8/kv4 in the heterogeneous fleet),
//! with migration traffic priced on the PCIe model.
//!
//! Acceptance (unit test below, and `BENCH_ASSERT=1` in CI): every row
//! completes all requests (zero lost), both disagg rows migrate every
//! request with nonzero KV bytes, and the disaggregated heterogeneous
//! fleet's modeled p95 TTFT is no worse than its monolithic
//! counterpart's. Rows are mirrored to `BENCH_disagg.json`.

use super::table::Table;
use crate::cluster::{run_disagg, run_fleet, ClusterConfig, DisaggConfig, ReplicaSpec, RouterPolicy};
use crate::config::{EngineConfig, PreemptionMode};
use crate::coordinator::Request;
use crate::util::json::{arr, obj, Json};
use crate::workload::SharedPrefixGen;

fn specs(ss: &[&str]) -> Vec<ReplicaSpec> {
    ss.iter().map(|s| s.parse().expect("bench replica spec")).collect()
}

/// One measured deployment row, however it was served.
struct Row {
    fleet: &'static str,
    mode: &'static str,
    completed: usize,
    total: usize,
    /// `None` for monolithic rows (nothing crosses replicas).
    migrated: Option<(usize, usize, usize)>, // (with KV, recompute, bytes)
    ttft_p95_s: f64,
    tpot_p50_s: f64,
    tok_s: f64,
}

pub fn fig_disagg() -> Table {
    let mut t = Table::new(
        "bench disagg — monolithic vs disaggregated prefill/decode (engine, 4-slot batches)",
        &["fleet", "mode", "completed", "migrated", "recompute", "KV bytes",
          "TTFT p95(ms)", "TPOT p50(ms)", "tok/s (model)"],
    );
    // Lossless preemption so any transient pressure is absorbed, and a
    // binding batch so queued prompts actually wait on slots.
    let base = EngineConfig {
        max_batch: 4,
        kv_pool_tokens: 16 * 64,
        prefill_chunk: 32,
        enable_prefix_cache: true,
        preemption_mode: PreemptionMode::Recompute,
        ..EngineConfig::default()
    };
    // Two-turn chat over a 64-token shared system prompt: the tail TTFT
    // story needs multi-request queues, the prefix cache keeps the
    // prefill tier honest about reuse.
    let gen = SharedPrefixGen {
        shared_tokens: 64,
        users: 6,
        turns: 2,
        turn_tokens: 12,
        gen_tokens: 10,
        rate: 8.0,
        seed: 0xD15A,
    };
    let vocab = 2048;
    let reqs: Vec<Request> = gen
        .generate()
        .iter()
        .enumerate()
        .map(|(i, r)| Request::new(gen.prompt_tokens(i, vocab), r.gen_tokens))
        .collect();
    let policy = RouterPolicy::RoundRobin;

    // Homogeneous: every engine at the base format's kv8. Heterogeneous:
    // the second decode engine holds kv4 (layout override, same W/A
    // format), and the disagg prefill tier admits wide at kv16 so the
    // migration transcodes downward into both decode layouts.
    let mono_homog = specs(&["w4a16,kv8,a100", "w4a16,kv8,a100"]);
    let mono_hetero = specs(&["w4a16,kv8,a100", "w4a16,kv8,h100,layout=kv4"]);
    let pre_homog = specs(&["w4a16,kv8,a100", "w4a16,kv8,a100"]);
    let dec_homog = specs(&["w4a16,kv8,a100", "w4a16,kv8,a100"]);
    let pre_hetero = specs(&["w4a16,kv8,a100,layout=kv16", "w4a16,kv8,a100,layout=kv16"]);
    let dec_hetero = specs(&["w4a16,kv8,a100", "w4a16,kv8,h100,layout=kv4"]);

    let mut rows: Vec<Row> = Vec::new();
    for (fleet, mono, pre, dec) in [
        ("homog", &mono_homog, &pre_homog, &dec_homog),
        ("hetero", &mono_hetero, &pre_hetero, &dec_hetero),
    ] {
        let cfg = ClusterConfig::heterogeneous(base.clone(), mono.clone(), policy);
        let run = run_fleet(&cfg, &reqs).expect("hermetic monolithic run");
        let sim = run.sim_metrics();
        rows.push(Row {
            fleet,
            mode: "monolithic",
            completed: run.completed(),
            total: reqs.len(),
            migrated: None,
            ttft_p95_s: sim.ttft_percentiles().map(|p| p.p95).unwrap_or(0.0),
            tpot_p50_s: sim.tpot_percentiles().map(|p| p.p50).unwrap_or(0.0),
            tok_s: run.sim_token_throughput(),
        });

        let dcfg = DisaggConfig::new(base.clone(), pre.clone(), dec.clone(), policy);
        let run = run_disagg(&dcfg, &reqs).expect("hermetic disagg run");
        let sim = run.sim_metrics();
        rows.push(Row {
            fleet,
            mode: "disagg",
            completed: run.completed(),
            total: reqs.len(),
            migrated: Some((run.migrated, run.recompute_migrations, run.migrated_bytes)),
            ttft_p95_s: sim.ttft_percentiles().map(|p| p.p95).unwrap_or(0.0),
            tpot_p50_s: sim.tpot_percentiles().map(|p| p.p50).unwrap_or(0.0),
            tok_s: run.sim_token_throughput(),
        });
    }

    let mut json_rows: Vec<Json> = Vec::new();
    for r in &rows {
        let (mig, rec, bytes) = r.migrated.unwrap_or((0, 0, 0));
        t.row(vec![
            r.fleet.into(),
            r.mode.into(),
            format!("{}/{}", r.completed, r.total),
            if r.migrated.is_some() { mig.to_string() } else { "-".into() },
            if r.migrated.is_some() { rec.to_string() } else { "-".into() },
            if r.migrated.is_some() { bytes.to_string() } else { "-".into() },
            format!("{:.3}", r.ttft_p95_s * 1e3),
            format!("{:.3}", r.tpot_p50_s * 1e3),
            format!("{:.0}", r.tok_s),
        ]);
        for (metric, value, unit) in [
            ("completed", Json::from(r.completed), "requests"),
            ("total", Json::from(r.total), "requests"),
            ("migrated", Json::from(mig), "requests"),
            ("recompute_migrations", Json::from(rec), "requests"),
            ("migrated_bytes", Json::from(bytes), "bytes"),
            ("ttft_p95_s", Json::from(r.ttft_p95_s), "s"),
            ("tpot_p50_s", Json::from(r.tpot_p50_s), "s"),
            ("throughput_tok_s", Json::from(r.tok_s), "tok/s"),
        ] {
            json_rows.push(obj([
                ("bench", Json::from("disagg")),
                ("metric", Json::from(metric)),
                ("value", value),
                ("unit", Json::from(unit)),
                ("fleet", Json::from(r.fleet)),
                ("mode", Json::from(r.mode)),
            ]));
        }
    }
    let doc = obj([
        ("bench", Json::from("disagg")),
        ("workload", Json::from("SharedPrefixGen 6 users × 2 turns, 64-token shared prefix, 10 gen")),
        ("rows", arr(json_rows)),
    ]);
    // Repo root, independent of the invoking cwd. Best-effort: a
    // read-only checkout must not fail the bench itself.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_disagg.json");
    if let Err(e) = std::fs::write(path, doc.dump() + "\n") {
        eprintln!("bench disagg: could not write {path}: {e}");
    }
    if std::env::var("BENCH_ASSERT").as_deref() == Ok("1") {
        assert_disagg_table(&t);
        eprintln!("bench disagg: BENCH_ASSERT checks passed");
    }
    t.note("repo extension: disaggregated prefill/decode with layout-tagged cross-replica KV migration (DESIGN.md §13); every row completes 12/12, both disagg rows migrate all requests with KV, and disagg-hetero p95 TTFT ≤ its monolithic counterpart — asserted by bench::disagg tests (and at runtime with BENCH_ASSERT=1); rows mirrored to BENCH_disagg.json");
    t
}

/// The `bench disagg` acceptance checks, shared by the unit test and the
/// generator's `BENCH_ASSERT=1` CI mode.
pub fn assert_disagg_table(t: &Table) {
    assert_eq!(t.rows.len(), 4, "2 fleets × 2 modes");
    let col = |name: &str| t.headers.iter().position(|h| h == name).unwrap();
    let (fleet_c, mode_c) = (col("fleet"), col("mode"));
    let (done_c, mig_c, ttft_c) = (col("completed"), col("migrated"), col("TTFT p95(ms)"));
    for row in &t.rows {
        let (served, total) = row[done_c].split_once('/').unwrap();
        assert_eq!(served, total, "row lost requests: {row:?}");
    }
    let get = |fleet: &str, mode: &str| {
        t.rows
            .iter()
            .find(|r| r[fleet_c] == fleet && r[mode_c] == mode)
            .unwrap_or_else(|| panic!("{fleet}/{mode} row missing"))
    };
    for fleet in ["homog", "hetero"] {
        let d = get(fleet, "disagg");
        let total = d[done_c].split_once('/').unwrap().1;
        assert_eq!(d[mig_c], total, "{fleet}: every request must migrate with KV");
        let (dt, mt) = (
            d[ttft_c].parse::<f64>().unwrap(),
            get(fleet, "monolithic")[ttft_c].parse::<f64>().unwrap(),
        );
        // The structural claim: freeing a prefill slot at the first
        // token (instead of at the last) cannot make queued prompts
        // start later.
        assert!(
            dt <= mt + 1e-9,
            "{fleet}: disagg p95 TTFT {dt}ms worse than monolithic {mt}ms"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disagg_bench_invariants() {
        assert_disagg_table(&fig_disagg());
    }
}

//! Kernel-level paper exhibits: Figs 11, 12, 13, 26 and Table 2.
//!
//! Each function regenerates one exhibit from the gpusim kernel models at
//! the paper's configuration (Qwen3-8B AWQ, W4A16KV8, A100 unless the
//! exhibit says otherwise) and prints the same rows/series the paper
//! reports, with the paper's reference numbers in the footnotes.

use super::table::{ms, pct_improvement, Table};
use crate::config::model::find_model;
use crate::config::DeviceProfile;
use crate::gpusim::{
    AttentionKernelModel, AttnWorkload, Framework, GemmKernelModel, GemmWorkload, PipelineSim,
};

/// Sum of one layer's projection GEMM times for the given m.
fn layer_gemm_time(dev: &DeviceProfile, fw: Framework, model: &str, m: usize, w_bits: usize) -> f64 {
    let cfg = find_model(model).unwrap();
    let tr = fw.traits_on(dev);
    let g = GemmKernelModel::new(dev, &tr);
    cfg.layer_gemms()
        .iter()
        .map(|&(_, k, n)| {
            g.run(&GemmWorkload { m, k, n, w_bits, a_bits: 16, group_size: 128 }).time_s
        })
        .sum()
}

fn attn_time(
    dev: &DeviceProfile,
    fw: Framework,
    model: &str,
    batch: usize,
    q_tokens: usize,
    kv_len: usize,
    kv_bits: usize,
) -> f64 {
    let cfg = find_model(model).unwrap();
    let tr = fw.traits_on(dev);
    AttentionKernelModel::new(dev, &tr)
        .run(&AttnWorkload {
            batch,
            q_tokens,
            kv_len,
            n_heads: cfg.n_heads,
            n_kv_heads: cfg.n_kv_heads,
            head_dim: cfg.head_dim,
            kv_bits,
        })
        .time_s
}

/// Fig 11: prefill/decoding attention + GEMM kernel latency within a single
/// request (Qwen3-8B AWQ, 8-bit KV, vs vLLM+MARLIN, A100).
pub fn fig11() -> Table {
    let dev = DeviceProfile::a100();
    let model = "qwen3-8b";
    let mut t = Table::new(
        "Fig 11 — per-request kernel latency, Qwen3-8B AWQ W4A16KV8 (A100)",
        &["phase", "kernel", "seq_len", "LMDeploy(ms)", "vLLM+MARLIN(ms)", "reduction"],
    );
    for &s in &[1024usize, 2048, 4096, 8192] {
        // Prefill: chunk of s tokens; attention sees the causal chunk.
        let a_tm = attn_time(&dev, Framework::TurboMind, model, 1, s, 0, 8);
        let a_vm = attn_time(&dev, Framework::VllmMarlin, model, 1, s, 0, 8);
        t.row(vec![
            "prefill".into(), "attention".into(), s.to_string(),
            ms(a_tm), ms(a_vm), pct_improvement(a_vm, a_tm),
        ]);
        let g_tm = layer_gemm_time(&dev, Framework::TurboMind, model, s, 4);
        let g_vm = layer_gemm_time(&dev, Framework::VllmMarlin, model, s, 4);
        t.row(vec![
            "prefill".into(), "gemm".into(), s.to_string(),
            ms(g_tm), ms(g_vm), pct_improvement(g_vm, g_tm),
        ]);
    }
    for &s in &[1024usize, 2048, 4096, 8192] {
        // Decode: one token attending a history of s.
        let a_tm = attn_time(&dev, Framework::TurboMind, model, 1, 1, s, 8);
        let a_vm = attn_time(&dev, Framework::VllmMarlin, model, 1, 1, s, 8);
        t.row(vec![
            "decode".into(), "attention".into(), s.to_string(),
            ms(a_tm), ms(a_vm), pct_improvement(a_vm, a_tm),
        ]);
        let g_tm = layer_gemm_time(&dev, Framework::TurboMind, model, 1, 4);
        let g_vm = layer_gemm_time(&dev, Framework::VllmMarlin, model, 1, 4);
        t.row(vec![
            "decode".into(), "gemm".into(), s.to_string(),
            ms(g_tm), ms(g_vm), pct_improvement(g_vm, g_tm),
        ]);
    }
    t.note("paper: attention prefill avg -22.1% (max -48.7%); decode avg -7.6% (max -29.9%); GEMM avg -19.2% (max -25.5%)");
    t
}

/// Fig 12: accumulated attention + GEMM kernel latency across batch sizes.
pub fn fig12() -> Table {
    let dev = DeviceProfile::a100();
    let model = "qwen3-8b";
    let cfg = find_model(model).unwrap();
    let mut t = Table::new(
        "Fig 12 — accumulated kernel latency per decode step vs batch (Qwen3-8B AWQ W4A16KV8, A100)",
        &["batch", "LMDeploy(ms)", "vLLM+MARLIN(ms)", "reduction"],
    );
    for &b in &[1usize, 2, 4, 8, 16, 32, 64] {
        let total = |fw: Framework| {
            (attn_time(&dev, fw, model, b, 1, 2048, 8)
                + layer_gemm_time(&dev, fw, model, b, 4))
                * cfg.n_layers as f64
        };
        let tm = total(Framework::TurboMind);
        let vm = total(Framework::VllmMarlin);
        t.row(vec![b.to_string(), ms(tm), ms(vm), pct_improvement(vm, tm)]);
    }
    t.note("paper: avg -88.5% accumulated latency across batch sizes (max -381.5% i.e. 4.8x)");
    t
}

/// Fig 13: INT4×FP16 vs FP16×FP16 GEMM across batch sizes (A100,
/// 8192×8192 projection — the crossover exhibit).
pub fn fig13() -> Table {
    let dev = DeviceProfile::a100();
    let tm = Framework::TurboMind.traits_on(&dev);
    let ml = Framework::VllmMarlin.traits_on(&dev);
    let g_tm = GemmKernelModel::new(&dev, &tm);
    let g_ml = GemmKernelModel::new(&dev, &ml);
    let (k, n) = (8192usize, 8192usize);
    let mut t = Table::new(
        "Fig 13 — INT4xFP16 vs FP16xFP16 GEMM (A100, 8192x8192)",
        &["batch", "int4(ms)", "f16(ms)", "int4_speedup", "marlin_int4(ms)"],
    );
    for &m in &[1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
        let t4 = g_tm.run(&GemmWorkload::w4a16(m, k, n)).time_s;
        let t16 = g_tm.run(&GemmWorkload::f16(m, k, n)).time_s;
        let tml = g_ml.run(&GemmWorkload::w4a16(m, k, n)).time_s;
        t.row(vec![
            m.to_string(),
            ms(t4),
            ms(t16),
            format!("{:.2}x", t16 / t4),
            ms(tml),
        ]);
    }
    t.note("paper: avg +134% (max +220.3%) speedup at batch 1-16; parity at batch 64; MARLIN degrades up to 20.3% at batch 64");
    t.note("roofline model: the compute/bandwidth crossover lands at batch ~256 for this 8192^2 shape; the paper's earlier crossover reflects sub-peak fp16 baselines at mid batch");
    t
}

/// Table 2: instruction/cycle counts INT4×FP16 vs cuBLAS FP16 at 16384³.
pub fn table2() -> Table {
    let dev = DeviceProfile::a100();
    let tr = Framework::TurboMind.traits_on(&dev);
    let sim = PipelineSim::new(&dev, &tr);
    let int4 = sim.gemm(16384, 16384, 16384, 4);
    let f16 = sim.gemm(16384, 16384, 16384, 16);
    let mut t = Table::new(
        "Table 2 — INT4xFP16 vs FP16xFP16 (cuBLAS proxy) at 16384^3, A100",
        &["metric", "LMDeploy INT4xFP16", "cuBLAS FP16xFP16", "overhead"],
    );
    let oi = int4.total_instrs() as f64 / f16.total_instrs() as f64 - 1.0;
    let oc = int4.cycles as f64 / f16.cycles as f64 - 1.0;
    let ot = int4.runtime_s(&dev) / f16.runtime_s(&dev) - 1.0;
    t.row(vec![
        "instr count".into(),
        int4.total_instrs().to_string(),
        f16.total_instrs().to_string(),
        format!("{:+.2}%", oi * 100.0),
    ]);
    t.row(vec![
        "cycle count".into(),
        int4.cycles.to_string(),
        f16.cycles.to_string(),
        format!("{:+.2}%", oc * 100.0),
    ]);
    t.row(vec![
        "runtime (ms)".into(),
        ms(int4.runtime_s(&dev)),
        ms(f16.runtime_s(&dev)),
        format!("{:+.2}%", ot * 100.0),
    ]);
    t.note("paper: +64.66% instructions, +2.89% cycles, +2.45% runtime (30.28 vs 29.55 ms)");
    t
}

/// Fig 26 (Appendix G): attention kernel memory bandwidth utilization.
pub fn fig26() -> Table {
    let model = "qwen3-8b";
    let cfg = find_model(model).unwrap();
    let mut t = Table::new(
        "Fig 26 — attention kernel HBM bandwidth utilization (LMDeploy)",
        &["gpu", "kv_bits", "batch", "bw_utilization"],
    );
    for dev in [DeviceProfile::a100(), DeviceProfile::h100()] {
        let tr = Framework::TurboMind.traits_on(&dev);
        let m = AttentionKernelModel::new(&dev, &tr);
        for kv_bits in [16usize, 8] {
            for &b in &[1usize, 4, 16, 64] {
                let r = m.run(&AttnWorkload {
                    batch: b,
                    q_tokens: 1,
                    kv_len: 4096,
                    n_heads: cfg.n_heads,
                    n_kv_heads: cfg.n_kv_heads,
                    head_dim: cfg.head_dim,
                    kv_bits,
                });
                t.row(vec![
                    dev.name.into(),
                    kv_bits.to_string(),
                    b.to_string(),
                    format!("{:.1}%", r.bw_utilization * 100.0),
                ]);
            }
        }
    }
    t.note("paper: up to 91/95% (16-bit KV) and 86/93% (8-bit KV) on the two GPUs");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, name: &str) -> usize {
        t.headers.iter().position(|h| h == name).unwrap()
    }

    #[test]
    fn fig11_lmdeploy_wins_every_row() {
        let t = fig11();
        let c = col(&t, "reduction");
        for row in &t.rows {
            assert!(row[c].starts_with('+'), "row {row:?}");
        }
    }

    #[test]
    fn fig13_crossover_shape() {
        let t = fig13();
        let c = col(&t, "int4_speedup");
        let speedup = |i: usize| t.rows[i][c].trim_end_matches('x').parse::<f64>().unwrap();
        // Small batch: >1.5x; monotonically approaching parity by B=128.
        assert!(speedup(0) > 1.5, "B=1 speedup {}", speedup(0));
        let last = speedup(t.rows.len() - 1);
        assert!((0.85..=1.2).contains(&last), "B=512 ratio {last}");
        assert!(speedup(0) > last);
    }

    #[test]
    fn table2_matches_paper_band() {
        let t = table2();
        let c = col(&t, "overhead");
        let parse = |s: &str| s.trim_start_matches('+').trim_end_matches('%').parse::<f64>().unwrap();
        let instr = parse(&t.rows[0][c]);
        let cycles = parse(&t.rows[1][c]);
        assert!((40.0..90.0).contains(&instr), "instr {instr} (paper 64.66)");
        assert!((0.0..10.0).contains(&cycles), "cycles {cycles} (paper 2.89)");
    }

    #[test]
    fn fig26_utilization_band() {
        let t = fig26();
        let c = col(&t, "bw_utilization");
        let best: f64 = t
            .rows
            .iter()
            .map(|r| r[c].trim_end_matches('%').parse::<f64>().unwrap())
            .fold(0.0, f64::max);
        assert!((80.0..=96.0).contains(&best), "best util {best} (paper up to 95%)");
    }

    #[test]
    fn fig12_scales_with_batch() {
        let t = fig12();
        let c = col(&t, "LMDeploy(ms)");
        let first: f64 = t.rows[0][c].parse().unwrap();
        let last: f64 = t.rows.last().unwrap()[c].parse().unwrap();
        assert!(last > first);
    }
}

//! Bench harness: one generator per paper table/figure (DESIGN.md §3).
//!
//! Each generator returns a [`table::Table`] with the same rows/series the
//! paper reports, plus the paper's reference numbers as footnotes. The
//! `cargo bench` binaries and the `turbomind bench` CLI subcommand both
//! dispatch through [`registry`].

pub mod disagg;
pub mod hotpath;
pub mod persist;
pub mod kernel_figures;
pub mod serving_figures;
pub mod table;

pub use table::Table;

/// All figure/table generators by paper exhibit id.
pub fn registry() -> Vec<(&'static str, fn() -> Table)> {
    vec![
        ("fig11", kernel_figures::fig11 as fn() -> Table),
        ("fig12", kernel_figures::fig12),
        ("fig13", kernel_figures::fig13),
        ("table2", kernel_figures::table2),
        ("fig26", kernel_figures::fig26),
        ("fig14", serving_figures::fig14),
        ("fig15", serving_figures::fig15),
        ("fig16", serving_figures::fig16),
        ("fig17", serving_figures::fig17),
        ("fig18", serving_figures::fig18),
        ("fig19", serving_figures::fig19),
        ("fig20", serving_figures::fig20),
        ("fig21", serving_figures::fig21),
        ("fig27", serving_figures::fig27),
        ("fig28", serving_figures::fig28),
        ("prefix_cache", serving_figures::fig_prefix),
        ("preempt", serving_figures::fig_preempt),
        ("router", serving_figures::fig_router),
        ("ladder", serving_figures::fig_ladder),
        ("disagg", disagg::fig_disagg),
        ("hotpath", hotpath::fig_hotpath),
        ("persist", persist::fig_persist),
    ]
}

/// Run one generator by name.
pub fn run(name: &str) -> Option<Table> {
    registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f())
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_covers_all_gpusim_exhibits() {
        let names: Vec<_> = super::registry().iter().map(|(n, _)| *n).collect();
        for f in ["fig11", "fig12", "fig13", "fig14", "fig15", "fig16", "fig17",
                  "fig18", "fig19", "fig20", "fig21", "fig26", "fig27", "fig28",
                  "table2"] {
            assert!(names.contains(&f), "{f} missing");
        }
    }

    #[test]
    fn run_unknown_is_none() {
        assert!(super::run("fig99").is_none());
    }
}

//! Disaggregated prefill/decode serving with layout-tagged KV migration
//! (DESIGN.md §13).
//!
//! The monolithic fleet ([`super::run_fleet`]) interleaves prefill and
//! decode on every replica, so compute-bound prompt chunks steal
//! iterations from latency-sensitive decode steps. This module splits the
//! fleet into two tiers instead:
//!
//! * a **prefill tier** runs each prompt to its *first* sampled token
//!   ([`Engine::submit_prefill_only`]) and exports the sequence's KV as a
//!   byte-exact, layout-tagged [`SeqSnapshot`];
//! * a **decode tier** imports that snapshot — transcoded host-side to
//!   the destination replica's per-layer layout — and continues the
//!   generation ([`Engine::submit_migrated`]).
//!
//! Routing is two-stage. Prefill placement uses the ordinary router
//! policies (round-robin / least-loaded / prefix-affinity — affinity
//! matters *here*, where the prompt blocks live). Decode placement runs
//! at migration time over the replicas whose layout the snapshot can
//! reach by a downward transcode, minimizing `(outstanding tokens,
//! modeled import bytes)` — so among equally loaded replicas the cheapest
//! wire format wins. When no decode layout is reachable (the prefill tier
//! admitted at a *narrower* rung than some decode pool) the request
//! migrates without KV and re-prefills at the destination, which is
//! slower but bit-identical.
//!
//! Migration cost rides the existing PCIe model, one hop per end: the
//! prefill engine charges `transfer_time_s(source-layout bytes)` at
//! export (`MigrateOut`), the decode engine charges the target-layout
//! bytes at import (`MigrateIn`); the host-side transcode between hops is
//! treated as free. Composed end-to-end modeled latency is therefore
//! `prefill.latency_sim + out-hop + decode.latency_sim` (the in-hop is
//! already inside the decode engine's clock).
//!
//! **Determinism contract.** Sampling is greedy and the KV codecs are
//! bit-exact, so a request prefillled at one KV layout and decoded at
//! another produces exactly the tokens of a monolithic run at the
//! *decode* layout, provided both tiers serve the same weight/activation
//! format. The randomized harness (`tests/integration_disagg.rs`)
//! asserts this token-for-token.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::replica::{request_cost, ReplicaSpec};
use super::router::{argmin_by, LoadView, Router, RouterPolicy};
use super::stats::{merge_telemetry, ReplicaSnapshot};
use crate::config::EngineConfig;
use crate::coordinator::{Engine, FinishReason, Request, RequestOutput};
use crate::kvcache::SwapBackend;
use crate::kvcache::swap::{snapshot_bytes, transfer_time_s};
use crate::kvcache::{KvLayout, SeqSnapshot};
use crate::metrics::MetricsCollector;
use crate::trace::TraceDump;

/// Configuration of a disaggregated deployment: one base engine config
/// both tiers inherit, plus per-tier replica specs.
#[derive(Debug, Clone)]
pub struct DisaggConfig {
    pub base: EngineConfig,
    /// Prefill-tier replicas (prompt processing + first token).
    pub prefill_specs: Vec<ReplicaSpec>,
    /// Decode-tier replicas (token generation on imported KV).
    pub decode_specs: Vec<ReplicaSpec>,
    /// Prefill placement policy (decode placement is always the
    /// load/bytes argmin described in the module docs).
    pub policy: RouterPolicy,
    /// Prompt blocks the `prefix_affinity` hash covers.
    pub affinity_blocks: usize,
}

impl DisaggConfig {
    pub fn new(
        base: EngineConfig,
        prefill_specs: Vec<ReplicaSpec>,
        decode_specs: Vec<ReplicaSpec>,
        policy: RouterPolicy,
    ) -> Self {
        Self { base, prefill_specs, decode_specs, policy, affinity_blocks: 4 }
    }

    pub fn validate(&self) -> Result<()> {
        if self.prefill_specs.is_empty() {
            bail!("disaggregated fleet needs at least one prefill replica");
        }
        if self.decode_specs.is_empty() {
            bail!("disaggregated fleet needs at least one decode replica");
        }
        if self.affinity_blocks == 0 {
            bail!("affinity_blocks must be > 0");
        }
        for (i, s) in self.prefill_specs.iter().enumerate() {
            s.engine_config(&self.base)
                .validate()
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("prefill replica {i} config"))?;
        }
        for (i, s) in self.decode_specs.iter().enumerate() {
            s.engine_config(&self.base)
                .validate()
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("decode replica {i} config"))?;
        }
        Ok(())
    }
}

/// One request's end-to-end outcome through the two tiers.
#[derive(Debug, Clone)]
pub struct DisaggOutput {
    /// Index into the submitted request slice.
    pub request: usize,
    /// Prefill-tier replica that ran the prompt.
    pub prefill_replica: usize,
    /// Decode-tier replica that finished the generation; `None` when the
    /// request terminated at the prefill tier (aborted, stop token on the
    /// first sample, or a 1-token budget).
    pub decode_replica: Option<usize>,
    /// KV bytes imported at the decode layout (0 on a recompute
    /// migration, i.e. no reachable decode layout).
    pub migrated_bytes: usize,
    /// Modeled out-hop transfer time charged between the tiers.
    pub migrate_s: f64,
    /// Composed end-to-end output (tokens from the decode tier; TTFT from
    /// the prefill tier; modeled latency spans both plus the migration).
    pub output: RequestOutput,
}

/// Result of [`run_disagg`].
#[derive(Debug)]
pub struct DisaggRun {
    /// Prefill-tier assignment per request.
    pub prefill_assignments: Vec<usize>,
    pub outputs: Vec<DisaggOutput>,
    pub prefill_snapshots: Vec<ReplicaSnapshot>,
    pub decode_snapshots: Vec<ReplicaSnapshot>,
    /// Requests that crossed tiers with their KV snapshot.
    pub migrated: usize,
    /// Requests that crossed tiers without KV (re-prefilled at the
    /// destination because no decode layout was transcode-reachable).
    pub recompute_migrations: usize,
    /// Total KV bytes imported by the decode tier.
    pub migrated_bytes: usize,
    /// Per-tier `(label, flight-recorder dump)` — prefill replicas first,
    /// then decode replicas; empty dumps when tracing is off.
    pub traces: Vec<(String, TraceDump)>,
}

impl DisaggRun {
    /// Requests that finished without aborting.
    pub fn completed(&self) -> usize {
        self.outputs.iter().filter(|o| o.output.finish != FinishReason::Aborted).count()
    }

    /// Fleet telemetry merged over both tiers.
    pub fn fleet_telemetry(&self) -> crate::metrics::TelemetrySummary {
        merge_telemetry(self.prefill_snapshots.iter().chain(&self.decode_snapshots))
    }

    /// Per-request modeled completion metrics (successes only, matching
    /// [`super::FleetRun::sim_metrics`]).
    pub fn sim_metrics(&self) -> MetricsCollector {
        let mut m = MetricsCollector::new();
        for o in &self.outputs {
            if o.output.finish == FinishReason::Aborted {
                continue;
            }
            m.record(
                o.output.latency_sim,
                o.output.ttft_sim,
                o.output.latency_sim,
                o.output.prompt_len,
                o.output.tokens.len(),
            );
        }
        m
    }

    /// Chrome-trace tracks over the per-replica dumps — prefill tracks
    /// first, then decode tracks, matching `traces` order.
    pub fn trace_tracks(&self) -> Vec<crate::trace::TraceTrack<'_>> {
        self.traces
            .iter()
            .enumerate()
            .map(|(i, (label, dump))| crate::trace::TraceTrack {
                tid: i,
                label: label.clone(),
                dump,
            })
            .collect()
    }

    /// Modeled fleet makespan: the tiers run as a pipeline with a barrier
    /// in this offline runner, so the bound is slowest-prefill +
    /// slowest-decode.
    pub fn sim_makespan_s(&self) -> f64 {
        let p = self.prefill_snapshots.iter().map(|s| s.stats.sim_time_s).fold(0.0, f64::max);
        let d = self.decode_snapshots.iter().map(|s| s.stats.sim_time_s).fold(0.0, f64::max);
        p + d
    }

    /// Generated tokens per modeled fleet second (both tiers' clocks).
    pub fn sim_token_throughput(&self) -> f64 {
        let toks: usize = self
            .prefill_snapshots
            .iter()
            .chain(&self.decode_snapshots)
            .map(|s| s.stats.tokens_generated)
            .sum();
        let t = self.sim_makespan_s();
        if t > 0.0 {
            toks as f64 / t
        } else {
            0.0
        }
    }
}

/// Modeled wire size of `snap` once transcoded to `target`: the code
/// payload shrinks with the target rungs while the f32 scale rows ride
/// along unchanged. Used for decode placement *before* paying for the
/// transcode itself.
fn modeled_import_bytes(snap: &SeqSnapshot, target: &KvLayout) -> usize {
    snap.len * target.token_code_bytes(snap.kv_heads, snap.head_dim) + snap.scales.len() * 4
}

/// A prefill-tier result waiting for decode placement.
struct PrefillDone {
    request: usize,
    prefill_replica: usize,
    output: RequestOutput,
    snapshot: Option<SeqSnapshot>,
}

/// Deterministic offline disaggregated run, the two-tier analogue of
/// [`super::run_fleet`]: route the whole request set over the prefill
/// tier, drive each prefill engine to completion on this thread, then
/// place every surviving request on a decode replica, ship (and
/// transcode) its snapshot, and drive the decode engines to completion.
/// Same `(config, requests)` → byte-identical outputs.
pub fn run_disagg(cfg: &DisaggConfig, requests: &[Request]) -> Result<DisaggRun> {
    cfg.validate()?;

    // ---- Stage 1: prefill placement (router policy) ----
    let np = cfg.prefill_specs.len();
    let mut router =
        Router::new(cfg.policy, np, cfg.base.kv_block_tokens, cfg.affinity_blocks);
    let mut assigned = vec![LoadView::default(); np];
    let mut prefill_assignments = Vec::with_capacity(requests.len());
    for req in requests {
        let i = router.pick(&req.prompt, &assigned);
        assigned[i].reqs += 1;
        assigned[i].tokens += request_cost(req);
        prefill_assignments.push(i);
    }

    // ---- Stage 2: prefill tier to completion, collecting exports ----
    let mut outputs: Vec<DisaggOutput> = Vec::with_capacity(requests.len());
    let mut pending: Vec<PrefillDone> = Vec::new();
    let mut prefill_snapshots = Vec::with_capacity(np);
    let mut traces = Vec::with_capacity(np + cfg.decode_specs.len());
    for i in 0..np {
        let mut engine = Engine::new(cfg.prefill_specs[i].engine_config(&cfg.base))
            .with_context(|| format!("prefill replica {i}"))?;
        let mine: Vec<usize> =
            (0..requests.len()).filter(|&g| prefill_assignments[g] == i).collect();
        let mut id_to_global = HashMap::new();
        for &g in &mine {
            match engine.submit_prefill_only(requests[g].clone()) {
                Ok(id) => {
                    id_to_global.insert(id, g);
                }
                Err(e) => outputs.push(DisaggOutput {
                    request: g,
                    prefill_replica: i,
                    decode_replica: None,
                    migrated_bytes: 0,
                    migrate_s: 0.0,
                    output: RequestOutput::rejected(e.to_string()),
                }),
            }
        }
        let outs = engine.run_to_completion()?;
        let mut exports: HashMap<u64, SeqSnapshot> =
            engine.take_migration_exports().into_iter().collect();
        for out in outs {
            let g = id_to_global[&out.id];
            let snapshot = exports.remove(&out.id);
            // Terminal at the prefill tier: aborted, stopped on the first
            // sample, or the request only ever wanted one token. The
            // prefill output *is* the final answer (its export, if any,
            // is discarded — the prefill node always ships at finish).
            let done_here = out.finish == FinishReason::Aborted
                || out.finish == FinishReason::Stop
                || requests[g].max_new_tokens <= 1;
            if done_here {
                outputs.push(DisaggOutput {
                    request: g,
                    prefill_replica: i,
                    decode_replica: None,
                    migrated_bytes: 0,
                    migrate_s: 0.0,
                    output: out,
                });
            } else {
                pending.push(PrefillDone { request: g, prefill_replica: i, output: out, snapshot });
            }
        }
        prefill_snapshots.push(ReplicaSnapshot::of(
            i,
            &format!("prefill:{}", cfg.prefill_specs[i].label()),
            &engine,
            mine.len(),
            0,
            0,
        ));
        traces.push((format!("prefill:{}", cfg.prefill_specs[i].label()), engine.trace_dump()));
    }
    // Decode placement must not depend on prefill replica completion
    // order: process migrations in request order.
    pending.sort_by_key(|p| p.request);

    // ---- Stage 3: decode placement + migration ----
    let nd = cfg.decode_specs.len();
    let mut decode_engines = Vec::with_capacity(nd);
    for j in 0..nd {
        decode_engines.push(
            Engine::new(cfg.decode_specs[j].engine_config(&cfg.base))
                .with_context(|| format!("decode replica {j}"))?,
        );
    }
    let decode_layouts: Vec<KvLayout> =
        decode_engines.iter().map(|e| e.kv_pool().layout().clone()).collect();
    let mut decode_assigned = vec![LoadView::default(); nd];
    // Per decode replica: (global request, generated-so-far, transcoded
    // snapshot, out-hop seconds).
    let mut shipments: Vec<Vec<(usize, Vec<i32>, Option<SeqSnapshot>, f64)>> =
        vec![Vec::new(); nd];
    let mut migrated = 0usize;
    let mut recompute_migrations = 0usize;
    let mut migrated_bytes = 0usize;
    let mut prefill_half: HashMap<usize, (usize, RequestOutput)> = HashMap::new();
    for p in pending {
        let reachable: Vec<usize> = (0..nd)
            .filter(|&j| {
                p.snapshot.as_ref().is_some_and(|s| s.layout.can_transcode_to(&decode_layouts[j]))
            })
            .collect();
        let (j, shipped) = if reachable.is_empty() {
            // No decode layout is a downward transcode of the prefill
            // layout (or the prefill tier exported nothing): migrate the
            // tokens alone and re-prefill at the destination.
            let j = argmin_by(&decode_assigned, |l| l.tokens);
            (j, None)
        } else {
            // Load first, wire bytes second: among equally loaded
            // replicas the cheapest import format wins (deterministic,
            // lowest replica index on full ties).
            let k = argmin_by(&reachable, |&j| {
                let snap = p.snapshot.as_ref().expect("reachable implies snapshot");
                (decode_assigned[j].tokens, modeled_import_bytes(snap, &decode_layouts[j]))
            });
            (reachable[k], p.snapshot)
        };
        let cost = request_cost(&requests[p.request]);
        decode_assigned[j].reqs += 1;
        decode_assigned[j].tokens += cost;
        let (snap, out_hop) = match shipped {
            Some(s) => {
                // Out-hop at the *source* layout (what left the prefill
                // device); transcode happens host-side between hops.
                let dt = transfer_time_s(snapshot_bytes(&s));
                let t = s.transcode_to(&decode_layouts[j]).with_context(|| {
                    format!("transcoding request {} for decode replica {j}", p.request)
                })?;
                migrated += 1;
                migrated_bytes += snapshot_bytes(&t);
                (Some(t), dt)
            }
            None => {
                recompute_migrations += 1;
                (None, 0.0)
            }
        };
        shipments[j].push((p.request, p.output.tokens.clone(), snap, out_hop));
        prefill_half.insert(p.request, (p.prefill_replica, p.output));
    }

    // ---- Stage 4: decode tier to completion, composing outputs ----
    let mut decode_snapshots = Vec::with_capacity(nd);
    for (j, mut engine) in decode_engines.into_iter().enumerate() {
        let mut id_to_global = HashMap::new();
        let mut hops = HashMap::new();
        let n_mine = shipments[j].len();
        for (g, generated, snap, out_hop) in shipments[j].drain(..) {
            let imported = snap.as_ref().map(snapshot_bytes).unwrap_or(0);
            match engine.submit_migrated(requests[g].clone(), generated, snap) {
                Ok(id) => {
                    id_to_global.insert(id, g);
                    hops.insert(g, (out_hop, imported));
                }
                Err(e) => {
                    let (pr, _) = prefill_half.remove(&g).expect("prefill half recorded");
                    outputs.push(DisaggOutput {
                        request: g,
                        prefill_replica: pr,
                        decode_replica: Some(j),
                        migrated_bytes: 0,
                        migrate_s: 0.0,
                        output: RequestOutput::rejected(e.to_string()),
                    });
                }
            }
        }
        for out in engine.run_to_completion()? {
            let g = id_to_global[&out.id];
            let (pr, phalf) = prefill_half.remove(&g).expect("prefill half recorded");
            let (out_hop, imported) = hops[&g];
            outputs.push(DisaggOutput {
                request: g,
                prefill_replica: pr,
                decode_replica: Some(j),
                migrated_bytes: imported,
                migrate_s: out_hop,
                output: compose_output(&phalf, out, out_hop),
            });
        }
        decode_snapshots.push(ReplicaSnapshot::of(
            j,
            &format!("decode:{}", cfg.decode_specs[j].label()),
            &engine,
            n_mine,
            0,
            0,
        ));
        traces.push((format!("decode:{}", cfg.decode_specs[j].label()), engine.trace_dump()));
    }
    if !prefill_half.is_empty() {
        bail!("{} migrated request(s) were never answered by the decode tier", prefill_half.len());
    }

    outputs.sort_by_key(|o| o.request);
    Ok(DisaggRun {
        prefill_assignments,
        outputs,
        prefill_snapshots,
        decode_snapshots,
        migrated,
        recompute_migrations,
        migrated_bytes,
        traces,
    })
}

/// Stitch the two halves into one end-to-end answer. Tokens come from the
/// decode half (its generation was seeded with the prefill tier's first
/// token, so it already carries the full stream); TTFT comes from the
/// prefill half (the first token was produced there); modeled latency
/// chains both clocks plus the out-hop (the in-hop is inside the decode
/// engine's clock, charged at import before the first decode step).
fn compose_output(prefill: &RequestOutput, decode: RequestOutput, out_hop_s: f64) -> RequestOutput {
    RequestOutput {
        id: decode.id,
        tokens: decode.tokens,
        finish: decode.finish,
        ttft: prefill.ttft,
        latency: prefill.latency + decode.latency,
        ttft_sim: prefill.ttft_sim,
        latency_sim: prefill.latency_sim + out_hop_s + decode.latency_sim,
        prompt_len: prefill.prompt_len,
        prefix_hit_tokens: prefill.prefix_hit_tokens + decode.prefix_hit_tokens,
        preempt_count: prefill.preempt_count + decode.preempt_count,
        swapped_in_blocks: prefill.swapped_in_blocks + decode.swapped_in_blocks,
        ladder_count: prefill.ladder_count + decode.ladder_count,
        final_kv_layout: decode.final_kv_layout,
        abort_reason: decode.abort_reason,
    }
}

/// Drain `source` for retirement and resume everything on `target`: the
/// replica-drain protocol is the migration primitive pointed the other
/// way. Decoding sequences ship their KV (transcoded to the target's
/// layout when reachable, dropped to a re-prefill otherwise); queued and
/// mid-prefill sequences restart. Returns how many requests moved; the
/// source engine is left empty (`has_work() == false`, pool drained).
pub fn migrate_all(source: &mut Engine, target: &mut Engine) -> Result<usize> {
    let target_layout = target.kv_pool().layout().clone();
    let mut moved = 0;
    for a in source.drain_resumables()? {
        let snap = match a.snapshot {
            Some(s) if s.layout.can_transcode_to(&target_layout) => {
                Some(s.transcode_to(&target_layout)?)
            }
            _ => None,
        };
        target
            .submit_migrated(a.request, a.generated, snap)
            .with_context(|| format!("resuming drained request {}", a.source_id))?;
        moved += 1;
    }
    Ok(moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn base() -> EngineConfig {
        EngineConfig {
            kv_pool_tokens: 16 * 64,
            prefill_chunk: 32,
            ..EngineConfig::default()
        }
    }

    fn spec(s: &str) -> ReplicaSpec {
        s.parse().unwrap()
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n).map(|i| Request::new(vec![(i * 37 % 1024) as i32 + 1; 20 + i % 7], 6)).collect()
    }

    #[test]
    fn config_validation() {
        let cfg = DisaggConfig::new(
            base(),
            vec![spec("w4a16,kv16,a100")],
            vec![spec("w4a16,kv8,a100")],
            RouterPolicy::RoundRobin,
        );
        cfg.validate().unwrap();
        let mut bad = cfg.clone();
        bad.prefill_specs.clear();
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.decode_specs.clear();
        assert!(bad.validate().is_err());
        let mut bad = cfg;
        bad.decode_specs[0].device = "B200".into();
        assert!(bad.validate().is_err(), "per-replica config errors surface");
    }

    #[test]
    fn disagg_matches_monolithic_at_decode_layout() {
        // Prefill at kv16, decode at kv8; same W/A format everywhere. The
        // determinism contract says the composed tokens equal a
        // single-replica monolithic run at the *decode* layout.
        let cfg = DisaggConfig::new(
            base(),
            vec![spec("w4a16,kv16,a100"), spec("w4a16,kv16,a100")],
            vec![spec("w4a16,kv8,a100")],
            RouterPolicy::RoundRobin,
        );
        let rs = reqs(8);
        let run = run_disagg(&cfg, &rs).unwrap();
        assert_eq!(run.outputs.len(), rs.len(), "every request answered exactly once");
        assert_eq!(run.completed(), rs.len());
        assert_eq!(run.migrated, rs.len(), "kv16→kv8 is transcode-reachable");
        assert_eq!(run.recompute_migrations, 0);
        assert!(run.migrated_bytes > 0);

        let mono =
            ClusterConfig::heterogeneous(base(), vec![spec("w4a16,kv8,a100")], cfg.policy);
        let fleet = crate::cluster::run_fleet(&mono, &rs).unwrap();
        for (d, m) in run.outputs.iter().zip(&fleet.outputs) {
            assert_eq!(d.request, m.request);
            assert_eq!(
                d.output.tokens, m.output.tokens,
                "request {} diverged from the monolithic decode-layout run",
                d.request
            );
            assert_eq!(d.output.finish, m.output.finish);
        }
        // Modeled latency chains prefill + hop + decode, and TTFT is the
        // prefill tier's.
        for d in &run.outputs {
            assert!(d.decode_replica.is_some());
            assert!(d.migrate_s > 0.0);
            assert!(d.output.latency_sim > d.output.ttft_sim);
        }
        // Byte accounting flowed into fleet telemetry from both ends.
        let t = run.fleet_telemetry();
        assert!(t.migrate_pcie_bytes() > 0, "migration traffic attributed");
    }

    #[test]
    fn run_disagg_is_deterministic() {
        let cfg = DisaggConfig::new(
            base(),
            vec![spec("w4a16,kv16,a100")],
            vec![spec("w4a16,kv8,a100"), spec("w4a16,kv4,h100")],
            RouterPolicy::LeastLoaded,
        );
        let rs = reqs(10);
        let a = run_disagg(&cfg, &rs).unwrap();
        let b = run_disagg(&cfg, &rs).unwrap();
        assert_eq!(a.prefill_assignments, b.prefill_assignments);
        assert_eq!(a.outputs.len(), b.outputs.len());
        for (x, y) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(x.decode_replica, y.decode_replica, "replayable placement");
            assert_eq!(x.output.tokens, y.output.tokens, "replayable outputs");
            assert_eq!(x.output.latency_sim, y.output.latency_sim, "replayable timing");
        }
        // Both decode replicas actually served (least-loaded spreads).
        let used: std::collections::HashSet<_> =
            a.outputs.iter().filter_map(|o| o.decode_replica).collect();
        assert_eq!(used.len(), 2);
    }

    #[test]
    fn unreachable_decode_layout_falls_back_to_recompute() {
        // Prefill at kv4 cannot transcode *up* to a kv16 decode pool: the
        // request must still complete, via token-only migration.
        let cfg = DisaggConfig::new(
            base(),
            vec![spec("w4a16,kv4,a100")],
            vec![spec("w4a16,kv16,a100")],
            RouterPolicy::RoundRobin,
        );
        let rs = reqs(4);
        let run = run_disagg(&cfg, &rs).unwrap();
        assert_eq!(run.completed(), rs.len(), "no request lost to an unreachable layout");
        assert_eq!(run.migrated, 0);
        assert_eq!(run.recompute_migrations, rs.len());
        assert_eq!(run.migrated_bytes, 0);
        // Recompute migration is still bit-identical to the monolithic
        // decode-layout run.
        let mono =
            ClusterConfig::heterogeneous(base(), vec![spec("w4a16,kv16,a100")], cfg.policy);
        let fleet = crate::cluster::run_fleet(&mono, &rs).unwrap();
        for (d, m) in run.outputs.iter().zip(&fleet.outputs) {
            assert_eq!(d.output.tokens, m.output.tokens);
        }
    }

    #[test]
    fn terminal_prefill_requests_never_cross_tiers() {
        let mut rs = reqs(3);
        rs[1].max_new_tokens = 1; // done at the prefill tier by budget
        let cfg = DisaggConfig::new(
            base(),
            vec![spec("w4a16,kv16,a100")],
            vec![spec("w4a16,kv8,a100")],
            RouterPolicy::RoundRobin,
        );
        let run = run_disagg(&cfg, &rs).unwrap();
        assert_eq!(run.outputs.len(), 3);
        let one = &run.outputs[1];
        assert_eq!(one.decode_replica, None, "1-token request finished at prefill");
        assert_eq!(one.output.tokens.len(), 1);
        assert_eq!(run.migrated, 2);
    }

    #[test]
    fn migrate_all_drains_and_resumes_bit_identically() {
        // Run A to the middle of its generations, drain it into B (same
        // layout), and check the combined answers equal a full run on B.
        let mk = || Engine::new(spec("w4a16,kv8,a100").engine_config(&base())).unwrap();
        // Long generations so a handful of steps leaves everything
        // mid-decode — the drain must catch live KV, not finished work.
        let rs: Vec<Request> =
            (0..5).map(|i| Request::new(vec![(i * 37 % 1024) as i32 + 1; 20 + i], 16)).collect();

        let mut reference = mk();
        let mut want = HashMap::new();
        for r in &rs {
            reference.submit(r.clone()).unwrap();
        }
        for out in reference.run_to_completion().unwrap() {
            want.insert(out.prompt_len, out.tokens);
        }

        let mut a = mk();
        for r in &rs {
            a.submit(r.clone()).unwrap();
        }
        for _ in 0..6 {
            if a.has_work() {
                a.step().unwrap();
            }
        }
        let mut done: Vec<RequestOutput> = a.take_outputs();
        let mut b = mk();
        let moved = migrate_all(&mut a, &mut b).unwrap();
        assert!(!a.has_work(), "source drained");
        assert_eq!(a.kv_pool().used_blocks(), 0, "source pool released everything");
        assert!(a.swap_store().is_empty());
        assert_eq!(done.len() + moved, rs.len(), "every request finished or moved");
        done.extend(b.run_to_completion().unwrap());
        assert_eq!(done.len(), rs.len());
        for out in &done {
            assert_eq!(
                Some(&out.tokens),
                want.get(&out.prompt_len),
                "drained request diverged after resume"
            );
        }
        // A drain is placement, not pressure.
        assert_eq!(a.preemption_summary().preemptions, 0);
        assert!(b.migration_stats.migrated_in >= 1, "decoding residents shipped KV");
    }
}

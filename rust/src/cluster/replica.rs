//! One engine replica: its spec, its load accounting, and its thread.
//!
//! Execution backends are not `Send`, so each replica's [`Engine`] is
//! constructed *inside* its own thread and never leaves it — exactly the
//! single-engine `server::serve` loop, replicated N times. The thread
//! drains a **bounded** inbox (`mpsc::sync_channel`): a full inbox blocks
//! the router's dispatch, which is the fleet's backpressure — requests
//! queue at the router boundary instead of growing an unbounded in-memory
//! backlog on a replica that cannot keep up.
//!
//! Load accounting: the router increments [`ReplicaLoad`] *before* a
//! request enters the inbox; the replica thread decrements when the reply
//! is dispatched (or the submit is rejected). Both sides charge the same
//! `prompt + max_new_tokens` footprint, so a drained fleet always counts
//! back to zero — the invariant the randomized harness asserts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender, TryRecvError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::accounting::ReplicaRecorder;
use super::stats::ReplicaSnapshot;
use crate::config::{DeviceProfile, EngineConfig, LadderPolicy, PrecisionFormat};
use crate::coordinator::{Engine, Request, RequestOutput};
use crate::util::json::Json;

/// What makes one replica different from its neighbors: the precision
/// format it serves, the device profile its latency model runs on, and
/// its tensor-parallel degree — the heterogeneity axes of the paper's
/// hardware-aware format optimization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaSpec {
    pub precision: PrecisionFormat,
    pub device: String,
    pub tp: usize,
    /// Optional per-layer KV admission layout for this replica
    /// (`EngineConfig::kv_layout`). The CLI segment uses `;` between
    /// layers — `layout=l0:kv16;l1:kv8` — because the spec itself splits
    /// on `,`; it is stored here in the engine's `,`-joined form.
    pub kv_layout: Option<String>,
    /// Optional per-replica ladder policy (`ladder=auto`); `None`
    /// inherits the base config's policy.
    pub ladder: Option<LadderPolicy>,
}

impl ReplicaSpec {
    pub fn new(precision: PrecisionFormat, device: &str) -> Self {
        Self { precision, device: device.to_string(), tp: 1, kv_layout: None, ladder: None }
    }

    /// The replica identity string: `W4A16KV8@A100` (plus `/tp2` when
    /// sharded).
    pub fn label(&self) -> String {
        if self.tp > 1 {
            format!("{}@{}/tp{}", self.precision, self.device, self.tp)
        } else {
            format!("{}@{}", self.precision, self.device)
        }
    }

    /// Specialize a base engine config to this replica. Layout and ladder
    /// fall back to the base config when the spec leaves them unset, so a
    /// fleet-wide `--kv-ladder auto` still reaches every replica.
    pub fn engine_config(&self, base: &EngineConfig) -> EngineConfig {
        EngineConfig {
            precision: self.precision,
            device: self.device.clone(),
            tp: self.tp,
            kv_layout: self.kv_layout.clone().or_else(|| base.kv_layout.clone()),
            ladder_policy: self.ladder.unwrap_or(base.ladder_policy),
            ..base.clone()
        }
    }
}

impl std::str::FromStr for ReplicaSpec {
    type Err = String;

    /// Parse the CLI form `fmt,kv,device[,tpN][,layout=…][,ladder=…]` —
    /// e.g. `w4a16,kv8,a100`, `w8a8,kv16,h100,tp2`, or
    /// `w4a16,kv8,a100,layout=l0:kv16;l1:kv8,ladder=auto` (the layout
    /// segment separates layers with `;` since the spec splits on `,`).
    /// The first two fields concatenate into the usual `WxAyKVz`
    /// precision notation.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let parts: Vec<&str> = s.split(',').map(str::trim).collect();
        if parts.len() < 3 {
            return Err(format!(
                "replica spec `{s}` must be `fmt,kv,device[,tpN][,layout=…][,ladder=…]` \
                 (e.g. `w4a16,kv8,a100`)"
            ));
        }
        let precision: PrecisionFormat = format!("{}{}", parts[0], parts[1])
            .parse()
            .map_err(|e| format!("{e}"))?;
        let device = DeviceProfile::by_name(parts[2])
            .ok_or_else(|| format!("unknown device `{}` in replica spec `{s}`", parts[2]))?
            .name
            .to_string();
        let mut tp = 1;
        let mut kv_layout = None;
        let mut ladder = None;
        for t in &parts[3..] {
            if let Some(spec) = t.strip_prefix("layout=") {
                if spec.is_empty() {
                    return Err(format!("empty layout field in replica spec `{s}`"));
                }
                kv_layout = Some(spec.replace(';', ","));
            } else if let Some(pol) = t.strip_prefix("ladder=") {
                ladder = Some(pol.parse::<LadderPolicy>().map_err(|e| format!("{e}"))?);
            } else if let Some(n) = t.strip_prefix("tp") {
                tp = n
                    .parse::<usize>()
                    .ok()
                    .filter(|n| n.is_power_of_two())
                    .ok_or_else(|| format!("bad tp field `{t}` in replica spec `{s}`"))?;
            } else {
                return Err(format!("unknown field `{t}` in replica spec `{s}`"));
            }
        }
        Ok(Self { precision, device, tp, kv_layout, ladder })
    }
}

/// Outstanding-work counters shared between the router (increments at
/// dispatch) and the replica thread (decrements at reply).
#[derive(Debug, Default)]
pub struct ReplicaLoad {
    reqs: AtomicUsize,
    tokens: AtomicUsize,
}

impl ReplicaLoad {
    pub fn start(&self, cost_tokens: usize) {
        self.reqs.fetch_add(1, Ordering::SeqCst);
        self.tokens.fetch_add(cost_tokens, Ordering::SeqCst);
    }

    pub fn finish(&self, cost_tokens: usize) {
        self.reqs.fetch_sub(1, Ordering::SeqCst);
        self.tokens.fetch_sub(cost_tokens, Ordering::SeqCst);
    }

    pub fn reqs(&self) -> usize {
        self.reqs.load(Ordering::SeqCst)
    }

    pub fn tokens(&self) -> usize {
        self.tokens.load(Ordering::SeqCst)
    }
}

/// The token footprint a request reserves for load accounting.
pub fn request_cost(req: &Request) -> usize {
    req.prompt.len() + req.max_new_tokens
}

/// A message into a replica's inbox.
pub enum ToReplica {
    /// Generate; the output travels back on `reply`.
    Gen { req: Request, reply: Sender<RequestOutput> },
    /// Snapshot engine state (answered between iterations).
    Stats { reply: Sender<ReplicaSnapshot> },
    /// Dump the flight-recorder ring (`last = 0` → whole resident ring,
    /// `last = N` → newest N events), answered between iterations as a
    /// per-replica JSON object: `{"id", "label", "enabled", "recorded",
    /// "dropped", "torn", "events"}`.
    Trace { last: usize, reply: Sender<Json> },
}

/// A live replica: inbox sender + load counters + the join handle whose
/// value is the replica's final snapshot.
pub struct ReplicaHandle {
    pub id: usize,
    pub label: String,
    tx: Option<SyncSender<ToReplica>>,
    load: Arc<ReplicaLoad>,
    join: Option<JoinHandle<Option<ReplicaSnapshot>>>,
}

impl ReplicaHandle {
    /// Spawn replica `id` with its own engine built from `cfg`. Blocks
    /// until the engine constructed (or failed — the error propagates).
    pub fn spawn(
        id: usize,
        cfg: EngineConfig,
        label: String,
        queue_depth: usize,
        recorder: Arc<ReplicaRecorder>,
        started: Instant,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<ToReplica>(queue_depth.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let load = Arc::new(ReplicaLoad::default());
        let thread_load = Arc::clone(&load);
        let thread_label = label.clone();
        let join = thread::Builder::new()
            .name(format!("replica-{id}"))
            .spawn(move || {
                replica_main(id, cfg, thread_label, rx, ready_tx, thread_load, recorder, started)
            })
            .map_err(|e| anyhow!("spawning replica {id}: {e}"))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = join.join();
                return Err(e.context(format!("replica {id} ({label}) failed to start")));
            }
            Err(_) => bail!("replica {id} died before reporting readiness"),
        }
        Ok(Self { id, label, tx: Some(tx), load, join: Some(join) })
    }

    /// This replica's outstanding work (router-side view).
    pub fn load(&self) -> &ReplicaLoad {
        &self.load
    }

    /// Send into the bounded inbox; blocks when it is full (backpressure).
    pub fn send(&self, msg: ToReplica) -> Result<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("replica {} already shut down", self.id))?
            .send(msg)
            .map_err(|_| anyhow!("replica {} is gone", self.id))
    }

    /// Fire a snapshot probe without waiting for the answer. Uses
    /// `try_send`: a saturated inbox (full backpressure) fails the probe
    /// for this replica instead of blocking the caller behind queued
    /// generation work — [`super::Cluster::stats`] then omits it, same as
    /// a dead replica. The caller collects the reply from the returned
    /// receiver (typically with a deadline, never an unbounded wait).
    pub fn probe(&self) -> Result<Receiver<ReplicaSnapshot>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("replica {} already shut down", self.id))?
            .try_send(ToReplica::Stats { reply: tx })
            .map_err(|_| anyhow!("replica {} inbox full or gone; probe skipped", self.id))?;
        Ok(rx)
    }

    /// Fire a trace-dump probe without waiting for the answer (same
    /// `try_send` degradation contract as [`probe`](Self::probe): a
    /// saturated or dead replica fails the probe instead of blocking, and
    /// [`super::Cluster::trace`] omits it from the fleet answer).
    pub fn trace_probe(&self, last: usize) -> Result<Receiver<Json>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("replica {} already shut down", self.id))?
            .try_send(ToReplica::Trace { last, reply: tx })
            .map_err(|_| anyhow!("replica {} inbox full or gone; trace probe skipped", self.id))?;
        Ok(rx)
    }

    /// Ask the live replica for a snapshot, waiting for the answer
    /// (single-replica convenience; fleet probes use
    /// [`probe`](Self::probe) so one wedged replica cannot stall the
    /// others).
    pub fn stats(&self) -> Result<ReplicaSnapshot> {
        self.probe()?.recv().map_err(|_| anyhow!("replica {} dropped stats probe", self.id))
    }

    /// A replica whose thread drains its inbox but never answers anything
    /// — a deterministic stand-in for a wedged engine, used to prove the
    /// fleet stats probe degrades instead of hanging.
    #[cfg(test)]
    pub fn spawn_unresponsive(id: usize, queue_depth: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<ToReplica>(queue_depth.max(1));
        let join = thread::Builder::new()
            .name(format!("replica-{id}-unresponsive"))
            .spawn(move || {
                while rx.recv().is_ok() {}
                None
            })
            .expect("spawning unresponsive replica");
        Self {
            id,
            label: "wedged".into(),
            tx: Some(tx),
            load: Arc::new(ReplicaLoad::default()),
            join: Some(join),
        }
    }

    /// Close the inbox and wait for the replica to drain and exit;
    /// returns its final snapshot.
    pub fn join(mut self) -> Result<ReplicaSnapshot> {
        self.tx = None; // disconnects the inbox
        let join = self.join.take().expect("join handle present until joined");
        match join.join() {
            Ok(Some(snap)) => Ok(snap),
            Ok(None) => bail!("replica {} never started an engine", self.id),
            Err(_) => bail!("replica {} panicked", self.id),
        }
    }
}

/// The replica thread body: the `server::serve` engine loop, one per
/// replica. Returns the final snapshot once the inbox disconnects and all
/// accepted work has been answered.
#[allow(clippy::too_many_arguments)]
fn replica_main(
    id: usize,
    cfg: EngineConfig,
    label: String,
    rx: Receiver<ToReplica>,
    ready: Sender<Result<()>>,
    load: Arc<ReplicaLoad>,
    recorder: Arc<ReplicaRecorder>,
    started: Instant,
) -> Option<ReplicaSnapshot> {
    // Build AND warm up before reporting ready, mirroring `cmd_serve`:
    // a PJRT replica compiles its graphs now, so artifact problems
    // surface at spawn, not mid-request.
    let mut engine = match Engine::new(cfg).and_then(|e| {
        e.warmup()?;
        Ok(e)
    }) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return None;
        }
    };
    let mut pending: Vec<(u64, usize, Sender<RequestOutput>)> = Vec::new();
    let mut completed = 0usize;
    let mut disconnected = false;
    loop {
        // Dispatch finished outputs first — submit can finish a request
        // immediately, and the loop must never block while a client waits.
        for out in engine.take_outputs() {
            if let Some(pos) = pending.iter().position(|(pid, _, _)| *pid == out.id) {
                let (_, cost, reply) = pending.remove(pos);
                // Fleet percentiles summarize successful completions only
                // — an aborted answer's near-zero latency would skew them.
                // Wait-free: the recorder never blocks the reply path.
                if out.finish != crate::coordinator::FinishReason::Aborted {
                    recorder.record(
                        out.latency,
                        out.ttft,
                        started.elapsed().as_secs_f64(),
                        out.prompt_len,
                        out.tokens.len(),
                    );
                }
                load.finish(cost);
                completed += 1;
                let _ = reply.send(out);
            }
        }
        if disconnected && !engine.has_work() && pending.is_empty() {
            return Some(ReplicaSnapshot::of(
                id,
                &label,
                &engine,
                completed,
                load.reqs(),
                load.tokens(),
            ));
        }
        // Admit without blocking while the engine has work; block on the
        // inbox only when idle.
        while !disconnected {
            let msg = if engine.has_work() {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        disconnected = true;
                        break;
                    }
                }
            };
            match msg {
                ToReplica::Stats { reply } => {
                    let _ = reply.send(ReplicaSnapshot::of(
                        id,
                        &label,
                        &engine,
                        completed,
                        load.reqs(),
                        load.tokens(),
                    ));
                    // Idle engines go straight back to blocking on the
                    // inbox; busy ones fall through to admit more.
                    continue;
                }
                ToReplica::Trace { last, reply } => {
                    let _ = reply.send(replica_trace_json(id, &label, &engine, last));
                    continue;
                }
                ToReplica::Gen { req, reply } => {
                    let cost = request_cost(&req);
                    match engine.submit(req) {
                        Ok(rid) => {
                            pending.push((rid, cost, reply));
                            if !engine.has_work() {
                                break; // finished at submit: dispatch now
                            }
                        }
                        Err(e) => {
                            // A rejection is still an *answer*: release
                            // the load and count it, so per-replica
                            // `completed` sums keep equaling the requests
                            // routed in (the harness invariant), matching
                            // `run_fleet`'s accounting.
                            load.finish(cost);
                            completed += 1;
                            let _ = reply.send(RequestOutput::rejected(e.to_string()));
                        }
                    }
                }
            }
        }
        if engine.has_work() {
            if let Err(e) = engine.step() {
                // A stepping error is fatal for this replica: answer
                // everything outstanding as rejected so no client hangs.
                eprintln!("replica {id} ({label}) engine error: {e}");
                for (_, cost, reply) in pending.drain(..) {
                    load.finish(cost);
                    let _ = reply
                        .send(RequestOutput::rejected(format!("replica engine error: {e}")));
                }
                return Some(ReplicaSnapshot::of(
                    id,
                    &label,
                    &engine,
                    completed,
                    load.reqs(),
                    load.tokens(),
                ));
            }
        }
    }
}

/// One replica's trace-probe answer: the engine's ring dump plus the
/// replica identity, so the fleet-level `{"trace": ...}` answer needs no
/// side lookup to label its tracks.
fn replica_trace_json(id: usize, label: &str, engine: &Engine, last: usize) -> Json {
    let dump =
        if last == 0 { engine.trace_dump() } else { engine.trace_dump_last(last) };
    let mut body = crate::trace::dump_json(&dump);
    if let Json::Obj(m) = &mut body {
        m.insert("enabled".into(), Json::from(engine.trace_recorder().is_some()));
        m.insert("id".into(), Json::from(id));
        m.insert("label".into(), Json::from(label));
    }
    body
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_cli_form() {
        let s: ReplicaSpec = "w4a16,kv8,a100".parse().unwrap();
        assert_eq!(s.precision.to_string(), "W4A16KV8");
        assert_eq!(s.device, "A100");
        assert_eq!(s.tp, 1);
        assert_eq!(s.label(), "W4A16KV8@A100");

        let s: ReplicaSpec = "w8a8,kv16,h100,tp2".parse().unwrap();
        assert_eq!(s.precision.to_string(), "W8A8KV16");
        assert_eq!(s.device, "H100");
        assert_eq!(s.tp, 2);
        assert_eq!(s.label(), "W8A8KV16@H100/tp2");

        let s: ReplicaSpec =
            "w4a16,kv8,a100,layout=l0:kv16;l1:kv8,ladder=auto".parse().unwrap();
        assert_eq!(s.kv_layout.as_deref(), Some("l0:kv16,l1:kv8"), "`;` becomes `,`");
        assert_eq!(s.ladder, Some(LadderPolicy::Auto));
        assert_eq!(s.tp, 1);

        let s: ReplicaSpec = "w8a8,kv16,h100,tp2,ladder=off".parse().unwrap();
        assert_eq!(s.ladder, Some(LadderPolicy::Off));
        assert_eq!(s.tp, 2);
        assert!(s.kv_layout.is_none());

        assert!("w4a16,kv8".parse::<ReplicaSpec>().is_err(), "missing device");
        assert!("w4a16,kv8,b200".parse::<ReplicaSpec>().is_err(), "unknown device");
        assert!("w4a16,kv8,a100,tp3".parse::<ReplicaSpec>().is_err(), "non-pow2 tp");
        assert!("w3a16,kv8,a100".parse::<ReplicaSpec>().is_err(), "bad precision");
        assert!("w4a16,kv8,a100,layout=".parse::<ReplicaSpec>().is_err(), "empty layout");
        assert!("w4a16,kv8,a100,ladder=up".parse::<ReplicaSpec>().is_err(), "bad ladder");
        assert!("w4a16,kv8,a100,bogus".parse::<ReplicaSpec>().is_err(), "unknown field");
    }

    #[test]
    fn spec_specializes_base_config() {
        let base = EngineConfig { kv_pool_tokens: 16 * 64, ..EngineConfig::default() };
        let spec: ReplicaSpec = "w8a8,kv16,h100".parse().unwrap();
        let cfg = spec.engine_config(&base);
        assert_eq!(cfg.precision.to_string(), "W8A8KV16");
        assert_eq!(cfg.device, "H100");
        assert_eq!(cfg.kv_pool_tokens, 16 * 64, "base knobs survive");
        assert!(cfg.kv_layout.is_none());
        assert_eq!(cfg.ladder_policy, LadderPolicy::Off);
        cfg.validate().unwrap();

        // Spec-level layout/ladder override the base…
        let spec: ReplicaSpec =
            "w8a8,kv16,h100,layout=l0:kv16;l1:kv8,ladder=auto".parse().unwrap();
        let base = EngineConfig {
            preemption_mode: crate::config::PreemptionMode::Swap,
            ..EngineConfig::default()
        };
        let cfg = spec.engine_config(&base);
        assert_eq!(cfg.kv_layout.as_deref(), Some("l0:kv16,l1:kv8"));
        assert_eq!(cfg.ladder_policy, LadderPolicy::Auto);
        cfg.validate().unwrap();

        // …and an unset spec inherits a fleet-wide base policy.
        let spec: ReplicaSpec = "w8a8,kv16,h100".parse().unwrap();
        let base = EngineConfig {
            kv_layout: Some("kv8".into()),
            ladder_policy: LadderPolicy::Auto,
            preemption_mode: crate::config::PreemptionMode::Ladder,
            ..EngineConfig::default()
        };
        let cfg = spec.engine_config(&base);
        assert_eq!(cfg.kv_layout.as_deref(), Some("kv8"));
        assert_eq!(cfg.ladder_policy, LadderPolicy::Auto);
    }

    #[test]
    fn load_accounting_balances() {
        let l = ReplicaLoad::default();
        l.start(48);
        l.start(16);
        assert_eq!((l.reqs(), l.tokens()), (2, 64));
        l.finish(48);
        l.finish(16);
        assert_eq!((l.reqs(), l.tokens()), (0, 0));
    }

    #[test]
    fn replica_thread_serves_and_drains() {
        let recorder = Arc::new(ReplicaRecorder::new());
        let cfg = EngineConfig { kv_pool_tokens: 16 * 64, ..EngineConfig::default() };
        let r = ReplicaHandle::spawn(
            0,
            cfg,
            "W4A16KV8@A100".into(),
            8,
            Arc::clone(&recorder),
            Instant::now(),
        )
        .unwrap();
        let (otx, orx) = mpsc::channel();
        r.load().start(10 + 4);
        r.send(ToReplica::Gen {
            req: Request::new((0..10).collect(), 4),
            reply: otx,
        })
        .unwrap();
        let out = orx.recv().unwrap();
        assert_eq!(out.tokens.len(), 4);
        let snap = r.stats().unwrap();
        assert_eq!(snap.completed, 1);
        // Engine-rejected requests still answer (and release their load).
        let (etx, erx) = mpsc::channel();
        r.load().start(9999);
        r.send(ToReplica::Gen { req: Request::new(vec![1; 9000], 999), reply: etx })
            .unwrap();
        let rej = erx.recv().unwrap();
        assert!(rej.abort_reason.is_some());
        let snap = r.join().unwrap();
        assert_eq!(snap.completed, 2, "rejections count as answered");
        assert_eq!((snap.outstanding_reqs, snap.outstanding_tokens), (0, 0));
        assert_eq!(recorder.completed(), 1, "…but not as successes");
    }

    #[test]
    fn trace_probe_answers_with_identity_and_events() {
        let cfg = EngineConfig {
            kv_pool_tokens: 16 * 64,
            trace: true,
            ..EngineConfig::default()
        };
        let r = ReplicaHandle::spawn(
            3,
            cfg,
            "W4A16KV8@A100".into(),
            8,
            Arc::new(ReplicaRecorder::new()),
            Instant::now(),
        )
        .unwrap();
        let (otx, orx) = mpsc::channel();
        r.load().start(8 + 2);
        r.send(ToReplica::Gen { req: Request::new((0..8).collect(), 2), reply: otx })
            .unwrap();
        orx.recv().unwrap();
        let t = r.trace_probe(0).unwrap().recv().unwrap();
        assert_eq!(t.get("enabled").and_then(Json::as_bool), Some(true));
        assert_eq!(t.req_usize("id").unwrap(), 3);
        assert_eq!(t.req_str("label").unwrap(), "W4A16KV8@A100");
        let n = t.req_arr("events").unwrap().len();
        assert!(n >= 3, "admit + work + finish recorded, got {n}");
        assert_eq!(t.req_usize("recorded").unwrap(), n, "nothing dropped at this volume");
        // last-N bounds the answer.
        let t2 = r.trace_probe(2).unwrap().recv().unwrap();
        assert_eq!(t2.req_arr("events").unwrap().len(), 2);
        r.join().unwrap();
    }

    #[test]
    fn spawn_surfaces_engine_construction_errors() {
        let cfg = EngineConfig { max_batch: 3, ..EngineConfig::default() }; // invalid
        let err = ReplicaHandle::spawn(
            0,
            cfg,
            "bad".into(),
            4,
            Arc::new(ReplicaRecorder::new()),
            Instant::now(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("failed to start"), "{err}");
    }
}

//! Lock-free fleet accounting: per-replica completion recorders the
//! replica threads write **wait-free** on the serving hot path, merged
//! into a [`MetricsCollector`] only when a stats probe asks.
//!
//! The previous design funneled every completion on every replica through
//! one `Arc<Mutex<MetricsCollector>>` — a fleet-wide serialization point
//! on the reply path, and a lock the stats probe had to take *while*
//! replicas were completing work. Here each replica owns a
//! [`ReplicaRecorder`]:
//!
//! * exact counters (completions, prompt/generated token totals) are
//!   plain atomics — never lossy, never contended across replicas;
//! * per-completion samples (latency / TTFT / completion time) land in a
//!   fixed-capacity **seqlock ring**: the single writer never waits and
//!   never allocates, a torn read is detected by the reader and skipped,
//!   and an overfull ring windows to the most recent `capacity` samples
//!   (percentiles degrade gracefully; counts never do).
//!
//! Memory protocol (per slot, single producer / any readers):
//! writer bumps the slot's sequence to odd, publishes the payload, then
//! bumps to even with `Release`; a reader takes an `Acquire` snapshot of
//! the sequence before and after reading the payload and accepts the
//! sample only if both reads saw the same even value. `f64` payloads
//! travel as `to_bits` in `AtomicU64`s — no `unsafe` anywhere.

use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::metrics::MetricsCollector;

/// Default ring capacity: enough to keep fleet percentiles exact for any
/// probe interval that observes fewer than this many completions per
/// replica.
pub const DEFAULT_SAMPLE_CAPACITY: usize = 1024;

/// Bounded retries before a reader gives up on a slot the writer keeps
/// overwriting (the writer is wait-free; the reader is the one that
/// yields).
const READ_RETRIES: usize = 64;

#[derive(Debug, Default)]
struct SampleSlot {
    /// Seqlock sequence: even = stable, odd = write in progress.
    seq: AtomicU64,
    latency: AtomicU64,
    ttft: AtomicU64,
    done_at: AtomicU64,
    prompt: AtomicU64,
    gen: AtomicU64,
}

/// One replica's wait-free completion recorder.
///
/// Contract: [`record`](Self::record) has a **single producer** (the
/// owning replica thread). Readers ([`drain_into`](Self::drain_into))
/// may run concurrently from any thread at any time; they never block
/// the writer.
#[derive(Debug)]
pub struct ReplicaRecorder {
    /// Exact successful completions (monotonic; also the ring cursor).
    completed: AtomicUsize,
    prompt_tokens: AtomicUsize,
    gen_tokens: AtomicUsize,
    ring: Box<[SampleSlot]>,
}

impl Default for ReplicaRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplicaRecorder {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_SAMPLE_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        let ring = (0..capacity.max(1)).map(|_| SampleSlot::default()).collect();
        Self {
            completed: AtomicUsize::new(0),
            prompt_tokens: AtomicUsize::new(0),
            gen_tokens: AtomicUsize::new(0),
            ring,
        }
    }

    /// Record one successful completion. Wait-free: two atomic adds, one
    /// seqlock slot publish. Single producer — the owning replica thread.
    pub fn record(
        &self,
        latency_s: f64,
        ttft_s: f64,
        done_at_s: f64,
        prompt_tokens: usize,
        gen_tokens: usize,
    ) {
        let n = self.completed.load(Ordering::Relaxed);
        let slot = &self.ring[n % self.ring.len()];
        let s = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(s + 1, Ordering::Relaxed); // odd: write in progress
        fence(Ordering::Release);
        slot.latency.store(latency_s.to_bits(), Ordering::Relaxed);
        slot.ttft.store(ttft_s.to_bits(), Ordering::Relaxed);
        slot.done_at.store(done_at_s.to_bits(), Ordering::Relaxed);
        slot.prompt.store(prompt_tokens as u64, Ordering::Relaxed);
        slot.gen.store(gen_tokens as u64, Ordering::Relaxed);
        slot.seq.store(s + 2, Ordering::Release); // even: stable
        self.prompt_tokens.fetch_add(prompt_tokens, Ordering::Relaxed);
        self.gen_tokens.fetch_add(gen_tokens, Ordering::Relaxed);
        // Publish the count last so a reader that observes it also
        // observes the slot contents it promises.
        self.completed.store(n + 1, Ordering::Release);
    }

    /// Exact successful completions recorded so far.
    pub fn completed(&self) -> usize {
        self.completed.load(Ordering::Acquire)
    }

    /// Exact `(prompt, generated)` token totals.
    pub fn token_totals(&self) -> (usize, usize) {
        (
            self.prompt_tokens.load(Ordering::Relaxed),
            self.gen_tokens.load(Ordering::Relaxed),
        )
    }

    /// Samples currently resident in the ring window.
    pub fn sampled(&self) -> usize {
        self.completed().min(self.ring.len())
    }

    /// Merge every consistent resident sample into `m`; returns the
    /// number of slots skipped as torn (the writer lapped the reader
    /// mid-slot — each skip is one sample of percentile resolution lost,
    /// never a lost count).
    pub fn drain_into(&self, m: &mut MetricsCollector) -> usize {
        let mut torn = 0usize;
        for slot in self.ring.iter().take(self.sampled()) {
            let mut ok = false;
            for _ in 0..READ_RETRIES {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 % 2 == 1 {
                    continue; // mid-write
                }
                let latency = slot.latency.load(Ordering::Relaxed);
                let ttft = slot.ttft.load(Ordering::Relaxed);
                let done_at = slot.done_at.load(Ordering::Relaxed);
                let prompt = slot.prompt.load(Ordering::Relaxed);
                let gen = slot.gen.load(Ordering::Relaxed);
                fence(Ordering::Acquire);
                let s2 = slot.seq.load(Ordering::Relaxed);
                if s1 == s2 {
                    m.record(
                        f64::from_bits(latency),
                        f64::from_bits(ttft),
                        f64::from_bits(done_at),
                        prompt as usize,
                        gen as usize,
                    );
                    ok = true;
                    break;
                }
            }
            if !ok {
                torn += 1;
            }
        }
        torn
    }
}

/// Merge a fleet of recorders into one collector for percentile math,
/// alongside the **exact** fleet completion count (the ring may window;
/// the counter never does). The third element is the torn-slot count —
/// samples skipped because the writer lapped the probe.
pub fn collect(recorders: &[Arc<ReplicaRecorder>]) -> (MetricsCollector, usize, usize) {
    let mut m = MetricsCollector::new();
    let mut exact = 0usize;
    let mut torn = 0usize;
    for r in recorders {
        exact += r.completed();
        torn += r.drain_into(&mut m);
    }
    (m, exact, torn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn records_and_drains_exactly() {
        let r = ReplicaRecorder::with_capacity(8);
        r.record(1.0, 0.25, 1.0, 32, 4);
        r.record(2.0, 0.5, 2.0, 16, 8);
        assert_eq!(r.completed(), 2);
        assert_eq!(r.token_totals(), (48, 12));
        let mut m = MetricsCollector::new();
        assert_eq!(r.drain_into(&mut m), 0);
        assert_eq!(m.count(), 2);
        let p = m.latency_percentiles().unwrap();
        assert_eq!((p.p50, p.max), (1.0, 2.0));
    }

    #[test]
    fn ring_windows_but_counters_stay_exact() {
        let r = ReplicaRecorder::with_capacity(4);
        for i in 0..10 {
            r.record(i as f64, 0.1, i as f64, 1, 1);
        }
        assert_eq!(r.completed(), 10, "counter is exact");
        assert_eq!(r.sampled(), 4, "ring windows to capacity");
        assert_eq!(r.token_totals(), (10, 10), "token totals are exact");
        let mut m = MetricsCollector::new();
        assert_eq!(r.drain_into(&mut m), 0);
        assert_eq!(m.count(), 4);
        // The window holds the most recent samples (6..=9).
        assert_eq!(m.latency_percentiles().unwrap().max, 9.0);
    }

    #[test]
    fn concurrent_probes_never_see_torn_samples() {
        // One writer hammers the ring with a recognizable invariant
        // (ttft == latency / 2); reader threads snapshot concurrently and
        // must only ever observe intact pairs.
        let r = Arc::new(ReplicaRecorder::with_capacity(16));
        let w = Arc::clone(&r);
        let writer = thread::spawn(move || {
            for i in 1..=20_000u32 {
                let lat = i as f64;
                w.record(lat, lat / 2.0, lat, i as usize, 1);
            }
        });
        let mut readers = Vec::new();
        for _ in 0..3 {
            let rr = Arc::clone(&r);
            readers.push(thread::spawn(move || {
                let mut seen = 0usize;
                for _ in 0..200 {
                    let mut m = MetricsCollector::new();
                    rr.drain_into(&mut m);
                    seen += m.count();
                    // Every accepted sample satisfies the invariant.
                    if let (Some(l), Some(t)) =
                        (m.latency_percentiles(), m.ttft_percentiles())
                    {
                        assert_eq!(l.max / 2.0, t.max, "torn sample leaked");
                        assert_eq!(l.p50 / 2.0, t.p50, "torn sample leaked");
                    }
                }
                seen
            }));
        }
        writer.join().unwrap();
        for h in readers {
            h.join().unwrap();
        }
        assert_eq!(r.completed(), 20_000);
        let (m, exact, _) = collect(&[r]);
        assert_eq!(exact, 20_000);
        assert_eq!(m.count(), 16, "final drain sees a full, stable ring");
    }

    #[test]
    fn drain_totals_survive_merge_order_permutations() {
        // Fleet merges happen in whatever order the probe walks the
        // replicas; every aggregate a probe reports must be independent
        // of that order. Three recorders with distinct shapes (one of
        // them windowed) drained in all six orders.
        let a = Arc::new(ReplicaRecorder::with_capacity(4));
        let b = Arc::new(ReplicaRecorder::with_capacity(4));
        let c = Arc::new(ReplicaRecorder::with_capacity(2));
        a.record(1.0, 0.25, 1.0, 32, 4);
        a.record(3.0, 0.75, 3.0, 16, 4);
        b.record(2.0, 0.5, 2.0, 8, 2);
        for i in 0..5 {
            c.record(4.0 + i as f64, 1.0, 4.0, 4, 1); // windows to last 2
        }
        let orders: [[&Arc<ReplicaRecorder>; 3]; 6] = [
            [&a, &b, &c],
            [&a, &c, &b],
            [&b, &a, &c],
            [&b, &c, &a],
            [&c, &a, &b],
            [&c, &b, &a],
        ];
        let mut reference = None;
        for order in orders {
            let rs: Vec<Arc<ReplicaRecorder>> =
                order.iter().map(|r| Arc::clone(r)).collect();
            let (m, exact, torn) = collect(&rs);
            assert_eq!(torn, 0, "idle recorders never tear");
            let got = (
                exact,
                m.count(),
                m.total_tokens(),
                m.latency_percentiles(),
                m.ttft_percentiles(),
                m.tpot_percentiles(),
            );
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(&got, want, "merge order changed a total"),
            }
        }
        let (exact, count, ..) = reference.unwrap();
        assert_eq!(exact, 8, "counter totals are exact despite the windowed ring");
        assert_eq!(count, 5, "2 + 1 + windowed 2 percentile samples");
    }

    #[test]
    fn collect_merges_fleet_and_reports_exact_count() {
        let a = Arc::new(ReplicaRecorder::with_capacity(4));
        let b = Arc::new(ReplicaRecorder::with_capacity(4));
        a.record(1.0, 0.1, 1.0, 8, 2);
        for i in 0..6 {
            b.record(2.0 + i as f64, 0.2, 2.0, 4, 1);
        }
        let (m, exact, torn) = collect(&[a, b]);
        assert_eq!(exact, 7, "exact across the fleet despite windowing");
        assert_eq!(m.count(), 5, "1 + windowed 4 samples merged");
        assert_eq!(torn, 0);
    }
}

//! Routing policies over the replica fleet.
//!
//! The router is pure decision logic: given a request's prompt and a view
//! of per-replica load, pick a replica index. The same code drives both
//! the live threaded [`super::Cluster`] (loads read from the replicas'
//! atomic counters) and the deterministic offline [`super::run_fleet`]
//! (loads are the totals assigned so far).
//!
//! Policy contracts (DESIGN.md §9):
//! * `round_robin` — strict rotation; stateless wrt load and content.
//! * `least_loaded` — fewest outstanding *tokens* (prompt + generation
//!   budget of unanswered requests); ties break on fewer outstanding
//!   requests, then lowest index. Tokens, not requests, because a replica
//!   chewing one 2k-token prompt is busier than one holding three
//!   16-token chats.
//! * `prefix_affinity` — requests sharing leading prompt blocks (the
//!   [`crate::kvcache::route_key`] chain hash) stick to one replica, so a
//!   tenant's shared system prompt and each conversation's growing
//!   history stay resident in exactly one prefix cache. First touch of a
//!   key places it on the replica holding the fewest sticky keys (tie →
//!   lowest index): deterministic regardless of completion timing, which
//!   keeps fleet runs replayable, and balanced whenever key populations
//!   are (the multi-tenant shape this policy exists for).

use std::collections::{HashMap, VecDeque};

use crate::kvcache::route_key;

/// Sticky-key capacity of the `prefix_affinity` map. Beyond this the
/// oldest keys are forgotten (FIFO) — a forgotten session simply
/// re-places by first touch on its next request. Bounds router memory
/// under endless distinct-prompt traffic while staying deterministic
/// (eviction depends only on the pick sequence, never on timing).
const MAX_AFFINITY_KEYS: usize = 1 << 16;

/// How the cluster spreads requests over replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    #[default]
    RoundRobin,
    LeastLoaded,
    PrefixAffinity,
}

impl std::str::FromStr for RouterPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "round_robin" => Ok(RouterPolicy::RoundRobin),
            "least_loaded" => Ok(RouterPolicy::LeastLoaded),
            "prefix_affinity" => Ok(RouterPolicy::PrefixAffinity),
            other => Err(format!(
                "unknown router policy `{other}` (expected `round_robin`, `least_loaded`, \
                 or `prefix_affinity`)"
            )),
        }
    }
}

impl std::fmt::Display for RouterPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RouterPolicy::RoundRobin => "round_robin",
            RouterPolicy::LeastLoaded => "least_loaded",
            RouterPolicy::PrefixAffinity => "prefix_affinity",
        })
    }
}

/// One replica's load as the router sees it at pick time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadView {
    /// Requests dispatched and not yet answered.
    pub reqs: usize,
    /// Token footprint (prompt + generation budget) of those requests.
    pub tokens: usize,
}

/// The routing state machine.
#[derive(Debug)]
pub struct Router {
    policy: RouterPolicy,
    n: usize,
    rr_next: usize,
    /// Sticky prefix-key → replica assignments (`prefix_affinity` only).
    affinity: HashMap<u64, usize>,
    /// Insertion order of sticky keys, for FIFO eviction at capacity.
    affinity_order: VecDeque<u64>,
    /// Sticky keys per replica, for balanced first-touch placement.
    keys_per_replica: Vec<usize>,
    block_tokens: usize,
    affinity_blocks: usize,
    /// Max sticky keys retained ([`MAX_AFFINITY_KEYS`]; tests shrink it).
    max_keys: usize,
}

impl Router {
    /// `block_tokens` must match the replicas' KV block size so the
    /// routing hash walks the same block boundaries their prefix indexes
    /// do; `affinity_blocks` caps the hashed depth (see
    /// [`crate::kvcache::route_key`]).
    pub fn new(
        policy: RouterPolicy,
        n_replicas: usize,
        block_tokens: usize,
        affinity_blocks: usize,
    ) -> Self {
        assert!(n_replicas > 0, "router over an empty fleet");
        Self {
            policy,
            n: n_replicas,
            rr_next: 0,
            affinity: HashMap::new(),
            affinity_order: VecDeque::new(),
            keys_per_replica: vec![0; n_replicas],
            block_tokens,
            affinity_blocks,
            max_keys: MAX_AFFINITY_KEYS,
        }
    }

    /// Shrink the sticky-key capacity (tests exercise eviction without
    /// minting 65k keys).
    #[cfg(test)]
    fn with_max_keys(mut self, n: usize) -> Self {
        self.max_keys = n.max(1);
        self
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Distinct prefix keys currently pinned to replicas.
    pub fn affinity_keys(&self) -> usize {
        self.affinity.len()
    }

    /// Pick the replica for a request with this prompt under the current
    /// loads (`loads.len()` must equal the fleet size).
    pub fn pick(&mut self, prompt: &[i32], loads: &[LoadView]) -> usize {
        assert_eq!(loads.len(), self.n, "load view size != fleet size");
        match self.policy {
            RouterPolicy::RoundRobin => {
                let i = self.rr_next % self.n;
                self.rr_next += 1;
                i
            }
            RouterPolicy::LeastLoaded => argmin_by(loads, |l| (l.tokens, l.reqs)),
            RouterPolicy::PrefixAffinity => {
                let key = route_key(prompt, self.block_tokens, self.affinity_blocks);
                if let Some(&i) = self.affinity.get(&key) {
                    return i;
                }
                // Bound the sticky map: forget the oldest keys first so
                // endless one-shot prompts cannot grow memory or let dead
                // keys skew the first-touch balance forever.
                while self.affinity.len() >= self.max_keys {
                    let old = self.affinity_order.pop_front().expect("map non-empty");
                    if let Some(rep) = self.affinity.remove(&old) {
                        self.keys_per_replica[rep] -= 1;
                    }
                }
                let i = argmin_by(&self.keys_per_replica, |&k| k);
                self.affinity.insert(key, i);
                self.affinity_order.push_back(key);
                self.keys_per_replica[i] += 1;
                i
            }
        }
    }
}

/// Index of the minimum by `key`, lowest index on ties — the balanced
/// deterministic placement primitive every policy tie-break uses (shared
/// with the abstract fleet simulator's trace-level router).
pub(crate) fn argmin_by<T, K: Ord>(xs: &[T], key: impl Fn(&T) -> K) -> usize {
    assert!(!xs.is_empty(), "non-empty fleet");
    let mut best = 0usize;
    for i in 1..xs.len() {
        if key(&xs[i]) < key(&xs[best]) {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    const BT: usize = 16;

    fn loads(tokens: &[usize]) -> Vec<LoadView> {
        tokens.iter().map(|&t| LoadView { reqs: t / 32, tokens: t }).collect()
    }

    fn block(tag: i32) -> Vec<i32> {
        (0..BT as i32).map(|i| tag * 1000 + i).collect()
    }

    #[test]
    fn policy_parses_and_displays() {
        for (s, p) in [
            ("round_robin", RouterPolicy::RoundRobin),
            ("LEAST_LOADED", RouterPolicy::LeastLoaded),
            ("Prefix_Affinity", RouterPolicy::PrefixAffinity),
        ] {
            assert_eq!(s.parse::<RouterPolicy>().unwrap(), p);
        }
        assert!("random".parse::<RouterPolicy>().is_err());
        assert_eq!(RouterPolicy::PrefixAffinity.to_string(), "prefix_affinity");
    }

    #[test]
    fn round_robin_rotates() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 3, BT, 4);
        let l = loads(&[0, 0, 0]);
        let picks: Vec<usize> = (0..6).map(|_| r.pick(&block(1), &l)).collect();
        assert_eq!(picks, [0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_prefers_fewest_tokens_then_reqs_then_index() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 3, BT, 4);
        assert_eq!(r.pick(&block(1), &loads(&[100, 40, 90])), 1);
        // Token tie → fewer requests wins.
        let l = vec![
            LoadView { reqs: 3, tokens: 64 },
            LoadView { reqs: 1, tokens: 64 },
            LoadView { reqs: 2, tokens: 64 },
        ];
        assert_eq!(r.pick(&block(1), &l), 1);
        // Full tie → lowest index.
        assert_eq!(r.pick(&block(1), &loads(&[64, 64, 64])), 0);
    }

    #[test]
    fn prefix_affinity_sticks_and_balances_first_touch() {
        // Shared prefixes span the full cap (4 blocks), per the contract:
        // keep `affinity_blocks` ≤ the workload's stable shared prefix.
        let mut r = Router::new(RouterPolicy::PrefixAffinity, 2, BT, 4);
        let l = loads(&[0, 0]);
        // Four tenants: first touches alternate replicas 0,1,0,1…
        let mut tenant_prompts: Vec<Vec<i32>> = Vec::new();
        for t in 0..4 {
            let mut p = block(t);
            p.extend(block(t + 100));
            p.extend(block(t + 200));
            p.extend(block(t + 300)); // 4 shared blocks = the hash cap
            tenant_prompts.push(p);
        }
        let first: Vec<usize> =
            tenant_prompts.iter().map(|p| r.pick(p, &l)).collect();
        assert_eq!(first, [0, 1, 0, 1], "balanced deterministic placement");
        assert_eq!(r.affinity_keys(), 4);
        // …and every later request with the same leading blocks sticks,
        // regardless of load skew and of history growth past the cap.
        for (t, p) in tenant_prompts.iter().enumerate() {
            let mut grown = p.clone();
            grown.extend(block(900 + t as i32)); // divergent history
            grown.extend(block(950 + t as i32)); // > affinity_blocks depth
            assert_eq!(r.pick(&grown, &loads(&[10_000, 0])), first[t], "tenant {t}");
        }
        assert_eq!(r.affinity_keys(), 4, "grown prompts reuse their keys");
    }

    #[test]
    fn prefix_affinity_rekeys_prompts_that_start_below_the_cap() {
        // The documented limit of prefix hashing: a session whose initial
        // prompt has fewer full blocks than `affinity_blocks` hashes a
        // deeper key once it grows, so it re-places by first touch. Keep
        // the cap ≤ the stable shared prefix to avoid this; the behavior
        // itself must stay deterministic.
        let mut r = Router::new(RouterPolicy::PrefixAffinity, 2, BT, 4);
        let l = loads(&[0, 0]);
        let short = block(7); // 1 full block < cap
        let a = r.pick(&short, &l);
        let mut grown = short.clone();
        grown.extend(block(8));
        grown.extend(block(9));
        grown.extend(block(10)); // now 4 full blocks → deeper key
        let b = r.pick(&grown, &l);
        assert_eq!(r.affinity_keys(), 2, "growth past the cap mints a new key");
        // Both keys stay individually sticky.
        assert_eq!(r.pick(&short, &loads(&[500, 0])), a);
        assert_eq!(r.pick(&grown, &loads(&[500, 0])), b);
    }

    #[test]
    fn prefix_affinity_separates_distinct_prefixes() {
        let mut r = Router::new(RouterPolicy::PrefixAffinity, 4, BT, 1);
        let l = loads(&[0, 0, 0, 0]);
        let picks: Vec<usize> = (0..4).map(|t| r.pick(&block(t), &l)).collect();
        assert_eq!(picks, [0, 1, 2, 3], "distinct first blocks spread the fleet");
    }

    #[test]
    fn prefix_affinity_evicts_oldest_keys_at_capacity() {
        let mut r = Router::new(RouterPolicy::PrefixAffinity, 2, BT, 1).with_max_keys(3);
        let l = loads(&[0, 0]);
        assert_eq!(r.pick(&block(0), &l), 0);
        assert_eq!(r.pick(&block(1), &l), 1);
        assert_eq!(r.pick(&block(2), &l), 0);
        assert_eq!(r.affinity_keys(), 3);
        assert_eq!(r.pick(&block(0), &l), 0, "sticky while resident");
        // A 4th distinct key evicts the oldest (block 0's key, replica 0,
        // counters [2,1] → [1,1]) and first-touches by balance → 0.
        assert_eq!(r.pick(&block(3), &l), 0);
        assert_eq!(r.affinity_keys(), 3, "capacity bound holds");
        // The forgotten key re-places by first touch: evicting block 1's
        // key leaves counters [2,0], so it lands on replica 1 now.
        assert_eq!(r.pick(&block(0), &l), 1);
        assert_eq!(r.affinity_keys(), 3);
        // …and is sticky again at its new home, regardless of load.
        assert_eq!(r.pick(&block(0), &loads(&[9_999, 0])), 1);
    }

    #[test]
    #[should_panic(expected = "load view")]
    fn mismatched_load_view_is_a_bug() {
        let mut r = Router::new(RouterPolicy::RoundRobin, 2, BT, 4);
        r.pick(&block(1), &loads(&[0]));
    }
}

//! Fleet-wide observability: per-replica snapshots and the merged
//! [`ClusterStats`] the router's `{"stats": true}` probe reports.
//!
//! Percentiles (latency / TTFT / TPOT) come from a [`MetricsCollector`]
//! merged at probe time out of the per-replica wait-free recorders
//! ([`super::accounting`]) the replica threads record completions into;
//! counter-like fields (pool occupancy, prefix-cache and preemption
//! counters, modeled device time) are summed across replicas. Each
//! replica's counters are engine-local — merging never nets requests
//! against each other, so fleet sums equal what a single probe of every
//! replica would add up to.

use crate::coordinator::{Engine, EngineStats};
use crate::kvcache::SwapBackend;
use crate::metrics::{
    percentile_fields, MetricsCollector, Percentiles, PrefixCacheSummary, PreemptionSummary,
    TelemetrySummary, LATENCY_PCTL_KEYS, TPOT_PCTL_KEYS, TTFT_PCTL_KEYS,
};
use crate::util::json::{arr, obj, Json};

/// One replica's state at probe (or shutdown) time.
#[derive(Debug, Clone)]
pub struct ReplicaSnapshot {
    pub id: usize,
    /// Human-readable identity, e.g. `W4A16KV8@A100`.
    pub label: String,
    /// The pool's *current* per-layer KV layout (`kv16` or
    /// `l0:kv16,l1:kv8,…`) — under auto laddering this can be narrower
    /// than the admission layout the replica spec configured.
    pub kv_layout: String,
    /// Generation requests this replica *answered* (aborted and rejected
    /// answers included, so per-replica sums equal the requests routed
    /// in; filter on `FinishReason` for success counts, as
    /// [`super::FleetRun::completed`] does).
    pub completed: usize,
    /// Requests dispatched to this replica and not yet answered (queued +
    /// in flight).
    pub outstanding_reqs: usize,
    /// Reserved token footprint (prompt + budget) of those requests.
    pub outstanding_tokens: usize,
    pub stats: EngineStats,
    pub pool_total_blocks: usize,
    pub pool_free_blocks: usize,
    /// Blocks the prefix index keeps resident (0 with the cache off) —
    /// at drain, `pool_total − pool_free` must equal exactly this.
    pub prefix_resident_blocks: usize,
    /// None when this replica's prefix cache is disabled.
    pub prefix: Option<PrefixCacheSummary>,
    pub preempt: PreemptionSummary,
    pub swap_blocks_used: usize,
    pub swap_budget_blocks: usize,
    /// Precision-attributed byte telemetry (per-rung gather/transcode/swap
    /// traffic + resident-layer occupancy) — fleet views merge these
    /// element-wise, so per-rung sums stay exact.
    pub telemetry: TelemetrySummary,
}

impl ReplicaSnapshot {
    /// Snapshot a live engine (runs on the replica's own thread).
    pub fn of(
        id: usize,
        label: &str,
        engine: &Engine,
        completed: usize,
        outstanding_reqs: usize,
        outstanding_tokens: usize,
    ) -> Self {
        Self {
            id,
            label: label.to_string(),
            kv_layout: engine.kv_pool().layout().to_string(),
            completed,
            outstanding_reqs,
            outstanding_tokens,
            stats: engine.stats.clone(),
            pool_total_blocks: engine.kv_pool().total_blocks(),
            pool_free_blocks: engine.kv_pool().free_blocks(),
            prefix_resident_blocks: engine.prefix_cached_blocks(),
            prefix: engine.prefix_cache_summary(),
            preempt: engine.preemption_summary(),
            swap_blocks_used: engine.swap_store().used_blocks(),
            swap_budget_blocks: engine.swap_store().budget_blocks(),
            telemetry: engine.telemetry(),
        }
    }

    pub fn pool_utilization(&self) -> f64 {
        if self.pool_total_blocks == 0 {
            0.0
        } else {
            (self.pool_total_blocks - self.pool_free_blocks) as f64
                / self.pool_total_blocks as f64
        }
    }

    fn to_json(&self) -> Json {
        let p = self.prefix.unwrap_or_default();
        obj([
            ("id", Json::from(self.id)),
            ("label", Json::from(self.label.as_str())),
            ("kv_layout", Json::from(self.kv_layout.as_str())),
            ("completed", Json::from(self.completed)),
            ("outstanding_reqs", Json::from(self.outstanding_reqs)),
            ("outstanding_tokens", Json::from(self.outstanding_tokens)),
            ("pool_utilization", Json::from(self.pool_utilization())),
            ("prefix_cache_enabled", Json::from(self.prefix.is_some())),
            ("prefix_cache_hit_rate", Json::from(p.hit_rate())),
            ("prefill_tokens_skipped", Json::from(p.prefill_tokens_skipped)),
            ("tokens_generated", Json::from(self.stats.tokens_generated)),
            // Host swap-store occupancy, unconditionally: `used` is
            // meaningful whether or not a budget bounds it; utilization
            // is `null` when unbounded (no denominator — a fake 0 would
            // hide host pressure).
            ("swap_blocks_used", Json::from(self.swap_blocks_used)),
            ("swap_budget_blocks", Json::from(self.swap_budget_blocks)),
            (
                "swap_utilization",
                if self.swap_budget_blocks == 0 {
                    Json::Null
                } else {
                    Json::from(
                        self.swap_blocks_used as f64 / self.swap_budget_blocks as f64,
                    )
                },
            ),
            ("preemptions", Json::from(self.preempt.preemptions)),
            ("ladder_events", Json::from(self.preempt.ladder_events)),
            ("ladder_preemptions", Json::from(self.preempt.ladder_preemptions)),
            ("ladder_freed_bytes", Json::from(self.preempt.ladder_freed_bytes)),
            ("oom_aborts", Json::from(self.preempt.oom_aborts)),
            ("sim_time_s", Json::from(self.stats.sim_time_s)),
            ("gather_hbm_bytes", Json::from(self.stats.gather_hbm_bytes)),
            ("padded_slots", Json::from(self.stats.padded_slots)),
            // Host-global page-file store (all zero without `--store-path`).
            ("store_prefix_hits", Json::from(self.stats.store_prefix_hits)),
            (
                "store_prefix_hit_tokens",
                Json::from(self.stats.store_prefix_hit_tokens),
            ),
            (
                "store_published_blocks",
                Json::from(self.stats.store_published_blocks),
            ),
            (
                "store_disk_bytes",
                Json::from(self.stats.store_disk_bytes_by_rung.iter().sum::<usize>()),
            ),
            ("telemetry", self.telemetry.to_json()),
        ])
    }
}

/// Sum prefix-cache summaries across replicas (disabled replicas
/// contribute zeros).
pub fn merge_prefix<'a>(
    snaps: impl IntoIterator<Item = &'a ReplicaSnapshot>,
) -> PrefixCacheSummary {
    let mut m = PrefixCacheSummary::default();
    for s in snaps {
        let p = s.prefix.unwrap_or_default();
        m.lookups += p.lookups;
        m.hits += p.hits;
        m.blocks_saved += p.blocks_saved;
        m.prefill_tokens_skipped += p.prefill_tokens_skipped;
        m.evicted_blocks += p.evicted_blocks;
        m.invalidated_blocks += p.invalidated_blocks;
    }
    m
}

/// Sum precision-attributed telemetry across replicas. Element-wise, so
/// every per-rung fleet bucket equals the sum of the per-replica buckets
/// regardless of merge order.
pub fn merge_telemetry<'a>(
    snaps: impl IntoIterator<Item = &'a ReplicaSnapshot>,
) -> TelemetrySummary {
    let mut m = TelemetrySummary::default();
    for s in snaps {
        m.merge(&s.telemetry);
    }
    m
}

/// The merged fleet view.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub policy: String,
    pub replicas: Vec<ReplicaSnapshot>,
    /// Completed-request series across the whole fleet (wall clock for the
    /// live cluster; modeled clock for offline fleet runs).
    pub latency: Option<Percentiles>,
    pub ttft: Option<Percentiles>,
    pub tpot: Option<Percentiles>,
    pub completed: usize,
}

impl ClusterStats {
    pub fn new(policy: String, replicas: Vec<ReplicaSnapshot>, fleet: &MetricsCollector) -> Self {
        Self {
            policy,
            latency: fleet.latency_percentiles(),
            ttft: fleet.ttft_percentiles(),
            tpot: fleet.tpot_percentiles(),
            completed: fleet.count(),
            replicas,
        }
    }

    /// Fleet prefix-cache effectiveness (sums over replicas).
    pub fn fleet_prefix(&self) -> PrefixCacheSummary {
        merge_prefix(&self.replicas)
    }

    /// Fleet precision-attributed telemetry (element-wise sums).
    pub fn fleet_telemetry(&self) -> TelemetrySummary {
        merge_telemetry(&self.replicas)
    }

    /// Fraction of fleet admissions served at least one resident block.
    pub fn fleet_hit_rate(&self) -> f64 {
        self.fleet_prefix().hit_rate()
    }

    pub fn fleet_tokens_generated(&self) -> usize {
        self.replicas.iter().map(|r| r.stats.tokens_generated).sum()
    }

    /// Admissions anywhere in the fleet that adopted a prefix chain from
    /// the shared page-file store (0 without one configured).
    pub fn fleet_store_prefix_hits(&self) -> usize {
        self.replicas.iter().map(|r| r.stats.store_prefix_hits).sum()
    }

    /// Prompt tokens those adoptions skipped re-prefilling.
    pub fn fleet_store_prefix_hit_tokens(&self) -> usize {
        self.replicas.iter().map(|r| r.stats.store_prefix_hit_tokens).sum()
    }

    /// Prefix blocks the fleet published into the shared store.
    pub fn fleet_store_published_blocks(&self) -> usize {
        self.replicas.iter().map(|r| r.stats.store_published_blocks).sum()
    }

    /// Requests still queued or in flight anywhere in the fleet.
    pub fn fleet_outstanding_reqs(&self) -> usize {
        self.replicas.iter().map(|r| r.outstanding_reqs).sum()
    }

    /// The probe line: fleet aggregates + a per-replica breakdown.
    pub fn to_json(&self) -> Json {
        let pfx = self.fleet_prefix();
        let mut fields = vec![
            ("cluster", Json::from(true)),
            ("policy", Json::from(self.policy.as_str())),
            ("replicas", Json::from(self.replicas.len())),
            ("completed_requests", Json::from(self.completed)),
            ("outstanding_requests", Json::from(self.fleet_outstanding_reqs())),
            ("fleet_tokens_generated", Json::from(self.fleet_tokens_generated())),
            ("fleet_prefix_hit_rate", Json::from(pfx.hit_rate())),
            ("fleet_prefill_tokens_skipped", Json::from(pfx.prefill_tokens_skipped)),
            (
                "fleet_preemptions",
                Json::from(
                    self.replicas.iter().map(|r| r.preempt.preemptions).sum::<usize>(),
                ),
            ),
            (
                "fleet_ladder_events",
                Json::from(
                    self.replicas.iter().map(|r| r.preempt.ladder_events).sum::<usize>(),
                ),
            ),
            (
                "fleet_ladder_transcoded_bytes",
                Json::from(
                    self.replicas
                        .iter()
                        .map(|r| r.preempt.ladder_transcoded_bytes)
                        .sum::<usize>(),
                ),
            ),
            (
                "fleet_ladder_freed_bytes",
                Json::from(
                    self.replicas.iter().map(|r| r.preempt.ladder_freed_bytes).sum::<usize>(),
                ),
            ),
            (
                "fleet_oom_aborts",
                Json::from(self.replicas.iter().map(|r| r.preempt.oom_aborts).sum::<usize>()),
            ),
            (
                "fleet_gather_hbm_bytes",
                Json::from(
                    self.replicas.iter().map(|r| r.stats.gather_hbm_bytes).sum::<usize>(),
                ),
            ),
            (
                "fleet_padded_slots",
                Json::from(self.replicas.iter().map(|r| r.stats.padded_slots).sum::<usize>()),
            ),
            ("fleet_store_prefix_hits", Json::from(self.fleet_store_prefix_hits())),
            (
                "fleet_store_prefix_hit_tokens",
                Json::from(self.fleet_store_prefix_hit_tokens()),
            ),
            (
                "fleet_store_published_blocks",
                Json::from(self.fleet_store_published_blocks()),
            ),
            ("telemetry", self.fleet_telemetry().to_json()),
        ];
        fields.extend(percentile_fields(LATENCY_PCTL_KEYS, self.latency));
        fields.extend(percentile_fields(TTFT_PCTL_KEYS, self.ttft));
        fields.extend(percentile_fields(TPOT_PCTL_KEYS, self.tpot));
        let mut json = obj(fields);
        if let Json::Obj(m) = &mut json {
            m.insert(
                "per_replica".into(),
                arr(self.replicas.iter().map(ReplicaSnapshot::to_json)),
            );
        }
        json
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;

    fn snap(id: usize, hits: usize, lookups: usize) -> ReplicaSnapshot {
        let engine = Engine::new(EngineConfig::default()).unwrap();
        let mut s = ReplicaSnapshot::of(id, "W4A16KV8@A100", &engine, 3, 1, 40);
        s.prefix = Some(PrefixCacheSummary {
            lookups,
            hits,
            blocks_saved: hits,
            prefill_tokens_skipped: hits * 16,
            evicted_blocks: 0,
            invalidated_blocks: hits / 2,
        });
        s
    }

    #[test]
    fn fleet_prefix_sums_across_replicas() {
        let a = snap(0, 3, 4);
        let b = snap(1, 1, 4);
        let m = merge_prefix([&a, &b]);
        assert_eq!((m.hits, m.lookups), (4, 8));
        assert!((m.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(m.prefill_tokens_skipped, 64);
        // `merge_prefix` must carry *every* summary field — this one
        // silently dropped `invalidated_blocks` before the telemetry work.
        assert_eq!(m.invalidated_blocks, 1);
    }

    #[test]
    fn fleet_telemetry_merges_element_wise() {
        let mut a = snap(0, 0, 0);
        a.telemetry.gather_hbm_bytes_by_rung = [10, 20, 30];
        a.telemetry.swap_pcie_bytes_by_rung = [1, 0, 2];
        a.telemetry.occupancy_layers_by_rung = [2, 2, 0];
        let mut b = snap(1, 0, 0);
        b.telemetry.gather_hbm_bytes_by_rung = [5, 0, 1];
        b.telemetry.transcode_bytes_by_rung = [0, 7, 0];
        b.telemetry.occupancy_layers_by_rung = [0, 4, 0];
        let ab = merge_telemetry([&a, &b]);
        let ba = merge_telemetry([&b, &a]);
        assert_eq!(ab, ba, "merge order never changes totals");
        assert_eq!(ab.gather_hbm_bytes_by_rung, [15, 20, 31]);
        assert_eq!(ab.transcode_bytes_by_rung, [0, 7, 0]);
        assert_eq!(ab.swap_pcie_bytes_by_rung, [1, 0, 2]);
        assert_eq!(ab.occupancy_layers_by_rung, [2, 6, 0]);
        assert_eq!(ab.gather_hbm_bytes(), 66);
    }

    #[test]
    fn cluster_stats_json_round_trips() {
        let mut fleet = MetricsCollector::new();
        fleet.record(1.0, 0.25, 1.0, 32, 4);
        fleet.record(2.0, 0.5, 2.0, 32, 4);
        let cs = ClusterStats::new(
            "prefix_affinity".into(),
            vec![snap(0, 3, 4), snap(1, 1, 4)],
            &fleet,
        );
        let parsed = Json::parse(&cs.to_json().dump()).unwrap();
        assert_eq!(parsed.get("cluster").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.req_usize("replicas").unwrap(), 2);
        assert_eq!(parsed.req_str("policy").unwrap(), "prefix_affinity");
        assert_eq!(parsed.req_usize("completed_requests").unwrap(), 2);
        assert_eq!(parsed.get("fleet_prefix_hit_rate").unwrap().as_f64(), Some(0.5));
        // Nearest-rank over two samples: p50 = smaller, p95/p99 = larger.
        assert_eq!(parsed.get("latency_p50_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(parsed.get("latency_p99_s").unwrap().as_f64(), Some(2.0));
        assert_eq!(parsed.get("ttft_p95_s").unwrap().as_f64(), Some(0.5));
        // TPOT: (1.0−0.25)/3 and (2.0−0.5)/3.
        assert_eq!(parsed.get("tpot_p50_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(parsed.req_arr("per_replica").unwrap().len(), 2);
        let r0 = &parsed.req_arr("per_replica").unwrap()[0];
        assert_eq!(r0.req_str("label").unwrap(), "W4A16KV8@A100");
        assert_eq!(r0.req_usize("completed").unwrap(), 3);
        assert_eq!(r0.req_usize("outstanding_tokens").unwrap(), 40);
        // Default engine: uniform kv8 admission layout, no ladder events.
        assert_eq!(r0.req_str("kv_layout").unwrap(), "kv8");
        assert_eq!(r0.req_usize("ladder_events").unwrap(), 0);
        // Swap occupancy rides every replica row; utilization is null for
        // the default unbounded budget rather than a fake 0.
        assert_eq!(r0.req_usize("swap_blocks_used").unwrap(), 0);
        assert_eq!(r0.req_usize("swap_budget_blocks").unwrap(), 0);
        assert_eq!(r0.get("swap_utilization"), Some(&Json::Null));
        assert_eq!(parsed.req_usize("fleet_ladder_events").unwrap(), 0);
        assert_eq!(parsed.req_usize("fleet_ladder_freed_bytes").unwrap(), 0);
        // Satellite telemetry fields round-trip at both tiers.
        assert_eq!(parsed.req_usize("fleet_gather_hbm_bytes").unwrap(), 0);
        assert_eq!(parsed.req_usize("fleet_padded_slots").unwrap(), 0);
        assert_eq!(r0.req_usize("gather_hbm_bytes").unwrap(), 0);
        assert_eq!(r0.req_usize("padded_slots").unwrap(), 0);
        let tel = parsed.get("telemetry").expect("fleet telemetry object");
        assert_eq!(tel.req_arr("rungs").unwrap().len(), 3);
        // Fleet occupancy = 2 replicas × default engine's kv8 layers.
        let occ = tel.req_arr("occupancy_layers_by_rung").unwrap();
        assert_eq!(occ[0].as_usize(), Some(0), "no kv16 layers in a kv8 fleet");
        assert!(occ[1].as_usize().unwrap() > 0, "kv8 layers counted twice over");
        let rtel = r0.get("telemetry").expect("per-replica telemetry object");
        assert_eq!(
            occ[1].as_usize().unwrap(),
            2 * rtel.req_arr("occupancy_layers_by_rung").unwrap()[1].as_usize().unwrap(),
            "fleet histogram sums the replicas"
        );
    }

}

//! Precision-heterogeneous multi-replica serving: a router tier over N
//! engine replicas (DESIGN.md §9).
//!
//! The paper's core observation is that the best mixed-precision format
//! is *device-specific* — a real deployment therefore runs a fleet where
//! each replica serves the format its hardware likes, and a router above
//! them spreads traffic. This module is that tier:
//!
//! * [`ReplicaSpec`] — per-replica `(PrecisionFormat, DeviceProfile, tp)`
//!   plus optional per-layer KV layout / ladder-policy overrides;
//! * [`ReplicaHandle`] — one engine per replica on its own thread behind a
//!   bounded inbox (backpressure at the router boundary);
//! * [`Router`] / [`RouterPolicy`] — `round_robin`, `least_loaded` (by
//!   outstanding tokens), `prefix_affinity` (chain-hash prompt blocks,
//!   stick sessions to the replica holding their prefix blocks);
//! * [`ClusterStats`] — fleet-merged counters + latency/TTFT/TPOT
//!   percentiles;
//! * [`Cluster`] — the live threaded fleet `server::serve_cluster` fronts;
//! * [`run_fleet`] — the deterministic closed-loop runner (`bench router`,
//!   determinism tests): routes a whole request set first, then drives
//!   each replica's engine to completion on the caller's thread, so
//!   modeled per-request times are replayable bit-for-bit;
//! * [`run_disagg`] — the disaggregated prefill/decode variant: two
//!   replica tiers with layout-tagged cross-replica KV migration between
//!   them (see [`disagg`]).
//!
//! Replicas share one `seed`, so a request produces **bit-identical
//! tokens on any replica serving the same precision** — routing is purely
//! a performance decision, never a correctness one (the heterogeneous
//! caveat: replicas at *different* precisions legitimately decode
//! different tokens, exactly like the paper's per-format accuracy story).

pub mod accounting;
pub mod disagg;
pub mod replica;
pub mod router;
pub mod stats;

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

pub use accounting::ReplicaRecorder;
pub use disagg::{migrate_all, run_disagg, DisaggConfig, DisaggOutput, DisaggRun};
pub use replica::{request_cost, ReplicaHandle, ReplicaLoad, ReplicaSpec, ToReplica};
pub use router::{LoadView, Router, RouterPolicy};
pub use stats::{merge_prefix, merge_telemetry, ClusterStats, ReplicaSnapshot};

use crate::config::EngineConfig;
use crate::coordinator::{Engine, FinishReason, Request, RequestOutput};
use crate::metrics::MetricsCollector;
use crate::trace::TraceDump;
use crate::util::json::{arr, obj, Json};

/// Fleet configuration: a base engine config every replica inherits
/// (pool geometry, chunking, cache/preemption knobs, seed) plus the
/// per-replica heterogeneity specs and the routing policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub base: EngineConfig,
    pub specs: Vec<ReplicaSpec>,
    pub policy: RouterPolicy,
    /// Bounded inbox depth per replica; a full inbox blocks dispatch.
    pub queue_depth: usize,
    /// Prompt blocks the `prefix_affinity` hash covers (see
    /// [`crate::kvcache::route_key`]).
    pub affinity_blocks: usize,
}

impl ClusterConfig {
    /// A homogeneous fleet: `n` replicas of the base config's precision
    /// and device.
    pub fn homogeneous(base: EngineConfig, n: usize, policy: RouterPolicy) -> Self {
        let spec = ReplicaSpec {
            precision: base.precision,
            device: base.device.clone(),
            tp: base.tp,
            kv_layout: None,
            ladder: None,
        };
        Self {
            base,
            specs: vec![spec; n.max(1)],
            policy,
            queue_depth: 64,
            affinity_blocks: 4,
        }
    }

    /// A heterogeneous fleet from explicit specs.
    pub fn heterogeneous(base: EngineConfig, specs: Vec<ReplicaSpec>, policy: RouterPolicy) -> Self {
        Self { base, specs, policy, queue_depth: 64, affinity_blocks: 4 }
    }

    pub fn n_replicas(&self) -> usize {
        self.specs.len()
    }

    /// The engine config replica `i` runs.
    pub fn engine_config(&self, i: usize) -> EngineConfig {
        self.specs[i].engine_config(&self.base)
    }

    pub fn validate(&self) -> Result<()> {
        if self.specs.is_empty() {
            bail!("cluster needs at least one replica");
        }
        if self.queue_depth == 0 {
            bail!("queue_depth must be > 0");
        }
        if self.affinity_blocks == 0 {
            bail!("affinity_blocks must be > 0");
        }
        for (i, _) in self.specs.iter().enumerate() {
            self.engine_config(i)
                .validate()
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("replica {i} config"))?;
        }
        Ok(())
    }
}

/// How long a fleet stats probe waits, in total, for replica answers.
/// Replicas answer between engine iterations, so healthy fleets respond
/// in microseconds; the deadline only matters when a replica is wedged.
const STATS_PROBE_DEADLINE: Duration = Duration::from_millis(250);

/// The live, threaded fleet.
pub struct Cluster {
    replicas: Vec<ReplicaHandle>,
    router: Router,
    /// Per-replica wait-free completion recorders (same order as
    /// `replicas`); merged only at probe time — the serving hot path
    /// never takes a fleet-wide lock.
    recorders: Vec<Arc<ReplicaRecorder>>,
    policy: RouterPolicy,
}

impl Cluster {
    /// Spawn every replica (each builds its engine on its own thread).
    pub fn start(cfg: ClusterConfig) -> Result<Self> {
        cfg.validate()?;
        let started = Instant::now();
        let mut replicas = Vec::with_capacity(cfg.n_replicas());
        let mut recorders = Vec::with_capacity(cfg.n_replicas());
        for i in 0..cfg.n_replicas() {
            let recorder = Arc::new(ReplicaRecorder::new());
            replicas.push(ReplicaHandle::spawn(
                i,
                cfg.engine_config(i),
                cfg.specs[i].label(),
                cfg.queue_depth,
                Arc::clone(&recorder),
                started,
            )?);
            recorders.push(recorder);
        }
        let router = Router::new(
            cfg.policy,
            cfg.n_replicas(),
            cfg.base.kv_block_tokens,
            cfg.affinity_blocks,
        );
        Ok(Self { replicas, router, recorders, policy: cfg.policy })
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Route `req` by policy and dispatch it; the reply arrives on
    /// `reply`. Blocks when the chosen replica's inbox is full.
    pub fn submit_with(&mut self, req: Request, reply: Sender<RequestOutput>) -> Result<usize> {
        let loads: Vec<LoadView> = self
            .replicas
            .iter()
            .map(|r| LoadView { reqs: r.load().reqs(), tokens: r.load().tokens() })
            .collect();
        let idx = self.router.pick(&req.prompt, &loads);
        self.dispatch_to(idx, req, reply)?;
        Ok(idx)
    }

    /// Route and dispatch, returning the receiver end (convenience).
    pub fn submit(&mut self, req: Request) -> Result<(usize, Receiver<RequestOutput>)> {
        let (tx, rx) = mpsc::channel();
        let idx = self.submit_with(req, tx)?;
        Ok((idx, rx))
    }

    /// Dispatch to a specific replica, bypassing the policy (tests, and
    /// the cross-replica determinism proof).
    pub fn dispatch_to(
        &self,
        idx: usize,
        req: Request,
        reply: Sender<RequestOutput>,
    ) -> Result<()> {
        let cost = request_cost(&req);
        let r = &self.replicas[idx];
        r.load().start(cost);
        if let Err(e) = r.send(ToReplica::Gen { req, reply }) {
            r.load().finish(cost);
            return Err(e);
        }
        Ok(())
    }

    /// Probe every replica and merge the fleet view. Two-phase: **all**
    /// probes are fired first (non-blocking `try_send`), then answers are
    /// collected against one shared deadline — a wedged or slow-draining
    /// replica costs at most the deadline, and never serializes behind
    /// its neighbors. A dead, saturated, or unresponsive replica is
    /// *omitted* from the per-replica list rather than failing the probe
    /// — monitoring must degrade, not take the surviving fleet down;
    /// compare the list length against `n_replicas` to detect the gap.
    /// Percentiles come from the wait-free recorders, so the probe takes
    /// no lock the serving path could be holding.
    pub fn stats(&self) -> Result<ClusterStats> {
        let probes: Vec<(usize, Result<std::sync::mpsc::Receiver<ReplicaSnapshot>>)> =
            self.replicas.iter().map(|r| (r.id, r.probe())).collect();
        let deadline = Instant::now() + STATS_PROBE_DEADLINE;
        let mut snaps = Vec::with_capacity(self.replicas.len());
        for (id, probe) in probes {
            let answer = probe.and_then(|rx| {
                let left = deadline.saturating_duration_since(Instant::now());
                rx.recv_timeout(left)
                    .map_err(|e| anyhow::anyhow!("replica {id} stats probe: {e}"))
            });
            match answer {
                Ok(s) => snaps.push(s),
                Err(e) => eprintln!("stats probe skipping replica {id}: {e}"),
            }
        }
        let (merged, exact, torn) = accounting::collect(&self.recorders);
        if torn > 0 {
            eprintln!("stats probe: {torn} sample slot(s) overwritten mid-read; skipped");
        }
        let mut cs = ClusterStats::new(self.policy.to_string(), snaps, &merged);
        // The ring windows percentile samples; the completion counters
        // never window. Report the exact fleet count.
        cs.completed = exact;
        Ok(cs)
    }

    /// Probe every replica's flight-recorder ring and merge the answers:
    /// `{"trace": {"cluster": true, "replicas": [...]}}`, one entry per
    /// responding replica (id, label, enabled, recorded/dropped/torn
    /// counters, events). Same two-phase fire-then-collect shape as
    /// [`stats`](Self::stats): a wedged replica costs at most the shared
    /// deadline and is omitted, never propagated as a probe failure.
    pub fn trace(&self, last: usize) -> Result<Json> {
        let probes: Vec<(usize, Result<Receiver<Json>>)> =
            self.replicas.iter().map(|r| (r.id, r.trace_probe(last))).collect();
        let deadline = Instant::now() + STATS_PROBE_DEADLINE;
        let mut entries = Vec::with_capacity(self.replicas.len());
        for (id, probe) in probes {
            let answer = probe.and_then(|rx| {
                let left = deadline.saturating_duration_since(Instant::now());
                rx.recv_timeout(left)
                    .map_err(|e| anyhow::anyhow!("replica {id} trace probe: {e}"))
            });
            match answer {
                Ok(j) => entries.push(j),
                Err(e) => eprintln!("trace probe skipping replica {id}: {e}"),
            }
        }
        Ok(obj([(
            "trace",
            obj([("cluster", Json::from(true)), ("replicas", arr(entries))]),
        )]))
    }

    /// Close every inbox, wait for replicas to drain outstanding work,
    /// and return their final snapshots.
    pub fn shutdown(self) -> Result<Vec<ReplicaSnapshot>> {
        self.replicas.into_iter().map(ReplicaHandle::join).collect()
    }
}

/// One routed request's outcome in an offline fleet run.
#[derive(Debug, Clone)]
pub struct RoutedOutput {
    /// Index into the submitted request slice.
    pub request: usize,
    /// Replica that served it.
    pub replica: usize,
    pub output: RequestOutput,
}

/// Result of [`run_fleet`].
#[derive(Debug)]
pub struct FleetRun {
    pub assignments: Vec<usize>,
    pub outputs: Vec<RoutedOutput>,
    pub snapshots: Vec<ReplicaSnapshot>,
    pub policy: RouterPolicy,
    /// Per-replica `(label, flight-recorder dump)` in replica order —
    /// empty dumps when the base config leaves tracing off. The labels
    /// become Chrome-trace track names ([`crate::trace::TraceTrack`]).
    pub traces: Vec<(String, TraceDump)>,
}

impl FleetRun {
    /// Requests that finished without aborting.
    pub fn completed(&self) -> usize {
        self.outputs.iter().filter(|o| o.output.finish != FinishReason::Aborted).count()
    }

    /// Fleet prefix-cache effectiveness (sums over replicas).
    pub fn fleet_prefix(&self) -> crate::metrics::PrefixCacheSummary {
        merge_prefix(&self.snapshots)
    }

    /// Fleet precision-attributed telemetry (element-wise sums).
    pub fn fleet_telemetry(&self) -> crate::metrics::TelemetrySummary {
        merge_telemetry(&self.snapshots)
    }

    /// Chrome-trace tracks over the per-replica dumps (one track per
    /// replica, `tid` = replica index), ready for
    /// [`crate::trace::write_chrome`].
    pub fn trace_tracks(&self) -> Vec<crate::trace::TraceTrack<'_>> {
        self.traces
            .iter()
            .enumerate()
            .map(|(i, (label, dump))| crate::trace::TraceTrack {
                tid: i,
                label: label.clone(),
                dump,
            })
            .collect()
    }

    /// Modeled completion metrics on each replica's device clock: replicas
    /// run in parallel in a real fleet, so per-request durations merge
    /// while the fleet makespan is the slowest replica's clock. Successes
    /// only — an aborted answer's near-zero modeled latency would reward
    /// the policy that sheds the most load (same filter as the live
    /// metric-recording sites).
    pub fn sim_metrics(&self) -> MetricsCollector {
        let mut m = MetricsCollector::new();
        for o in &self.outputs {
            if o.output.finish == FinishReason::Aborted {
                continue;
            }
            m.record(
                o.output.latency_sim,
                o.output.ttft_sim,
                o.output.latency_sim,
                o.output.prompt_len,
                o.output.tokens.len(),
            );
        }
        m
    }

    /// The slowest replica's modeled device time — the fleet's makespan.
    pub fn sim_makespan_s(&self) -> f64 {
        self.snapshots.iter().map(|s| s.stats.sim_time_s).fold(0.0, f64::max)
    }

    /// Generated tokens per modeled fleet second.
    pub fn sim_token_throughput(&self) -> f64 {
        let toks: usize = self.snapshots.iter().map(|s| s.stats.tokens_generated).sum();
        let t = self.sim_makespan_s();
        if t > 0.0 {
            toks as f64 / t
        } else {
            0.0
        }
    }
}

/// Deterministic closed-loop fleet run: route the entire request set by
/// policy (for `least_loaded`, load = tokens *assigned* so far — the
/// static proxy, since nothing completes during assignment), then build
/// each replica's engine on this thread, submit its share in arrival
/// order, and run it to completion. No threads, no timing races: the same
/// `(config, requests)` always yields byte-identical outputs, which is
/// what lets `bench router` *assert* policy orderings instead of
/// eyeballing them.
///
/// When `cfg.base.store` is set, every replica shares that one page-file
/// store (the `Arc` rides the config clone), so replica *i*+1 adopts the
/// prefix blocks replica *i* published — and because replicas build and
/// run sequentially here, the store's evolution (publications, adoptions,
/// LRU order) is deterministic too. The threaded [`Cluster`] can share a
/// store the same way, but its publication *order* then depends on thread
/// interleaving; block contents stay byte-exact either way, so outputs
/// remain bit-identical.
pub fn run_fleet(cfg: &ClusterConfig, requests: &[Request]) -> Result<FleetRun> {
    cfg.validate()?;
    let n = cfg.n_replicas();
    let mut router =
        Router::new(cfg.policy, n, cfg.base.kv_block_tokens, cfg.affinity_blocks);
    let mut assigned = vec![LoadView::default(); n];
    let mut assignments = Vec::with_capacity(requests.len());
    for req in requests {
        let i = router.pick(&req.prompt, &assigned);
        assigned[i].reqs += 1;
        assigned[i].tokens += request_cost(req);
        assignments.push(i);
    }

    let mut outputs = Vec::with_capacity(requests.len());
    let mut snapshots = Vec::with_capacity(n);
    let mut traces = Vec::with_capacity(n);
    for i in 0..n {
        let mut engine =
            Engine::new(cfg.engine_config(i)).with_context(|| format!("replica {i}"))?;
        // Engine-assigned ids are 0.. per replica in submission order.
        let mine: Vec<usize> =
            (0..requests.len()).filter(|&g| assignments[g] == i).collect();
        let mut id_to_global = std::collections::HashMap::new();
        for &g in &mine {
            // Mirror the live replica loop: an engine-rejected request is
            // answered as a rejection, never a hard error that would lose
            // the rest of the run.
            match engine.submit(requests[g].clone()) {
                Ok(id) => {
                    id_to_global.insert(id, g);
                }
                Err(e) => outputs.push(RoutedOutput {
                    request: g,
                    replica: i,
                    output: RequestOutput::rejected(e.to_string()),
                }),
            }
        }
        for out in engine.run_to_completion()? {
            let g = id_to_global[&out.id];
            outputs.push(RoutedOutput { request: g, replica: i, output: out });
        }
        // Submit-time aborts surface via take_outputs inside
        // run_to_completion too, so every submitted request is accounted.
        snapshots.push(ReplicaSnapshot::of(i, &cfg.specs[i].label(), &engine, mine.len(), 0, 0));
        traces.push((cfg.specs[i].label(), engine.trace_dump()));
    }
    outputs.sort_by_key(|o| o.request);
    Ok(FleetRun { assignments, outputs, snapshots, policy: cfg.policy, traces })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::MultiTenantGen;

    fn base() -> EngineConfig {
        EngineConfig {
            kv_pool_tokens: 16 * 64,
            prefill_chunk: 32,
            enable_prefix_cache: true,
            ..EngineConfig::default()
        }
    }

    fn tenant_requests(g: &MultiTenantGen, vocab: usize) -> Vec<Request> {
        g.generate()
            .iter()
            .enumerate()
            .map(|(i, r)| Request::new(g.prompt_tokens(i, vocab), r.gen_tokens))
            .collect()
    }

    #[test]
    fn config_validation() {
        let cfg = ClusterConfig::homogeneous(base(), 2, RouterPolicy::RoundRobin);
        cfg.validate().unwrap();
        let mut bad = cfg.clone();
        bad.specs.clear();
        assert!(bad.validate().is_err());
        let mut bad = cfg.clone();
        bad.queue_depth = 0;
        assert!(bad.validate().is_err());
        let mut bad = cfg;
        bad.specs[1].device = "B200".into();
        assert!(bad.validate().is_err(), "per-replica config errors surface");
    }

    #[test]
    fn run_fleet_is_deterministic_and_loses_nothing() {
        let g = MultiTenantGen {
            tenants: 2,
            users: 2,
            turns: 2,
            shared_tokens: 64,
            turn_tokens: 8,
            gen_tokens: 4,
            rate: 10.0,
            seed: 3,
        };
        let cfg = ClusterConfig::homogeneous(base(), 2, RouterPolicy::PrefixAffinity);
        let reqs = tenant_requests(&g, 2048);
        let a = run_fleet(&cfg, &reqs).unwrap();
        let b = run_fleet(&cfg, &reqs).unwrap();
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.outputs.len(), reqs.len(), "every request answered once");
        assert_eq!(a.completed(), reqs.len());
        for (x, y) in a.outputs.iter().zip(&b.outputs) {
            assert_eq!(x.output.tokens, y.output.tokens, "replayable outputs");
            assert_eq!(x.output.latency_sim, y.output.latency_sim, "replayable timing");
        }
        // Affinity keeps each tenant on one replica.
        for (gi, &rep) in a.assignments.iter().enumerate() {
            let (tenant, _, _) = g.locate(gi);
            assert_eq!(rep, a.assignments[tenant * g.users], "tenant {tenant} split");
        }
    }

    #[test]
    fn stats_probe_survives_wedged_replica_and_keeps_serving() {
        let cfg = ClusterConfig::homogeneous(base(), 1, RouterPolicy::RoundRobin);
        let mut c = Cluster::start(cfg).unwrap();
        // A replica that accepts probes but never answers them — a
        // deterministic slow/wedged drain. The old probe collected each
        // reply with a blocking `recv()` and would hang here forever.
        c.replicas.push(ReplicaHandle::spawn_unresponsive(1, 4));
        let t0 = Instant::now();
        let stats = c.stats().unwrap();
        assert!(
            t0.elapsed() < STATS_PROBE_DEADLINE + Duration::from_secs(5),
            "probe must bound its wait"
        );
        assert_eq!(stats.replicas.len(), 1, "wedged replica omitted, healthy one reported");
        // New submissions still flow while the wedged replica never
        // answers its probe.
        let (otx, orx) = mpsc::channel();
        c.dispatch_to(0, Request::new((0..8).collect(), 2), otx).unwrap();
        let out = orx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(out.tokens.len(), 2);
        // The completion landed in the wait-free recorder; the next probe
        // reports the exact count.
        let stats = c.stats().unwrap();
        assert_eq!(stats.completed, 1);
        // Closing the wedged inbox lets its thread exit; then drain the
        // real replica.
        drop(c.replicas.pop());
        c.shutdown().unwrap();
    }

    #[test]
    fn fleet_traces_collect_and_cluster_probe_answers() {
        let mut cfg = ClusterConfig::homogeneous(base(), 2, RouterPolicy::RoundRobin);
        cfg.base.trace = true;
        let reqs: Vec<Request> =
            (0..4).map(|i| Request::new(vec![(i * 17 % 512) as i32; 24], 3)).collect();
        let run = run_fleet(&cfg, &reqs).unwrap();
        assert_eq!(run.traces.len(), 2);
        for (label, dump) in &run.traces {
            assert!(!dump.events.is_empty(), "{label} traced nothing");
            assert_eq!(dump.dropped, 0);
        }
        // The per-replica dumps export as one multi-track Chrome trace.
        let json = crate::trace::chrome_trace(&run.trace_tracks());
        crate::trace::validate(&json).unwrap();

        // Same config live: the router-tier probe merges per-replica rings.
        let mut c = Cluster::start(cfg).unwrap();
        let (otx, orx) = mpsc::channel();
        c.dispatch_to(0, Request::new((0..8).collect(), 2), otx).unwrap();
        orx.recv().unwrap();
        let t = c.trace(0).unwrap();
        let body = t.get("trace").unwrap();
        assert_eq!(body.get("cluster").and_then(crate::util::json::Json::as_bool), Some(true));
        let reps = body.req_arr("replicas").unwrap();
        assert_eq!(reps.len(), 2, "both replicas answered");
        assert_eq!(reps[0].req_usize("id").unwrap(), 0);
        assert!(
            reps[0].req_arr("events").unwrap().len() >= 3,
            "dispatched replica recorded admit/work/finish"
        );
        assert_eq!(
            reps[1].req_arr("events").unwrap().len(),
            0,
            "idle replica's ring is empty, not missing"
        );
        c.shutdown().unwrap();
    }

    #[test]
    fn heterogeneous_fleet_serves_mixed_precisions() {
        let specs: Vec<ReplicaSpec> =
            vec!["w4a16,kv8,a100".parse().unwrap(), "w8a8,kv16,h100".parse().unwrap()];
        let cfg = ClusterConfig::heterogeneous(base(), specs, RouterPolicy::RoundRobin);
        let reqs: Vec<Request> =
            (0..6).map(|i| Request::new(vec![(i * 31 % 2048) as i32; 24], 4)).collect();
        let run = run_fleet(&cfg, &reqs).unwrap();
        assert_eq!(run.completed(), 6);
        assert_eq!(run.snapshots[0].label, "W4A16KV8@A100");
        assert_eq!(run.snapshots[1].label, "W8A8KV16@H100");
        // Both replicas actually worked (round robin splits 3/3).
        assert_eq!(run.assignments.iter().filter(|&&r| r == 0).count(), 3);
        for s in &run.snapshots {
            assert!(s.stats.tokens_generated > 0);
            assert!(s.stats.sim_time_s > 0.0);
        }
    }
}

//! Groupwise symmetric weight quantization (AWQ/GPTQ-style).
//!
//! A `[K, N]` weight matrix is split into groups of `group_size` consecutive
//! rows per output column; each group gets one FP scale chosen so the max
//! magnitude maps to the integer range. INT4 values are stored packed two
//! per byte (low nibble first) — the storage format the GEMM pipeline's
//! offline stage consumes.

use crate::config::DType;

/// Quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupwiseQuant {
    pub dtype: DType,
    /// Rows (along K) sharing one scale. Must divide K.
    pub group_size: usize,
}

impl GroupwiseQuant {
    pub fn int4(group_size: usize) -> Self {
        Self { dtype: DType::Int4, group_size }
    }

    pub fn int8(group_size: usize) -> Self {
        Self { dtype: DType::Int8, group_size }
    }
}

/// A quantized `[K, N]` matrix: integer codes + per-group scales.
#[derive(Debug, Clone)]
pub struct QuantizedMatrix {
    pub k: usize,
    pub n: usize,
    pub quant: GroupwiseQuant,
    /// Integer codes. INT8: `k*n` bytes (i8 as u8). INT4: `k*n/2` bytes,
    /// element `(r, c)` in the low nibble of byte `(r*n + c) / 2` when
    /// `(r*n + c)` even, high nibble otherwise (row-major element order).
    pub codes: Vec<u8>,
    /// Scales `[K/group_size, N]`, row-major.
    pub scales: Vec<f32>,
}

impl QuantizedMatrix {
    /// Quantize a row-major `[K, N]` f32 matrix.
    pub fn quantize(weights: &[f32], k: usize, n: usize, quant: GroupwiseQuant) -> Self {
        assert_eq!(weights.len(), k * n, "weight buffer size mismatch");
        assert!(quant.group_size > 0 && k % quant.group_size == 0, "group_size must divide K");
        let n_groups = k / quant.group_size;
        let qmax = quant.dtype.qmax() as f32;
        assert!(qmax > 0.0, "dtype {:?} is not integer-quantizable", quant.dtype);

        // Per-(group, col) max-abs → scale.
        let mut scales = vec![0f32; n_groups * n];
        for g in 0..n_groups {
            for c in 0..n {
                let mut maxabs = 0f32;
                for r in g * quant.group_size..(g + 1) * quant.group_size {
                    maxabs = maxabs.max(weights[r * n + c].abs());
                }
                scales[g * n + c] = if maxabs > 0.0 { maxabs / qmax } else { 1.0 };
            }
        }

        // Quantize codes.
        let total = k * n;
        let mut codes = vec![0u8; quant.dtype.bytes_for(total)];
        for r in 0..k {
            let g = r / quant.group_size;
            for c in 0..n {
                let s = scales[g * n + c];
                let q = (weights[r * n + c] / s).round().clamp(-qmax, qmax) as i8;
                let idx = r * n + c;
                match quant.dtype {
                    DType::Int8 => codes[idx] = q as u8,
                    DType::Int4 => {
                        let nib = (q as u8) & 0x0F;
                        if idx % 2 == 0 {
                            codes[idx / 2] |= nib;
                        } else {
                            codes[idx / 2] |= nib << 4;
                        }
                    }
                    _ => unreachable!(),
                }
            }
        }
        Self { k, n, quant, codes, scales }
    }

    /// Read the integer code at `(r, c)` as a signed value.
    #[inline]
    pub fn code_at(&self, r: usize, c: usize) -> i8 {
        let idx = r * self.n + c;
        match self.quant.dtype {
            DType::Int8 => self.codes[idx] as i8,
            DType::Int4 => {
                let byte = self.codes[idx / 2];
                let nib = if idx % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                sign_extend4(nib)
            }
            _ => unreachable!(),
        }
    }

    /// Scale applying to element `(r, c)`.
    #[inline]
    pub fn scale_at(&self, r: usize, c: usize) -> f32 {
        self.scales[(r / self.quant.group_size) * self.n + c]
    }

    /// Dequantize back to a dense `[K, N]` f32 matrix.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.k * self.n];
        for r in 0..self.k {
            for c in 0..self.n {
                out[r * self.n + c] = self.code_at(r, c) as f32 * self.scale_at(r, c);
            }
        }
        out
    }

    /// Worst-case absolute quantization error bound: half an LSB per group.
    pub fn error_bound(&self) -> f32 {
        self.scales.iter().fold(0f32, |m, s| m.max(*s)) * 0.5
    }

    /// Storage bytes (codes + scales).
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }
}

/// Sign-extend a 4-bit two's-complement nibble.
#[inline]
pub fn sign_extend4(nib: u8) -> i8 {
    let v = nib & 0x0F;
    if v & 0x08 != 0 {
        (v | 0xF0) as i8
    } else {
        v as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    fn make_weights(k: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..k * n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect()
    }

    #[test]
    fn sign_extend_cases() {
        assert_eq!(sign_extend4(0x0), 0);
        assert_eq!(sign_extend4(0x7), 7);
        assert_eq!(sign_extend4(0x8), -8);
        assert_eq!(sign_extend4(0xF), -1);
        assert_eq!(sign_extend4(0x9), -7);
    }

    #[test]
    fn int8_roundtrip_error_bounded() {
        let (k, n) = (64, 32);
        let w = make_weights(k, n, 1);
        let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int8(32));
        let dq = q.dequantize();
        let bound = q.error_bound() * 1.001;
        for (a, b) in w.iter().zip(&dq) {
            assert!((a - b).abs() <= bound, "{a} vs {b}, bound {bound}");
        }
    }

    #[test]
    fn int4_roundtrip_error_bounded() {
        let (k, n) = (128, 16);
        let w = make_weights(k, n, 2);
        let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(64));
        let dq = q.dequantize();
        let bound = q.error_bound() * 1.001;
        for (a, b) in w.iter().zip(&dq) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn int4_codes_stay_in_range() {
        let (k, n) = (64, 8);
        let w = make_weights(k, n, 3);
        let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(64));
        for r in 0..k {
            for c in 0..n {
                let v = q.code_at(r, c);
                assert!((-7..=7).contains(&v), "code {v}");
            }
        }
    }

    #[test]
    fn group_boundary_scales() {
        // Distinct magnitudes per group must give distinct scales.
        let k = 8;
        let n = 1;
        let mut w = vec![0f32; k];
        for (i, x) in w.iter_mut().enumerate() {
            *x = if i < 4 { 1.0 } else { 100.0 };
        }
        let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int8(4));
        assert!(q.scale_at(0, 0) < q.scale_at(4, 0));
        assert_eq!(q.scales.len(), 2);
    }

    #[test]
    fn zero_matrix_is_exact() {
        let w = vec![0f32; 64];
        let q = QuantizedMatrix::quantize(&w, 8, 8, GroupwiseQuant::int4(8));
        assert_eq!(q.dequantize(), w);
    }

    #[test]
    fn int4_storage_half_of_int8() {
        let (k, n) = (64, 64);
        let w = make_weights(k, n, 4);
        let q4 = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(64));
        let q8 = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int8(64));
        assert_eq!(q4.codes.len() * 2, q8.codes.len());
    }

    #[test]
    fn prop_roundtrip_error_within_bound() {
        run_prop("groupwise-roundtrip", 0xBEEF, 40, |g| {
            let group = *g.choose(&[8usize, 16, 32, 64]);
            let k = group * g.usize_in(1, 4);
            let n = g.usize_in(1, 24);
            let dt = if g.bool() { GroupwiseQuant::int4(group) } else { GroupwiseQuant::int8(group) };
            let w = g.f32_vec(k * n, -3.0, 3.0);
            let q = QuantizedMatrix::quantize(&w, k, n, dt);
            let dq = q.dequantize();
            let bound = q.error_bound() * 1.001;
            for (a, b) in w.iter().zip(&dq) {
                assert!((a - b).abs() <= bound, "err {} bound {bound}", (a - b).abs());
            }
        });
    }

    #[test]
    #[should_panic(expected = "group_size must divide K")]
    fn rejects_nondividing_group() {
        let w = vec![0f32; 10 * 4];
        QuantizedMatrix::quantize(&w, 10, 4, GroupwiseQuant::int4(64));
    }
}

//! In-place KV transcode kernels: re-quantize resident KV rows down the
//! precision ladder (kv16→kv8, kv16→kv4, kv8→kv4) without round-tripping
//! through the original activations.
//!
//! Invariant (load-bearing for the laddering preemption rung): transcoded
//! codes are **bit-identical** to quantizing the original row directly at
//! the target precision.
//!
//! * kv16 rows store exact little-endian f32 values (scale 1.0), so
//!   kv16→kv8 / kv16→kv4 literally are `quantize_kv_int8` /
//!   `quantize_kv_int4` applied to the decoded floats.
//! * kv8→kv4 holds because INT4 is *defined* as the nested refinement of
//!   the INT8 codes (`int4_from_int8` in [`super::kv`]); the original
//!   floats are not needed.
//!
//! The kernels operate on raw row bytes as laid out in the paged KV pool
//! (`kvcache::pool`): f32 rows are `head_dim * 4` bytes LE, int8 rows are
//! `head_dim` bytes of two's-complement codes, int4 rows are
//! `head_dim.div_ceil(2)` bytes packed low-nibble-even.

use super::kv::{
    int4_from_int8_scalar, pack_int4_from_i8_bytes, quantize_kv_int4, quantize_kv_int8,
};

/// Decode a kv16 row (little-endian f32 bytes) into floats.
fn f32_row(src: &[u8]) -> Vec<f32> {
    debug_assert_eq!(src.len() % 4, 0);
    src.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Reinterpret an int8 row's raw bytes as codes.
fn i8_row(src: &[u8]) -> Vec<i8> {
    src.iter().map(|&b| b as i8).collect()
}

/// Transcode one kv16 row to kv8. `src` is `head_dim * 4` bytes, `dst` is
/// `head_dim` bytes. Returns the new per-row scale.
pub fn f32_row_to_int8(src: &[u8], dst: &mut [u8]) -> f32 {
    let (codes, scale) = quantize_kv_int8(&f32_row(src));
    debug_assert_eq!(dst.len(), codes.len());
    for (d, c) in dst.iter_mut().zip(&codes) {
        *d = *c as u8;
    }
    scale
}

/// Transcode one kv16 row to kv4. `src` is `head_dim * 4` bytes, `dst` is
/// `head_dim.div_ceil(2)` bytes. Returns the new per-row scale.
pub fn f32_row_to_int4(src: &[u8], dst: &mut [u8]) -> f32 {
    let (packed, scale) = quantize_kv_int4(&f32_row(src));
    debug_assert_eq!(dst.len(), packed.len());
    dst.copy_from_slice(&packed);
    scale
}

/// Transcode one kv8 row to kv4 straight from resident codes. `src` is
/// `head_dim` bytes of int8 codes, `dst` is `head_dim.div_ceil(2)` bytes.
/// Returns the new per-row scale. Word-wise and allocation-free: the
/// nibble LUT + SWAR pack runs directly on the pool's row bytes —
/// bit-identical to [`int8_row_to_int4_scalar`] (property-tested below).
pub fn int8_row_to_int4(src: &[u8], src_scale: f32, dst: &mut [u8]) -> f32 {
    debug_assert_eq!(dst.len(), src.len().div_ceil(2));
    pack_int4_from_i8_bytes(src, src_scale, dst)
}

/// Byte-at-a-time reference for [`int8_row_to_int4`] — the pre-word-codec
/// implementation (decode to `Vec<i8>`, scalar repack), retained for
/// bit-identity property tests and the `bench hotpath` speedup ratio.
pub fn int8_row_to_int4_scalar(src: &[u8], src_scale: f32, dst: &mut [u8]) -> f32 {
    let (packed, scale) = int4_from_int8_scalar(&i8_row(src), src_scale);
    debug_assert_eq!(dst.len(), packed.len());
    dst.copy_from_slice(&packed);
    scale
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::kv::dequantize_kv_int4;
    use crate::util::proptest::run_prop;

    fn f32_bytes(row: &[f32]) -> Vec<u8> {
        row.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn prop_transcode_matches_direct_quantization_bitwise() {
        run_prop("transcode-bit-identity", 0x7C0D_E4, 50, |g| {
            let n = g.usize_in(1, 96);
            let row = g.f32_vec(n, -8.0, 8.0);
            let src = f32_bytes(&row);

            // kv16 -> kv8 == direct int8.
            let (c8, s8) = quantize_kv_int8(&row);
            let mut dst8 = vec![0u8; n];
            let got_s8 = f32_row_to_int8(&src, &mut dst8);
            assert_eq!(got_s8.to_bits(), s8.to_bits());
            assert_eq!(dst8, c8.iter().map(|&c| c as u8).collect::<Vec<u8>>());

            // kv16 -> kv4 == direct int4.
            let (c4, s4) = quantize_kv_int4(&row);
            let mut dst4 = vec![0u8; n.div_ceil(2)];
            let got_s4 = f32_row_to_int4(&src, &mut dst4);
            assert_eq!(got_s4.to_bits(), s4.to_bits());
            assert_eq!(dst4, c4);

            // kv8 -> kv4 from resident codes == direct int4.
            let mut lad4 = vec![0u8; n.div_ceil(2)];
            let lad_s4 = int8_row_to_int4(&dst8, got_s8, &mut lad4);
            assert_eq!(lad_s4.to_bits(), s4.to_bits());
            assert_eq!(lad4, c4);
        });
    }

    #[test]
    fn prop_word_transcode_matches_scalar_bitwise() {
        // The allocation-free word path vs the retained scalar reference,
        // across odd lengths and degenerate rows — dst starts dirty so a
        // stale-byte leak in either path would diverge.
        run_prop("transcode-word-vs-scalar", 0x7C0D_55, 50, |g| {
            let n = g.usize_in(1, 130);
            let row = match g.usize_in(0, 4) {
                0 => vec![0f32; n],
                1 => vec![f32::MIN_POSITIVE / 2.0; n],
                _ => g.f32_vec(n, -8.0, 8.0),
            };
            let (c8, s8) = quantize_kv_int8(&row);
            let bytes: Vec<u8> = c8.iter().map(|&c| c as u8).collect();
            let mut word = vec![0xAAu8; n.div_ceil(2)];
            let mut scalar = vec![0x55u8; n.div_ceil(2)];
            let sw = int8_row_to_int4(&bytes, s8, &mut word);
            let ss = int8_row_to_int4_scalar(&bytes, s8, &mut scalar);
            assert_eq!(sw.to_bits(), ss.to_bits());
            assert_eq!(word, scalar, "packed bytes diverge (n={n})");
        });
    }

    #[test]
    fn degenerate_rows_transcode_to_canonical_zero() {
        for row in [vec![0f32; 8], vec![f32::MIN_POSITIVE / 2.0; 8]] {
            let src = f32_bytes(&row);
            let mut dst8 = vec![0xAAu8; 8];
            assert_eq!(f32_row_to_int8(&src, &mut dst8), 1.0);
            assert!(dst8.iter().all(|&b| b == 0));
            let mut dst4 = vec![0xAAu8; 4];
            assert_eq!(f32_row_to_int4(&src, &mut dst4), 1.0);
            assert!(dst4.iter().all(|&b| b == 0));
            let mut lad4 = vec![0xAAu8; 4];
            assert_eq!(int8_row_to_int4(&dst8, 1.0, &mut lad4), 1.0);
            assert!(lad4.iter().all(|&b| b == 0));
        }
    }

    #[test]
    fn transcoded_values_stay_close_to_source() {
        let row: Vec<f32> = (0..32).map(|i| (i as f32 - 15.5) * 0.37).collect();
        let src = f32_bytes(&row);
        let mut dst4 = vec![0u8; 16];
        let s4 = f32_row_to_int4(&src, &mut dst4);
        let s8 = s4 * (7.0 / 127.0);
        for (a, b) in row.iter().zip(dequantize_kv_int4(&dst4, 32, s4)) {
            assert!((a - b).abs() <= (s4 + s8) * 0.5 + 1e-5);
        }
    }
}

//! §4.2 Adaptive head alignment — the Q rearrangement of Algorithm 1
//! (Appendix D), lane-exact.
//!
//! Mixing FP16 Q with low-bit K misaligns warp fragments (Challenge-III):
//! `ldmatrix` fetches wider K tiles per lane than Q tiles. TurboMind fixes
//! the *Q side* once per head: each lane loads Q elements from shared
//! memory at coordinates chosen so its registers line up with the
//! quantized-K fragment the MMA instruction will consume.
//!
//! Algorithm 1's parameters for the `m16n8k16` instruction with head
//! dimension `HeadDim`:
//! * `OP_K` — tensor-core operand K-granularity at the KV precision
//!   (16 for FP16 K, 8 for INT8, 4 for INT4 — §4.2 step (i));
//! * `X = 16 / kv_bits` — sub-word batching factor (2 for 8-bit, 4 for
//!   4-bit KV);
//! * lane mapping (step (ii)): `hi = n·OP_N + lane/4`,
//!   `di = k·OP_K + (lane mod 4)·2X + 2x + 8·d·X`.
//!
//! The tests verify the properties the paper claims: the rearrangement is
//! a **bijection** onto the Q tile (no element read twice, none dropped)
//! and every load phase targets **distinct elements** (step (ii)); full
//! bank-conflict freedom additionally uses the swizzled SMEM placement
//! demonstrated in [`super::swizzle`].

use super::access::LaneAccess;
#[cfg(test)]
use super::access::bank_conflict_degree;
use super::fragment::WARP_SIZE;

/// MMA operand-N extent for `m16n8k16`.
pub const OP_N: usize = 8;

/// Q-rearrangement parameters for one KV precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QRearrange {
    /// Attention head dimension (e.g. 128 in the paper's models).
    pub head_dim: usize,
    /// KV cache bits (16 / 8 / 4).
    pub kv_bits: usize,
}

impl QRearrange {
    pub fn new(head_dim: usize, kv_bits: usize) -> Self {
        assert!(matches!(kv_bits, 16 | 8 | 4), "kv_bits {kv_bits}");
        Self { head_dim, kv_bits }
    }

    /// Tensor-core operand K-granularity (§4.2 step (i)): FP16→16,
    /// INT8→8, INT4→4.
    pub fn op_k(&self) -> usize {
        match self.kv_bits {
            16 => 16,
            8 => 8,
            4 => 4,
            _ => unreachable!(),
        }
    }

    /// Number of K-slices of the Q matrix (`K_K` in Algorithm 1):
    /// 128-dim heads need 8 / 16 / 32 slices for FP16 / INT8 / INT4 K.
    pub fn k_slices(&self) -> usize {
        self.head_dim / self.op_k()
    }

    /// Sub-word batching factor `X = 16 / kv_bits` (Appendix D).
    pub fn x(&self) -> usize {
        16 / self.kv_bits
    }

    /// Dims covered per k-window: each window spans `16·X` consecutive Q
    /// dims (X² K-slices of OP_K dims), fully tiled by one warp step.
    pub fn window_dims(&self) -> usize {
        16 * self.x()
    }

    /// The (row, dim) Q coordinates lane `lane` loads for warp-tile row
    /// block `n` and K-window `kwin` — Algorithm 1's inner loops with the
    /// 32-bit load granularity made explicit: each `Load(Q_sm[hi][di])`
    /// fetches a **pair** of f16 elements `(di, di+1)`, so a lane holds
    /// `4X` elements per window in register order
    /// `frag_Q[n][k+x][2d], frag_Q[n][k+x][2d+1]`.
    pub fn lane_coords(&self, lane: usize, n: usize, kwin: usize) -> Vec<(usize, usize)> {
        assert!(lane < WARP_SIZE);
        let x_max = self.x();
        let base = kwin * self.window_dims();
        let mut out = Vec::with_capacity(4 * x_max);
        let hi = n * OP_N + lane / 4;
        for x in 0..x_max {
            for d in 0..2 {
                let di = base + (lane % 4) * 2 * x_max + 2 * x + 8 * x_max * d;
                out.push((hi, di));
                out.push((hi, di + 1));
            }
        }
        out
    }

    /// Run the full rearrangement over a Q warp tile of `rows` rows
    /// (`rows` a multiple of OP_N): returns, per lane, the flat list of
    /// (row, dim) elements in register order — `frag_Q` of Algorithm 1.
    pub fn rearrange_coords(&self, rows: usize) -> Vec<Vec<(usize, usize)>> {
        assert_eq!(rows % OP_N, 0);
        assert_eq!(self.head_dim % self.window_dims(), 0);
        let windows = self.head_dim / self.window_dims();
        let mut frags = vec![Vec::new(); WARP_SIZE];
        for n in 0..rows / OP_N {
            for kwin in 0..windows {
                for (lane, frag) in frags.iter_mut().enumerate() {
                    frag.extend(self.lane_coords(lane, n, kwin));
                }
            }
        }
        frags
    }

    /// Shared-memory accesses of one `lane_coords` window under a
    /// row-major f16 Q tile (each (x, d) pair is one 32-bit load). Step
    /// (ii)'s guarantee as stated is *distinct elements per phase*; full
    /// bank-conflict freedom additionally relies on the swizzled SMEM
    /// placement of Q (Appendix C / `quant::swizzle`).
    pub fn lane_accesses(&self, n: usize, kwin: usize) -> Vec<Vec<LaneAccess>> {
        let x_max = self.x();
        // One phase per (x, d) 32-bit load, across all 32 lanes.
        (0..2 * x_max)
            .map(|phase| {
                (0..WARP_SIZE)
                    .map(|lane| {
                        let coords = self.lane_coords(lane, n, kwin);
                        let (r, d0) = coords[phase * 2];
                        LaneAccess { addr: (r * self.head_dim + d0) * 2, len: 4 }
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn op_k_matches_paper() {
        // §4.2: "128-dimensional Q heads require 8, 16, and 32 K-slices for
        // FP16, INT8, and INT4 operands respectively (OP_K = 16, 8, 4)".
        assert_eq!(QRearrange::new(128, 16).op_k(), 16);
        assert_eq!(QRearrange::new(128, 8).op_k(), 8);
        assert_eq!(QRearrange::new(128, 4).op_k(), 4);
        assert_eq!(QRearrange::new(128, 16).k_slices(), 8);
        assert_eq!(QRearrange::new(128, 8).k_slices(), 16);
        assert_eq!(QRearrange::new(128, 4).k_slices(), 32);
    }

    #[test]
    fn x_factor() {
        // Appendix D: "X equals 2 for an 8-bit KV and 4 for a 4-bit KV".
        assert_eq!(QRearrange::new(128, 8).x(), 2);
        assert_eq!(QRearrange::new(128, 4).x(), 4);
        assert_eq!(QRearrange::new(128, 16).x(), 1);
    }

    #[test]
    fn rearrangement_is_a_bijection() {
        // Every Q element of the (rows × head_dim) tile is assigned to
        // exactly one (lane, register) slot — nothing dropped or doubled.
        for kv_bits in [16usize, 8, 4] {
            let q = QRearrange::new(128, kv_bits);
            let rows = 16;
            let frags = q.rearrange_coords(rows);
            let mut seen = BTreeSet::new();
            let mut total = 0usize;
            for frag in &frags {
                for &(r, d) in frag {
                    assert!(r < rows && d < 128, "({r},{d}) out of tile");
                    assert!(seen.insert((r, d)), "({r},{d}) duplicated [kv{kv_bits}]");
                    total += 1;
                }
            }
            assert_eq!(total, rows * 128, "kv{kv_bits}: coverage");
        }
    }

    #[test]
    fn per_phase_loads_hit_distinct_elements() {
        // Step (ii): "each of the 32 threads computes unique row and column
        // indices to target distinct Q matrix elements".
        for kv_bits in [16usize, 8, 4] {
            let q = QRearrange::new(128, kv_bits);
            for n in 0..2 {
                for kwin in 0..q.head_dim / q.window_dims() {
                    for phase in q.lane_accesses(n, kwin) {
                        let mut addrs: Vec<_> = phase.iter().map(|a| a.addr).collect();
                        addrs.sort_unstable();
                        addrs.dedup();
                        assert_eq!(addrs.len(), WARP_SIZE, "kv{kv_bits} n{n} k{kwin}");
                    }
                }
            }
        }
    }

    #[test]
    fn phase_conflict_degree_bounded() {
        // Without SMEM swizzling a row-major Q tile serializes up to the
        // row-group depth (8); the combination with Appendix C's swizzle
        // (see `quant::swizzle`) removes the rest. Degree must never exceed
        // the 8-row structure.
        for kv_bits in [16usize, 8, 4] {
            let q = QRearrange::new(128, kv_bits);
            for phase in q.lane_accesses(0, 0) {
                let deg = bank_conflict_degree(&phase, 32);
                assert!(deg <= 8, "kv{kv_bits}: degree {deg}");
            }
        }
    }

    #[test]
    fn register_count_matches_mma_operand() {
        // Per (n, k) step each lane holds 2X values — one m16n8k16 operand-B
        // fragment column pair per sub-word batch.
        for kv_bits in [16usize, 8, 4] {
            let q = QRearrange::new(128, kv_bits);
            let coords = q.lane_coords(0, 0, 0);
            assert_eq!(coords.len(), 4 * q.x());
        }
    }

    #[test]
    fn lanes_share_rows_within_groups_of_four() {
        // hi = n·OP_N + lane/4: lanes 0-3 read row 0, lanes 4-7 row 1, …
        let q = QRearrange::new(128, 8);
        for lane in 0..WARP_SIZE {
            for (r, _) in q.lane_coords(lane, 0, 0) {
                assert_eq!(r, lane / 4);
            }
        }
    }

    #[test]
    #[should_panic(expected = "kv_bits")]
    fn rejects_bad_bits() {
        QRearrange::new(128, 3);
    }
}

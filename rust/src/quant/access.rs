//! Warp memory-access analyzer: global-memory transaction counting and
//! shared-memory bank-conflict detection.
//!
//! Given the byte addresses each lane of a warp touches, this computes the
//! quantities the paper's Challenges I and II are about:
//!
//! * **global transactions** — distinct aligned segments (32/64/128 B)
//!   covered by the warp's accesses; 1 transaction per 128 B of useful data
//!   is perfectly coalesced (Appendix B, Figure 22);
//! * **bank conflicts** — the serialization degree when multiple lanes hit
//!   different 32-bit words in the same shared-memory bank (Appendix B,
//!   Figure 23).
//!
//! Both `gpusim` and the §4.1 packing verifier are built on this analyzer,
//! so the "coalesced / conflict-free" guarantees of the packed layout are
//! *measured properties*, not assumptions.

use std::collections::{BTreeMap, BTreeSet};

/// One lane's access: starting byte address and length in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneAccess {
    pub addr: usize,
    pub len: usize,
}

/// Result of analyzing one warp-wide access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessReport {
    /// Number of global-memory transactions (distinct segments touched).
    pub transactions: usize,
    /// Minimum possible transactions for the bytes actually requested.
    pub ideal_transactions: usize,
    /// Shared-memory serialization degree: 1 = conflict-free, `n` = the
    /// worst bank serves `n` distinct words sequentially.
    pub bank_conflict_degree: usize,
    /// Total useful bytes requested by the warp.
    pub useful_bytes: usize,
}

impl AccessReport {
    /// Coalescing efficiency in (0, 1]: ideal/actual transactions.
    pub fn coalescing_efficiency(&self) -> f64 {
        if self.transactions == 0 {
            1.0
        } else {
            self.ideal_transactions as f64 / self.transactions as f64
        }
    }

    pub fn is_fully_coalesced(&self) -> bool {
        self.transactions == self.ideal_transactions
    }

    pub fn is_conflict_free(&self) -> bool {
        self.bank_conflict_degree <= 1
    }
}

/// Analyze a warp's global-memory access with the given segment size.
pub fn analyze_global(accesses: &[LaneAccess], segment_bytes: usize) -> AccessReport {
    assert!(segment_bytes.is_power_of_two());
    let mut segments = BTreeSet::new();
    let mut useful = 0usize;
    for a in accesses {
        if a.len == 0 {
            continue;
        }
        useful += a.len;
        let first = a.addr / segment_bytes;
        let last = (a.addr + a.len - 1) / segment_bytes;
        for s in first..=last {
            segments.insert(s);
        }
    }
    let transactions = segments.len();
    let ideal = useful.div_ceil(segment_bytes).max(usize::from(useful > 0));
    AccessReport {
        transactions,
        ideal_transactions: ideal,
        bank_conflict_degree: bank_conflict_degree(accesses, 32),
        useful_bytes: useful,
    }
}

/// Shared-memory bank conflict degree for a warp access: banks are 4-byte
/// words striped across `n_banks`; the degree is the max number of
/// *distinct* words mapped to one bank (same-word broadcast is free).
///
/// Hardware splits wide per-lane accesses into phases — LDS.64 issues two
/// half-warp transactions, LDS.128 four quarter-warp transactions — and
/// conflicts only arise *within* a phase (CUDA C++ Programming Guide,
/// shared-memory section). When every lane accesses the same width of 8 or
/// 16 bytes we model those phases; other patterns are evaluated in a single
/// phase (conservative for scattered sub-word gathers, which is exactly the
/// naive-layout pathology the paper describes).
pub fn bank_conflict_degree(accesses: &[LaneAccess], n_banks: usize) -> usize {
    let uniform_len = match accesses.first() {
        Some(a) if accesses.iter().all(|x| x.len == a.len) => a.len,
        _ => 0,
    };
    let phase_lanes = match uniform_len {
        8 => 16,  // LDS.64: half-warp phases
        16 => 8,  // LDS.128: quarter-warp phases
        _ => accesses.len().max(1),
    };
    let mut worst = 1usize;
    for phase in accesses.chunks(phase_lanes) {
        let mut bank_words: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
        for a in phase {
            if a.len == 0 {
                continue;
            }
            // Every 4-byte word the lane touches participates.
            let first_word = a.addr / 4;
            let last_word = (a.addr + a.len - 1) / 4;
            for w in first_word..=last_word {
                bank_words.entry(w % n_banks).or_default().insert(w);
            }
        }
        worst = worst.max(bank_words.values().map(BTreeSet::len).max().unwrap_or(1));
    }
    worst
}

/// Convenience: the access pattern of a warp loading one `elem_bytes`-sized
/// element per lane at stride `stride_bytes` starting from `base`.
pub fn strided_warp_access(
    base: usize,
    stride_bytes: usize,
    elem_bytes: usize,
    lanes: usize,
) -> Vec<LaneAccess> {
    (0..lanes)
        .map(|l| LaneAccess { addr: base + l * stride_bytes, len: elem_bytes })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_warp_is_one_transaction() {
        // 32 lanes × 4 bytes contiguous = 128 B = 1 segment.
        let acc = strided_warp_access(0, 4, 4, 32);
        let r = analyze_global(&acc, 128);
        assert_eq!(r.transactions, 1);
        assert!(r.is_fully_coalesced());
        assert!(r.is_conflict_free());
    }

    #[test]
    fn misaligned_warp_needs_two_transactions() {
        // Same 128 useful bytes but offset by 64: straddles two segments
        // (paper Appendix B, Figure 22).
        let acc = strided_warp_access(64, 4, 4, 32);
        let r = analyze_global(&acc, 128);
        assert_eq!(r.transactions, 2);
        assert_eq!(r.ideal_transactions, 1);
        assert!(!r.is_fully_coalesced());
        assert!((r.coalescing_efficiency() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn scattered_warp_is_fully_uncoalesced() {
        // Each lane in its own segment: 32 transactions for 128 bytes.
        let acc = strided_warp_access(0, 128, 4, 32);
        let r = analyze_global(&acc, 128);
        assert_eq!(r.transactions, 32);
        assert_eq!(r.ideal_transactions, 1);
    }

    #[test]
    fn full_row_stride_hits_one_bank() {
        // The paper's Challenge-II: 32 lanes reading a column of 32-bit
        // words with a 128-byte row stride all map to bank 0 → 32-way.
        let acc = strided_warp_access(0, 128, 4, 32);
        assert_eq!(bank_conflict_degree(&acc, 32), 32);
    }

    #[test]
    fn unit_stride_is_conflict_free() {
        let acc = strided_warp_access(0, 4, 4, 32);
        assert_eq!(bank_conflict_degree(&acc, 32), 1);
    }

    #[test]
    fn broadcast_same_word_is_free() {
        let acc: Vec<_> = (0..32).map(|_| LaneAccess { addr: 16, len: 4 }).collect();
        assert_eq!(bank_conflict_degree(&acc, 32), 1);
    }

    #[test]
    fn eight_way_conflict_for_strided_word_column() {
        // A column walk over a 32-byte-row layout with 32-bit loads: lanes
        // l and l+4 share a bank with distinct words → 8-way serialization
        // (the Figure 5 "before ldmatrix" pathology).
        let acc = strided_warp_access(0, 32, 4, 32);
        assert_eq!(bank_conflict_degree(&acc, 32), 8);
    }

    #[test]
    fn lds64_consecutive_words_conflict_free() {
        // LDS.64: each lane reads 8 consecutive bytes, lanes read adjacent
        // 64-bit words. Hardware splits into two half-warp phases, each
        // covering all 32 banks exactly once → conflict-free. This is the
        // two-fragment storage read pattern of §4.1 step (iv).
        let acc = strided_warp_access(0, 8, 8, 32);
        assert_eq!(bank_conflict_degree(&acc, 32), 1);
    }

    #[test]
    fn lds128_consecutive_conflict_free() {
        let acc = strided_warp_access(0, 16, 16, 32);
        assert_eq!(bank_conflict_degree(&acc, 32), 1);
    }

    #[test]
    fn empty_access_is_neutral() {
        let r = analyze_global(&[], 128);
        assert_eq!(r.transactions, 0);
        assert_eq!(r.useful_bytes, 0);
        assert!(r.is_conflict_free());
    }

    #[test]
    fn int4_packed_column_load_is_pathological() {
        // Challenge-I instance: a warp gathering a *column* of packed INT4
        // weights (N=4096 row stride → 2048 bytes between consecutive K
        // elements of one column).
        let acc = strided_warp_access(0, 2048, 4, 32);
        let r = analyze_global(&acc, 128);
        assert_eq!(r.transactions, 32, "every lane lands in its own segment");
        assert_eq!(r.bank_conflict_degree, 32);
    }
}

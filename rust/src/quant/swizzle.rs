//! Appendix C: the 8×128-byte swizzled shared-memory layout — the runtime
//! alternative that §4.1's offline packing makes unnecessary.
//!
//! `cp.async` writes rows (horizontal, coalesced); `ldmatrix` reads columns
//! (vertical, per-lane). With a naive row-major tile those column reads pile
//! onto the same banks. The classic fix permutes each 16-byte chunk within
//! its row by XOR-ing the chunk index with the row index (the 8×128 B
//! swizzle unit, Figure 25), making both access directions conflict-free.
//!
//! This module implements that swizzle and *measures* (tests below) the
//! paper's Appendix C claims:
//! 1. naive layout: row writes clean, ldmatrix column reads conflicted;
//! 2. swizzled layout: both clean — but every read now needs the XOR
//!    address arithmetic at runtime;
//! 3. the §4.1 packed layout gets the same conflict-freedom with plain
//!    linear addresses ("packing bakes the swizzle in offline").

use super::access::LaneAccess;
#[cfg(test)]
use super::access::bank_conflict_degree;

/// Chunk size the swizzle permutes (one `ldmatrix` row / lane load).
pub const CHUNK: usize = 16;
/// Bytes per swizzle-unit row (a 128-byte SMEM cache line).
pub const ROW_BYTES: usize = 128;
/// Rows per swizzle unit.
pub const ROWS: usize = 8;

/// Map a logical (row, byte-in-row) address to its swizzled physical byte
/// offset within the 8×128 B unit: chunk index XOR row.
pub fn swizzle_addr(row: usize, byte: usize) -> usize {
    debug_assert!(row < ROWS && byte < ROW_BYTES);
    let chunk = byte / CHUNK;
    let within = byte % CHUNK;
    let phys_chunk = chunk ^ row;
    row * ROW_BYTES + phys_chunk * CHUNK + within
}

/// Apply the swizzle to an 8×128-byte tile (row-major input).
pub fn swizzle_tile(data: &[u8]) -> Vec<u8> {
    assert_eq!(data.len(), ROWS * ROW_BYTES);
    let mut out = vec![0u8; data.len()];
    for row in 0..ROWS {
        for byte in 0..ROW_BYTES {
            out[swizzle_addr(row, byte)] = data[row * ROW_BYTES + byte];
        }
    }
    out
}

/// Inverse mapping (self-inverse per row since XOR is an involution).
pub fn unswizzle_tile(data: &[u8]) -> Vec<u8> {
    assert_eq!(data.len(), ROWS * ROW_BYTES);
    let mut out = vec![0u8; data.len()];
    for row in 0..ROWS {
        for byte in 0..ROW_BYTES {
            out[row * ROW_BYTES + byte] = data[swizzle_addr(row, byte)];
        }
    }
    out
}

/// The warp's write pattern for one cp.async row store (lane `l` writes
/// bytes `l*4..l*4+4` of `row`), under the given address mapping.
pub fn row_write_accesses(row: usize, swizzled: bool) -> Vec<LaneAccess> {
    (0..32)
        .map(|lane| {
            let byte = lane * 4;
            let addr = if swizzled { swizzle_addr(row, byte) } else { row * ROW_BYTES + byte };
            LaneAccess { addr, len: 4 }
        })
        .collect()
}

/// The `ldmatrix`-style column read: 8 lanes each fetch the same 16-byte
/// *logical column chunk* across the 8 rows of the unit (lane `l` reads
/// logical chunk `col_chunk` of row `l`).
pub fn column_read_accesses(col_chunk: usize, swizzled: bool) -> Vec<LaneAccess> {
    (0..ROWS)
        .map(|row| {
            let byte = col_chunk * CHUNK;
            let addr = if swizzled { swizzle_addr(row, byte) } else { row * ROW_BYTES + byte };
            LaneAccess { addr, len: CHUNK }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swizzle_roundtrips() {
        let data: Vec<u8> = (0..ROWS * ROW_BYTES).map(|i| (i % 251) as u8).collect();
        assert_eq!(unswizzle_tile(&swizzle_tile(&data)), data);
    }

    #[test]
    fn swizzle_is_a_permutation() {
        let mut seen = vec![false; ROWS * ROW_BYTES];
        for row in 0..ROWS {
            for byte in 0..ROW_BYTES {
                let a = swizzle_addr(row, byte);
                assert!(!seen[a], "address {a} hit twice");
                seen[a] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn row_zero_is_identity() {
        // chunk XOR 0 = chunk: the first row is unpermuted.
        for byte in 0..ROW_BYTES {
            assert_eq!(swizzle_addr(0, byte), byte);
        }
    }

    #[test]
    fn naive_column_reads_conflict() {
        // Appendix C: "with a naive row-major layout, those vertical reads
        // cause multiple lanes to hit the same shared memory bank".
        // 8 lanes × 16-byte chunks at 128-byte row stride: every lane maps
        // to the same four banks → 8-way serialization.
        let acc = column_read_accesses(3, false);
        assert_eq!(bank_conflict_degree(&acc, 32), 8);
    }

    #[test]
    fn swizzled_column_reads_are_conflict_free() {
        for col_chunk in 0..ROW_BYTES / CHUNK {
            let acc = column_read_accesses(col_chunk, true);
            assert_eq!(
                bank_conflict_degree(&acc, 32),
                1,
                "chunk {col_chunk} conflicted"
            );
        }
    }

    #[test]
    fn swizzled_row_writes_stay_coalesced_and_clean() {
        // "the horizontal cp.async writes remain coalesced": a swizzled row
        // write touches the same 128-byte line, permuted within it.
        for row in 0..ROWS {
            let acc = row_write_accesses(row, true);
            let min = acc.iter().map(|a| a.addr).min().unwrap();
            let max = acc.iter().map(|a| a.addr + a.len).max().unwrap();
            assert_eq!(min / ROW_BYTES, (max - 1) / ROW_BYTES, "row {row} split lines");
            assert_eq!(bank_conflict_degree(&acc, 32), 1);
        }
    }

    #[test]
    fn packed_layout_needs_no_swizzle() {
        // The §4.1 contrast ("why does our packing avoid swizzling?"): the
        // offline-packed layout's runtime loads are already conflict-free
        // with *linear* addressing — no XOR arithmetic on the hot path.
        use crate::quant::{pack_weights_hw_aware, GroupwiseQuant, QuantizedMatrix};
        let w = vec![0.5f32; 64 * 64];
        let q = QuantizedMatrix::quantize(&w, 64, 64, GroupwiseQuant::int4(16));
        let p = pack_weights_hw_aware(&q);
        for t in 0..p.n_tiles() {
            let r = p.runtime_load_report(t, 128);
            assert!(r.is_conflict_free() && r.is_fully_coalesced());
        }
    }
}

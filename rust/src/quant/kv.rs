//! KV-cache quantization: per-token, per-KV-head symmetric scales.
//!
//! The engine quantizes each new (K, V) row as it is appended to the paged
//! pool (`kvcache`), and the AOT decode graphs dequantize on the fly inside
//! the attention kernel (the paper's attention pipeline, §3.4). The exact
//! same scheme is implemented in `python/compile/quantize.py` so the Rust
//! pool and the Pallas kernel agree bit-for-bit on the codes.

/// Quantize one KV row (`head_dim` values) to INT8. Returns (codes, scale).
pub fn quantize_kv_int8(row: &[f32]) -> (Vec<i8>, f32) {
    let maxabs = row.iter().fold(0f32, |m, x| m.max(x.abs()));
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    let codes = row.iter().map(|x| (x / scale).round().clamp(-127.0, 127.0) as i8).collect();
    (codes, scale)
}

/// Quantize one KV row to INT4, packed two codes per byte (low nibble =
/// even element). Returns (packed bytes, scale).
pub fn quantize_kv_int4(row: &[f32]) -> (Vec<u8>, f32) {
    let maxabs = row.iter().fold(0f32, |m, x| m.max(x.abs()));
    let scale = if maxabs > 0.0 { maxabs / 7.0 } else { 1.0 };
    let mut packed = vec![0u8; row.len().div_ceil(2)];
    for (i, x) in row.iter().enumerate() {
        let q = (x / scale).round().clamp(-7.0, 7.0) as i8;
        let nib = (q as u8) & 0x0F;
        if i % 2 == 0 {
            packed[i / 2] |= nib;
        } else {
            packed[i / 2] |= nib << 4;
        }
    }
    (packed, scale)
}

/// Dequantize INT8 codes with a scalar scale.
pub fn dequantize_kv(codes: &[i8], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

/// Dequantize INT4 packed codes (`n` original elements) with a scalar scale.
pub fn dequantize_kv_int4(packed: &[u8], n: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        out.push(super::groupwise::sign_extend4(nib) as f32 * scale);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    #[test]
    fn int8_roundtrip() {
        let row: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.1).collect();
        let (codes, scale) = quantize_kv_int8(&row);
        let dq = dequantize_kv(&codes, scale);
        for (a, b) in row.iter().zip(&dq) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn int4_roundtrip() {
        let row: Vec<f32> = (0..32).map(|i| ((i * 7919) % 13) as f32 - 6.0).collect();
        let (packed, scale) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 16);
        let dq = dequantize_kv_int4(&packed, 32, scale);
        for (a, b) in row.iter().zip(&dq) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_row_exact() {
        let row = vec![0f32; 16];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(dequantize_kv(&codes, scale), row);
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(dequantize_kv_int4(&packed, 16, scale4), row);
    }

    #[test]
    fn extreme_value_maps_to_max_code() {
        let mut row = vec![0.01f32; 8];
        row[3] = -100.0;
        let (codes, _) = quantize_kv_int8(&row);
        assert_eq!(codes[3], -127);
        let (packed, _) = quantize_kv_int4(&row);
        let dq = dequantize_kv_int4(&packed, 8, 100.0 / 7.0);
        assert!((dq[3] + 100.0).abs() < 1.0);
    }

    #[test]
    fn odd_length_int4() {
        let row = vec![1.0f32, -2.0, 3.0];
        let (packed, scale) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 2);
        let dq = dequantize_kv_int4(&packed, 3, scale);
        assert_eq!(dq.len(), 3);
    }

    #[test]
    fn prop_kv_roundtrip_error() {
        run_prop("kv-roundtrip", 0xCAFE, 50, |g| {
            let n = g.usize_in(1, 128);
            let row = g.f32_vec(n, -8.0, 8.0);
            let (c8, s8) = quantize_kv_int8(&row);
            for (a, b) in row.iter().zip(dequantize_kv(&c8, s8)) {
                assert!((a - b).abs() <= s8 * 0.5 + 1e-5);
            }
            let (c4, s4) = quantize_kv_int4(&row);
            for (a, b) in row.iter().zip(dequantize_kv_int4(&c4, n, s4)) {
                assert!((a - b).abs() <= s4 * 0.5 + 1e-5);
            }
        });
    }
}

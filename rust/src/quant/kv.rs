//! KV-cache quantization: per-token, per-KV-head symmetric scales.
//!
//! The engine quantizes each new (K, V) row as it is appended to the paged
//! pool (`kvcache`), and the AOT decode graphs dequantize on the fly inside
//! the attention kernel (the paper's attention pipeline, §3.4). The exact
//! same scheme is implemented in `python/compile/quantize.py` so the Rust
//! pool and the Pallas kernel agree bit-for-bit on the codes.
//!
//! INT4 is defined as a *nested* refinement of INT8: a row is first
//! quantized to INT8 codes, and the INT4 codes are derived from those codes
//! (`int4_from_int8`). This makes the in-place kv8→kv4 transcode in
//! [`super::transcode`] bit-identical to quantizing the original row
//! directly at INT4 — the invariant the precision-laddering preemption rung
//! relies on for determinism.

/// Resolve the symmetric scale for a max-abs value, guarding degenerate
/// rows. All-zero rows and subnormal rows whose computed scale underflows
/// to zero (or is non-finite) get `None`, which callers map to scale 1.0
/// with all-zero codes — avoiding div-by-zero / NaN on the quantize path.
fn kv_scale(maxabs: f32, levels: f32) -> Option<f32> {
    let scale = maxabs / levels;
    if scale > 0.0 && scale.is_finite() {
        Some(scale)
    } else {
        None
    }
}

/// Quantize one KV row (`head_dim` values) to INT8. Returns (codes, scale).
pub fn quantize_kv_int8(row: &[f32]) -> (Vec<i8>, f32) {
    let maxabs = row.iter().fold(0f32, |m, x| m.max(x.abs()));
    let Some(scale) = kv_scale(maxabs, 127.0) else {
        return (vec![0i8; row.len()], 1.0);
    };
    let codes = row.iter().map(|x| (x / scale).round().clamp(-127.0, 127.0) as i8).collect();
    (codes, scale)
}

/// Derive INT4 packed codes from INT8 codes + scale (low nibble = even
/// element). Returns (packed bytes, scale). `quantize_kv_int4` is defined
/// as `int4_from_int8(quantize_kv_int8(row))`, so transcoding resident
/// INT8 codes with this function is bit-identical to quantizing the
/// original row directly at INT4.
pub fn int4_from_int8(codes: &[i8], scale: f32) -> (Vec<u8>, f32) {
    let mut packed = vec![0u8; codes.len().div_ceil(2)];
    if codes.iter().all(|&c| c == 0) {
        // Degenerate (zero / subnormal) rows keep the canonical scale 1.0.
        return (packed, 1.0);
    }
    let scale4 = scale * (127.0 / 7.0);
    for (i, &c) in codes.iter().enumerate() {
        let q = ((c as f32) * (7.0 / 127.0)).round().clamp(-7.0, 7.0) as i8;
        let nib = (q as u8) & 0x0F;
        if i % 2 == 0 {
            packed[i / 2] |= nib;
        } else {
            packed[i / 2] |= nib << 4;
        }
    }
    (packed, scale4)
}

/// Quantize one KV row to INT4, packed two codes per byte (low nibble =
/// even element). Returns (packed bytes, scale). Defined as the nested
/// refinement of the INT8 codes — see [`int4_from_int8`].
pub fn quantize_kv_int4(row: &[f32]) -> (Vec<u8>, f32) {
    let (c8, s8) = quantize_kv_int8(row);
    int4_from_int8(&c8, s8)
}

/// Dequantize INT8 codes with a scalar scale.
pub fn dequantize_kv(codes: &[i8], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

/// Dequantize INT4 packed codes (`n` original elements) with a scalar scale.
pub fn dequantize_kv_int4(packed: &[u8], n: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        out.push(super::groupwise::sign_extend4(nib) as f32 * scale);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    /// Nested INT4 pays at most half a step at each of the two rounding
    /// stages: |x - c4*s4| <= 0.5*s8 + 0.5*s4.
    fn int4_tol(s4: f32) -> f32 {
        let s8 = s4 * (7.0 / 127.0);
        (s4 + s8) * 0.5 + 1e-5
    }

    #[test]
    fn int8_roundtrip() {
        let row: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.1).collect();
        let (codes, scale) = quantize_kv_int8(&row);
        let dq = dequantize_kv(&codes, scale);
        for (a, b) in row.iter().zip(&dq) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn int4_roundtrip() {
        let row: Vec<f32> = (0..32).map(|i| ((i * 7919) % 13) as f32 - 6.0).collect();
        let (packed, scale) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 16);
        let dq = dequantize_kv_int4(&packed, 32, scale);
        for (a, b) in row.iter().zip(&dq) {
            assert!((a - b).abs() <= int4_tol(scale), "{a} vs {b}");
        }
    }

    #[test]
    fn zero_row_exact() {
        let row = vec![0f32; 16];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(scale, 1.0);
        assert_eq!(dequantize_kv(&codes, scale), row);
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(scale4, 1.0);
        assert_eq!(dequantize_kv_int4(&packed, 16, scale4), row);
    }

    #[test]
    fn subnormal_row_degrades_to_zero_codes() {
        // maxabs is subnormal, so maxabs/127 underflows to 0.0 — the old
        // `maxabs > 0.0` guard missed this and produced a zero scale.
        let row = vec![f32::MIN_POSITIVE / 4.0; 8];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(scale, 1.0);
        assert!(codes.iter().all(|&c| c == 0));
        assert!(dequantize_kv(&codes, scale).iter().all(|v| v.is_finite()));
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(scale4, 1.0);
        assert!(packed.iter().all(|&b| b == 0));
    }

    #[test]
    fn single_element_row() {
        let row = vec![3.0f32];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(codes, vec![127]);
        assert!((scale - 3.0 / 127.0).abs() < 1e-9);
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 1);
        assert_eq!(packed[0] & 0x0F, 7);
        let dq = dequantize_kv_int4(&packed, 1, scale4);
        assert!((dq[0] - 3.0).abs() <= int4_tol(scale4));
    }

    #[test]
    fn extreme_value_maps_to_max_code() {
        let mut row = vec![0.01f32; 8];
        row[3] = -100.0;
        let (codes, _) = quantize_kv_int8(&row);
        assert_eq!(codes[3], -127);
        let (packed, scale4) = quantize_kv_int4(&row);
        let dq = dequantize_kv_int4(&packed, 8, scale4);
        assert!((dq[3] + 100.0).abs() < 1.0);
    }

    #[test]
    fn odd_length_int4() {
        let row = vec![1.0f32, -2.0, 3.0];
        let (packed, scale) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 2);
        let dq = dequantize_kv_int4(&packed, 3, scale);
        assert_eq!(dq.len(), 3);
    }

    #[test]
    fn int4_is_nested_refinement_of_int8() {
        let row: Vec<f32> = (0..64).map(|i| ((i * 37) % 17) as f32 * 0.25 - 2.0).collect();
        let (c8, s8) = quantize_kv_int8(&row);
        let (direct, sd) = quantize_kv_int4(&row);
        let (nested, sn) = int4_from_int8(&c8, s8);
        assert_eq!(direct, nested);
        assert_eq!(sd.to_bits(), sn.to_bits());
    }

    #[test]
    fn prop_kv_roundtrip_error() {
        run_prop("kv-roundtrip", 0xCAFE, 50, |g| {
            let n = g.usize_in(1, 128);
            let row = g.f32_vec(n, -8.0, 8.0);
            let (c8, s8) = quantize_kv_int8(&row);
            for (a, b) in row.iter().zip(dequantize_kv(&c8, s8)) {
                assert!((a - b).abs() <= s8 * 0.5 + 1e-5);
            }
            let (c4, s4) = quantize_kv_int4(&row);
            for (a, b) in row.iter().zip(dequantize_kv_int4(&c4, n, s4)) {
                assert!((a - b).abs() <= int4_tol(s4));
            }
        });
    }
}

//! KV-cache quantization: per-token, per-KV-head symmetric scales.
//!
//! The engine quantizes each new (K, V) row as it is appended to the paged
//! pool (`kvcache`), and the AOT decode graphs dequantize on the fly inside
//! the attention kernel (the paper's attention pipeline, §3.4). The exact
//! same scheme is implemented in `python/compile/quantize.py` so the Rust
//! pool and the Pallas kernel agree bit-for-bit on the codes.
//!
//! INT4 is defined as a *nested* refinement of INT8: a row is first
//! quantized to INT8 codes, and the INT4 codes are derived from those codes
//! (`int4_from_int8`). This makes the in-place kv8→kv4 transcode in
//! [`super::transcode`] bit-identical to quantizing the original row
//! directly at INT4 — the invariant the precision-laddering preemption rung
//! relies on for determinism.
//!
//! The INT4 pack/unpack inner loops are word-level ([`super::word`]): the
//! int8→nibble rounding goes through a 256-entry table computed with the
//! exact scalar expression (so no float op is ever re-ordered), and the
//! nibble movement itself is SWAR — 16 codes per packed `u64` pair. The
//! byte-at-a-time originals are retained as `*_scalar` references that
//! property tests assert bit-identical against.

use std::sync::OnceLock;

use super::word::{all_zero_bytes, pack_nibbles8, sign_extend4x8, spread_nibbles8};

/// Resolve the symmetric scale for a max-abs value, guarding degenerate
/// rows. All-zero rows and subnormal rows whose computed scale underflows
/// to zero (or is non-finite) get `None`, which callers map to scale 1.0
/// with all-zero codes — avoiding div-by-zero / NaN on the quantize path.
fn kv_scale(maxabs: f32, levels: f32) -> Option<f32> {
    let scale = maxabs / levels;
    if scale > 0.0 && scale.is_finite() {
        Some(scale)
    } else {
        None
    }
}

/// Quantize one KV row (`head_dim` values) to INT8. Returns (codes, scale).
pub fn quantize_kv_int8(row: &[f32]) -> (Vec<i8>, f32) {
    let maxabs = row.iter().fold(0f32, |m, x| m.max(x.abs()));
    let Some(scale) = kv_scale(maxabs, 127.0) else {
        return (vec![0i8; row.len()], 1.0);
    };
    let codes = row.iter().map(|x| (x / scale).round().clamp(-127.0, 127.0) as i8).collect();
    (codes, scale)
}

/// 256-entry nibble table: entry `b` is the packed INT4 nibble for INT8
/// code `b as i8`, computed once with the **exact** scalar rounding
/// expression — the word-wise pack below is bit-identical to
/// [`int4_from_int8_scalar`] by construction, float op for float op.
fn int8_to_nib_lut() -> &'static [u8; 256] {
    static LUT: OnceLock<[u8; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut t = [0u8; 256];
        for (b, e) in t.iter_mut().enumerate() {
            let c = b as u8 as i8;
            let q = ((c as f32) * (7.0 / 127.0)).round().clamp(-7.0, 7.0) as i8;
            *e = (q as u8) & 0x0F;
        }
        t
    })
}

/// Word-wise nibble packing core: 16 source codes become 8 packed bytes
/// per iteration (two `u64` lane loads compacted by [`pack_nibbles8`]),
/// scalar tail. `nib` maps a source element to its 4-bit code; every `dst`
/// byte is written (stale contents never survive).
#[inline]
fn pack_rows<T: Copy>(src: &[T], dst: &mut [u8], nib: impl Fn(T) -> u8) {
    debug_assert_eq!(dst.len(), src.len().div_ceil(2));
    let mut chunks = src.chunks_exact(16);
    let mut out = dst.chunks_exact_mut(8);
    for (c, o) in (&mut chunks).zip(&mut out) {
        let mut nibs = [0u8; 16];
        for (n, &v) in nibs.iter_mut().zip(c.iter()) {
            *n = nib(v);
        }
        let lo = pack_nibbles8(u64::from_le_bytes(nibs[..8].try_into().expect("8 lanes")));
        let hi = pack_nibbles8(u64::from_le_bytes(nibs[8..].try_into().expect("8 lanes")));
        o[..4].copy_from_slice(&lo.to_le_bytes());
        o[4..].copy_from_slice(&hi.to_le_bytes());
    }
    let (ts, td) = (chunks.remainder(), out.into_remainder());
    for (i, &v) in ts.iter().enumerate() {
        if i % 2 == 0 {
            td[i / 2] = nib(v);
        } else {
            td[i / 2] |= nib(v) << 4;
        }
    }
}

/// Derive INT4 packed codes from INT8 codes + scale (low nibble = even
/// element). Returns (packed bytes, scale). `quantize_kv_int4` is defined
/// as `int4_from_int8(quantize_kv_int8(row))`, so transcoding resident
/// INT8 codes with this function is bit-identical to quantizing the
/// original row directly at INT4. Word-wise; bit-identical to
/// [`int4_from_int8_scalar`] (asserted by `prop_word_codec_matches_scalar`).
pub fn int4_from_int8(codes: &[i8], scale: f32) -> (Vec<u8>, f32) {
    let mut packed = vec![0u8; codes.len().div_ceil(2)];
    if codes.iter().all(|&c| c == 0) {
        // Degenerate (zero / subnormal) rows keep the canonical scale 1.0.
        return (packed, 1.0);
    }
    let lut = int8_to_nib_lut();
    pack_rows(codes, &mut packed, |c: i8| lut[(c as u8) as usize]);
    (packed, scale * (127.0 / 7.0))
}

/// Byte-at-a-time reference for [`int4_from_int8`] — the pre-word-codec
/// implementation, retained for bit-identity property tests and the
/// `bench hotpath` speedup ratio.
pub fn int4_from_int8_scalar(codes: &[i8], scale: f32) -> (Vec<u8>, f32) {
    let mut packed = vec![0u8; codes.len().div_ceil(2)];
    if codes.iter().all(|&c| c == 0) {
        return (packed, 1.0);
    }
    let scale4 = scale * (127.0 / 7.0);
    for (i, &c) in codes.iter().enumerate() {
        let q = ((c as f32) * (7.0 / 127.0)).round().clamp(-7.0, 7.0) as i8;
        let nib = (q as u8) & 0x0F;
        if i % 2 == 0 {
            packed[i / 2] |= nib;
        } else {
            packed[i / 2] |= nib << 4;
        }
    }
    (packed, scale4)
}

/// [`int4_from_int8`] operating directly on raw int8 row *bytes* (the
/// pool/transcode representation) — no intermediate `Vec<i8>`. Overwrites
/// all of `dst` and returns the new per-row scale.
pub fn pack_int4_from_i8_bytes(src: &[u8], src_scale: f32, dst: &mut [u8]) -> f32 {
    debug_assert_eq!(dst.len(), src.len().div_ceil(2));
    if all_zero_bytes(src) {
        dst.fill(0);
        return 1.0;
    }
    let lut = int8_to_nib_lut();
    pack_rows(src, dst, |b: u8| lut[b as usize]);
    src_scale * (127.0 / 7.0)
}

/// Quantize one KV row to INT4, packed two codes per byte (low nibble =
/// even element). Returns (packed bytes, scale). Defined as the nested
/// refinement of the INT8 codes — see [`int4_from_int8`].
pub fn quantize_kv_int4(row: &[f32]) -> (Vec<u8>, f32) {
    let (c8, s8) = quantize_kv_int8(row);
    int4_from_int8(&c8, s8)
}

/// Dequantize INT8 codes with a scalar scale.
pub fn dequantize_kv(codes: &[i8], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

/// Dequantize INT4 packed codes (`n` original elements) with a scalar
/// scale. Word-wise unpack: 8 codes per `u32` of packed nibbles (spread +
/// SWAR sign extension), scalar tail — bit-identical to
/// [`dequantize_kv_int4_scalar`].
pub fn dequantize_kv_int4(packed: &[u8], n: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    let groups = n / 8;
    for g in 0..groups {
        let w = u32::from_le_bytes(packed[g * 4..g * 4 + 4].try_into().expect("4 bytes"));
        let ext = sign_extend4x8(spread_nibbles8(w));
        for b in ext.to_le_bytes() {
            out.push(b as i8 as f32 * scale);
        }
    }
    for i in groups * 8..n {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        out.push(super::groupwise::sign_extend4(nib) as f32 * scale);
    }
    out
}

/// Byte-at-a-time reference for [`dequantize_kv_int4`] — retained for
/// bit-identity property tests and the `bench hotpath` speedup ratio.
pub fn dequantize_kv_int4_scalar(packed: &[u8], n: usize, scale: f32) -> Vec<f32> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
        out.push(super::groupwise::sign_extend4(nib) as f32 * scale);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::run_prop;

    /// Nested INT4 pays at most half a step at each of the two rounding
    /// stages: |x - c4*s4| <= 0.5*s8 + 0.5*s4.
    fn int4_tol(s4: f32) -> f32 {
        let s8 = s4 * (7.0 / 127.0);
        (s4 + s8) * 0.5 + 1e-5
    }

    #[test]
    fn int8_roundtrip() {
        let row: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.1).collect();
        let (codes, scale) = quantize_kv_int8(&row);
        let dq = dequantize_kv(&codes, scale);
        for (a, b) in row.iter().zip(&dq) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn int4_roundtrip() {
        let row: Vec<f32> = (0..32).map(|i| ((i * 7919) % 13) as f32 - 6.0).collect();
        let (packed, scale) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 16);
        let dq = dequantize_kv_int4(&packed, 32, scale);
        for (a, b) in row.iter().zip(&dq) {
            assert!((a - b).abs() <= int4_tol(scale), "{a} vs {b}");
        }
    }

    #[test]
    fn zero_row_exact() {
        let row = vec![0f32; 16];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(scale, 1.0);
        assert_eq!(dequantize_kv(&codes, scale), row);
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(scale4, 1.0);
        assert_eq!(dequantize_kv_int4(&packed, 16, scale4), row);
    }

    #[test]
    fn subnormal_row_degrades_to_zero_codes() {
        // maxabs is subnormal, so maxabs/127 underflows to 0.0 — the old
        // `maxabs > 0.0` guard missed this and produced a zero scale.
        let row = vec![f32::MIN_POSITIVE / 4.0; 8];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(scale, 1.0);
        assert!(codes.iter().all(|&c| c == 0));
        assert!(dequantize_kv(&codes, scale).iter().all(|v| v.is_finite()));
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(scale4, 1.0);
        assert!(packed.iter().all(|&b| b == 0));
    }

    #[test]
    fn single_element_row() {
        let row = vec![3.0f32];
        let (codes, scale) = quantize_kv_int8(&row);
        assert_eq!(codes, vec![127]);
        assert!((scale - 3.0 / 127.0).abs() < 1e-9);
        let (packed, scale4) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 1);
        assert_eq!(packed[0] & 0x0F, 7);
        let dq = dequantize_kv_int4(&packed, 1, scale4);
        assert!((dq[0] - 3.0).abs() <= int4_tol(scale4));
    }

    #[test]
    fn extreme_value_maps_to_max_code() {
        let mut row = vec![0.01f32; 8];
        row[3] = -100.0;
        let (codes, _) = quantize_kv_int8(&row);
        assert_eq!(codes[3], -127);
        let (packed, scale4) = quantize_kv_int4(&row);
        let dq = dequantize_kv_int4(&packed, 8, scale4);
        assert!((dq[3] + 100.0).abs() < 1.0);
    }

    #[test]
    fn odd_length_int4() {
        let row = vec![1.0f32, -2.0, 3.0];
        let (packed, scale) = quantize_kv_int4(&row);
        assert_eq!(packed.len(), 2);
        let dq = dequantize_kv_int4(&packed, 3, scale);
        assert_eq!(dq.len(), 3);
    }

    #[test]
    fn int4_is_nested_refinement_of_int8() {
        let row: Vec<f32> = (0..64).map(|i| ((i * 37) % 17) as f32 * 0.25 - 2.0).collect();
        let (c8, s8) = quantize_kv_int8(&row);
        let (direct, sd) = quantize_kv_int4(&row);
        let (nested, sn) = int4_from_int8(&c8, s8);
        assert_eq!(direct, nested);
        assert_eq!(sd.to_bits(), sn.to_bits());
    }

    #[test]
    fn prop_word_codec_matches_scalar() {
        // The word-wise pack/unpack vs the retained byte-at-a-time
        // references: bit-identical across odd lengths, degenerate rows
        // (all-zero, subnormal), and extreme codes.
        run_prop("kv-word-vs-scalar", 0x51AB, 60, |g| {
            let n = g.usize_in(1, 130);
            let mut row = g.f32_vec(n, -8.0, 8.0);
            match g.usize_in(0, 4) {
                0 => row.iter_mut().for_each(|v| *v = 0.0),
                1 => row.iter_mut().for_each(|v| *v = f32::MIN_POSITIVE / 4.0),
                2 => row[0] = 1000.0,
                _ => {}
            }
            let (c8, s8) = quantize_kv_int8(&row);
            let (vp, vsc) = int4_from_int8(&c8, s8);
            let (sp, ssc) = int4_from_int8_scalar(&c8, s8);
            assert_eq!(vp, sp, "packed bytes diverge (n={n})");
            assert_eq!(vsc.to_bits(), ssc.to_bits());

            // Byte-level twin (the transcode path) agrees too.
            let bytes: Vec<u8> = c8.iter().map(|&c| c as u8).collect();
            let mut direct = vec![0xAAu8; n.div_ceil(2)];
            let dsc = pack_int4_from_i8_bytes(&bytes, s8, &mut direct);
            assert_eq!(direct, sp);
            assert_eq!(dsc.to_bits(), ssc.to_bits());

            let dv = dequantize_kv_int4(&vp, n, vsc);
            let ds = dequantize_kv_int4_scalar(&vp, n, vsc);
            assert_eq!(dv.len(), ds.len());
            for (a, b) in dv.iter().zip(&ds) {
                assert_eq!(a.to_bits(), b.to_bits(), "dequant diverges (n={n})");
            }
        });
    }

    #[test]
    fn prop_kv_roundtrip_error() {
        run_prop("kv-roundtrip", 0xCAFE, 50, |g| {
            let n = g.usize_in(1, 128);
            let row = g.f32_vec(n, -8.0, 8.0);
            let (c8, s8) = quantize_kv_int8(&row);
            for (a, b) in row.iter().zip(dequantize_kv(&c8, s8)) {
                assert!((a - b).abs() <= s8 * 0.5 + 1e-5);
            }
            let (c4, s4) = quantize_kv_int4(&row);
            for (a, b) in row.iter().zip(dequantize_kv_int4(&c4, n, s4)) {
                assert!((a - b).abs() <= int4_tol(s4));
            }
        });
    }
}

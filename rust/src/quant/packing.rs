//! §4.1 Hardware-aware weight packing — the paper's offline GEMM-pipeline
//! stage, implemented faithfully at the lane level.
//!
//! The four steps (paper Figures 5-7):
//!
//! 1. **Bit extension** — INT4 codes are widened to 16-bit so the standard
//!    (non-mixed-precision) fragment pipeline applies.
//! 2. **Fragment loading** — each 16×16 tile is pushed through the emulated
//!    `ldmatrix` crossbar ([`super::fragment`]), giving every lane the eight
//!    elements the MMA instruction expects it to own.
//! 3. **Bit compression** — inside "registers", each lane repacks its eight
//!    16-bit words back to INT4 nibbles in one 32-bit word, permuting the
//!    sub-words into interleaved order `{0,2,4,6,1,3,5,7}` so the runtime
//!    I2F extraction (even nibbles then odd nibbles, the lop3 idiom) lands
//!    values directly in MMA register order (Figure 6).
//! 4. **Fragment storing** — lanes write packed words back to global memory
//!    two fragments at a time: word index `lane*2 + frag`, so each lane
//!    issues one contiguous 8-byte store and the warp's 256-byte write is
//!    fully coalesced (Figure 7's "flattened 32×2×8 format").
//!
//! The payoff, verified by the tests below with the [`super::access`]
//! analyzer: at runtime every warp reloads fragments with a single
//! coalesced copy + direct per-lane word reads — **no swizzle, no bank
//! conflicts, no misalignment** (Challenges I, II, V).

use super::access::{analyze_global, AccessReport, LaneAccess};
use super::fragment::{Tile16x16, FRAG_ELEMS_PER_LANE, WARP_SIZE};
use super::groupwise::{sign_extend4, QuantizedMatrix};
use super::word::{mask_nibbles, pack_nibbles8, sign_extend4x8, spread_nibbles8};
use crate::config::DType;

/// Sub-word permutation applied in step (iii): position `i` of the packed
/// word holds source register `PERMUTE[i]`. Interleaved even/odd order —
/// the inverse of the two-phase nibble extraction the runtime I2F performs.
pub const PERMUTE: [usize; 8] = [0, 2, 4, 6, 1, 3, 5, 7];

/// Tile side (16×16 elements per fragment).
pub const TILE: usize = 16;

/// Hardware-aware packed INT4 weights: the §4.1 output format.
#[derive(Debug, Clone)]
pub struct PackedWeights {
    pub k: usize,
    pub n: usize,
    /// Packed stream: for each tile (row-major over the (K/16, N/16) grid),
    /// `WARP_SIZE` u32 words; tiles are stored in pairs with word index
    /// `pair_base + lane*2 + frag` (two-fragment storage).
    pub words: Vec<u32>,
    /// Per-group scales, identical to the source [`QuantizedMatrix`].
    pub scales: Vec<f32>,
    pub group_size: usize,
}

/// Pack a groupwise-quantized INT4 matrix with the four offline steps.
/// `K` and `N` must be multiples of 16 (fragment granularity).
pub fn pack_weights_hw_aware(q: &QuantizedMatrix) -> PackedWeights {
    assert_eq!(q.quant.dtype, DType::Int4, "hardware-aware packing is the INT4 path");
    assert!(q.k % TILE == 0 && q.n % TILE == 0, "K and N must be multiples of 16");
    let tiles_k = q.k / TILE;
    let tiles_n = q.n / TILE;
    let n_tiles = tiles_k * tiles_n;
    // Tiles are stored in pairs (two-fragment storage); an odd tile count
    // still reserves a full pair region for the tail fragment.
    let mut words = vec![0u32; n_tiles.div_ceil(2) * 2 * WARP_SIZE];

    for t in 0..n_tiles {
        let (tk, tn) = (t / tiles_n, t % tiles_n);
        // Step (i): bit extension — widen each nibble to u16.
        let tile = Tile16x16::from_fn(|r, c| {
            (q.code_at(tk * TILE + r, tn * TILE + c) as u8 & 0x0F) as u16
        });
        // Step (ii): fragment loading through the ldmatrix crossbar.
        let frags = tile.ldmatrix_fragments();
        // Step (iii): bit compression + sub-word permute.
        // Step (iv): two-fragment storage — tile pair (t & !1, t | 1) shares
        // a 64-word region; word index = pair_base + lane*2 + (t & 1).
        let pair_base = (t & !1) * WARP_SIZE;
        let frag_in_pair = t & 1;
        for (lane, frag) in frags.iter().enumerate() {
            let packed = compress_lane_word(frag);
            words[pair_base + lane * 2 + frag_in_pair] = packed;
        }
    }
    PackedWeights {
        k: q.k,
        n: q.n,
        words,
        scales: q.scales.clone(),
        group_size: q.quant.group_size,
    }
}

/// Step (iii) for one lane: pack 8 extended values into one u32 with the
/// MMA-order permutation. Nibble `i` (bits `4i..4i+4`) holds register
/// `PERMUTE[i]`'s low 4 bits. Word-level: the registers are gathered into
/// byte lanes in permuted order and compacted with one SWAR sequence —
/// the register-resident analogue of the prmt+lop3 idiom, bit-identical
/// to [`compress_lane_word_scalar`].
#[inline]
pub fn compress_lane_word(frag: &[u16; FRAG_ELEMS_PER_LANE]) -> u32 {
    // Byte lane `slot` holds frag[PERMUTE[slot]]; `as u8` keeps the low 4
    // bits the scalar path masks, mask_nibbles clears the rest.
    let lanes = u64::from_le_bytes([
        frag[0] as u8,
        frag[2] as u8,
        frag[4] as u8,
        frag[6] as u8,
        frag[1] as u8,
        frag[3] as u8,
        frag[5] as u8,
        frag[7] as u8,
    ]);
    pack_nibbles8(mask_nibbles(lanes))
}

/// Nibble-at-a-time reference for [`compress_lane_word`] — retained for
/// bit-identity property tests and the `bench hotpath` speedup ratio.
#[inline]
pub fn compress_lane_word_scalar(frag: &[u16; FRAG_ELEMS_PER_LANE]) -> u32 {
    let mut w = 0u32;
    for (slot, &src) in PERMUTE.iter().enumerate() {
        w |= ((frag[src] as u32) & 0xF) << (4 * slot);
    }
    w
}

/// The runtime I2F extraction: recover the 8 signed codes of a packed word
/// in MMA register order. Mirrors the two-phase lop3 idiom — even registers
/// come from the low four nibbles, odd registers from the high four — which
/// is exactly why step (iii) permuted them. Word-level: one nibble spread +
/// SWAR sign extension, then the 8-move inverse permute — bit-identical to
/// [`i2f_extract_scalar`].
#[inline]
pub fn i2f_extract(word: u32) -> [i8; FRAG_ELEMS_PER_LANE] {
    let ext = sign_extend4x8(spread_nibbles8(word)).to_le_bytes();
    let mut out = [0i8; FRAG_ELEMS_PER_LANE];
    for (slot, &dst) in PERMUTE.iter().enumerate() {
        out[dst] = ext[slot] as i8;
    }
    out
}

/// Nibble-at-a-time reference for [`i2f_extract`] — retained for
/// bit-identity property tests and the `bench hotpath` speedup ratio.
#[inline]
pub fn i2f_extract_scalar(word: u32) -> [i8; FRAG_ELEMS_PER_LANE] {
    let mut out = [0i8; FRAG_ELEMS_PER_LANE];
    for (slot, &dst) in PERMUTE.iter().enumerate() {
        out[dst] = sign_extend4(((word >> (4 * slot)) & 0xF) as u8);
    }
    out
}

impl PackedWeights {
    fn tiles_n(&self) -> usize {
        self.n / TILE
    }

    /// Number of 16×16 tiles.
    pub fn n_tiles(&self) -> usize {
        (self.k / TILE) * self.tiles_n()
    }

    /// Runtime fragment load: each lane reads *its own* u32 directly — the
    /// whole point of §4.1 is that no crossbar/swizzle is needed anymore.
    /// Returns per-lane signed codes in MMA register order.
    pub fn load_fragment(&self, tile: usize) -> [[i8; FRAG_ELEMS_PER_LANE]; WARP_SIZE] {
        let pair_base = (tile & !1) * WARP_SIZE;
        let frag_in_pair = tile & 1;
        let mut out = [[0i8; FRAG_ELEMS_PER_LANE]; WARP_SIZE];
        for (lane, o) in out.iter_mut().enumerate() {
            *o = i2f_extract(self.words[pair_base + lane * 2 + frag_in_pair]);
        }
        out
    }

    /// The warp's global-memory access pattern for loading one tile *pair*
    /// at runtime (each lane reads its two adjacent u32 words).
    pub fn runtime_load_access(&self, tile: usize) -> Vec<LaneAccess> {
        let pair_base = (tile & !1) * WARP_SIZE;
        (0..WARP_SIZE)
            .map(|lane| LaneAccess { addr: (pair_base + lane * 2) * 4, len: 8 })
            .collect()
    }

    /// Access report for the runtime load (should be fully coalesced and
    /// conflict-free — the §4.1 guarantee).
    pub fn runtime_load_report(&self, tile: usize, segment_bytes: usize) -> AccessReport {
        analyze_global(&self.runtime_load_access(tile), segment_bytes)
    }

    /// Full inverse: reconstruct the original INT4 codes as a dense i8
    /// row-major `[K, N]` matrix (for round-trip verification).
    pub fn unpack_codes(&self) -> Vec<i8> {
        let tiles_n = self.tiles_n();
        // Lane → fragment coordinates are tile-invariant; hoisting them out
        // of the tile loop (they used to be derived per tile per lane)
        // keeps the loop bound by the word-level i2f extraction.
        let coords: Vec<[(usize, usize); FRAG_ELEMS_PER_LANE]> =
            (0..WARP_SIZE).map(super::fragment::mma_a_lane_coords).collect();
        let mut out = vec![0i8; self.k * self.n];
        for t in 0..self.n_tiles() {
            let (tk, tn) = (t / tiles_n, t % tiles_n);
            let frags = self.load_fragment(t);
            for (lane, frag) in frags.iter().enumerate() {
                for (i, (r, c)) in coords[lane].iter().enumerate() {
                    out[(tk * TILE + r) * self.n + (tn * TILE + c)] = frag[i];
                }
            }
        }
        out
    }

    /// Dequantize the packed weights back to f32 (round-trip check against
    /// `QuantizedMatrix::dequantize`).
    pub fn dequantize(&self) -> Vec<f32> {
        let codes = self.unpack_codes();
        let groups_row = |r: usize| r / self.group_size;
        let mut out = vec![0f32; self.k * self.n];
        for r in 0..self.k {
            for c in 0..self.n {
                out[r * self.n + c] =
                    codes[r * self.n + c] as f32 * self.scales[groups_row(r) * self.n + c];
            }
        }
        out
    }

    /// Packed storage bytes (words + scales).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 4 + self.scales.len() * 4
    }
}

/// Baseline for the ablation: the warp access pattern for gathering one
/// 16×16 tile's MMA fragments straight from a *naive row-major packed*
/// INT4 matrix of width `n` (no offline packing). Each lane must gather
/// eight sub-byte elements scattered across rows — the paper's Challenge-I
/// and -II failure mode.
pub fn naive_fragment_access(n: usize, tile_k: usize, tile_n: usize) -> Vec<LaneAccess> {
    let mut acc = Vec::with_capacity(WARP_SIZE * FRAG_ELEMS_PER_LANE);
    for lane in 0..WARP_SIZE {
        for (r, c) in super::fragment::mma_a_lane_coords(lane) {
            let elem = (tile_k * TILE + r) * n + (tile_n * TILE + c);
            // Packed INT4: element `elem` lives at byte elem/2; loads are
            // at least 1 byte each.
            acc.push(LaneAccess { addr: elem / 2, len: 1 });
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::groupwise::GroupwiseQuant;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Rng;

    fn quantized(k: usize, n: usize, seed: u64) -> QuantizedMatrix {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| (rng.next_f32() - 0.5) * 4.0).collect();
        QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(16))
    }

    #[test]
    fn permute_is_a_permutation() {
        let mut p = PERMUTE;
        p.sort_unstable();
        assert_eq!(p, [0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn compress_extract_roundtrip() {
        let frag: [u16; 8] = [0x1, 0xF, 0x8, 0x7, 0x0, 0x9, 0x3, 0xE];
        let word = compress_lane_word(&frag);
        let codes = i2f_extract(word);
        for i in 0..8 {
            assert_eq!(codes[i], sign_extend4(frag[i] as u8), "reg {i}");
        }
    }

    #[test]
    fn prop_word_compress_extract_match_scalar() {
        // The SWAR compress/extract vs the retained nibble-at-a-time
        // references: bit-identical for arbitrary register contents
        // (including values wider than a nibble — only the low 4 bits of
        // each register may matter) and arbitrary packed words.
        run_prop("packing-word-vs-scalar", 0xC0DE, 40, |g| {
            let mut frag = [0u16; FRAG_ELEMS_PER_LANE];
            for f in frag.iter_mut() {
                *f = g.usize_in(0, 0xFFFF) as u16;
            }
            let wv = compress_lane_word(&frag);
            let ws = compress_lane_word_scalar(&frag);
            assert_eq!(wv, ws, "compress diverges on {frag:?}");
            let word = g.usize_in(0, u32::MAX as usize) as u32;
            assert_eq!(i2f_extract(word), i2f_extract_scalar(word), "extract diverges on {word:#x}");
        });
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let q = quantized(64, 32, 1);
        let p = pack_weights_hw_aware(&q);
        let codes = p.unpack_codes();
        for r in 0..q.k {
            for c in 0..q.n {
                assert_eq!(codes[r * q.n + c], q.code_at(r, c), "({r},{c})");
            }
        }
    }

    #[test]
    fn dequantize_matches_source() {
        let q = quantized(32, 48, 2);
        let p = pack_weights_hw_aware(&q);
        assert_eq!(p.dequantize(), q.dequantize());
    }

    #[test]
    fn runtime_load_is_coalesced_and_conflict_free() {
        // The §4.1 guarantee, measured: every tile-pair load is 2 segments
        // for 256 useful bytes (ideal) with zero bank conflicts.
        let q = quantized(64, 64, 3);
        let p = pack_weights_hw_aware(&q);
        for t in 0..p.n_tiles() {
            let r = p.runtime_load_report(t, 128);
            assert!(r.is_fully_coalesced(), "tile {t}: {r:?}");
            assert!(r.is_conflict_free(), "tile {t}: {r:?}");
            assert_eq!(r.useful_bytes, 256);
            assert_eq!(r.transactions, 2);
        }
    }

    #[test]
    fn naive_layout_is_pathological() {
        // Without offline packing, gathering fragments from a row-major
        // packed matrix of realistic width costs an order of magnitude more
        // transactions and serializes on banks (Challenges I & II).
        let n = 4096;
        let naive = naive_fragment_access(n, 0, 0);
        let r = analyze_global(&naive, 128);
        assert!(r.transactions >= 16, "transactions {}", r.transactions);
        assert!(!r.is_fully_coalesced());
        assert!(r.bank_conflict_degree >= 8, "degree {}", r.bank_conflict_degree);
    }

    #[test]
    fn packed_layout_beats_naive_by_an_order_of_magnitude() {
        let q = quantized(64, 4096, 4);
        let p = pack_weights_hw_aware(&q);
        let packed = p.runtime_load_report(0, 128);
        let naive = analyze_global(&naive_fragment_access(4096, 0, 0), 128);
        // Two tiles per packed report vs one naive tile — still ≥8× better.
        assert!(
            naive.transactions as f64 / (packed.transactions as f64 / 2.0) >= 8.0,
            "naive {} packed {}",
            naive.transactions,
            packed.transactions
        );
    }

    #[test]
    fn load_fragment_matches_ldmatrix_semantics() {
        // Runtime direct loads must yield exactly what ldmatrix would have
        // produced from the unpacked tile — i.e. packing baked the swizzle
        // in offline (Appendix C).
        let q = quantized(16, 32, 5);
        let p = pack_weights_hw_aware(&q);
        for t in 0..2 {
            let tile = Tile16x16::from_fn(|r, c| (q.code_at(r, t * 16 + c) as u8 & 0xF) as u16);
            let expect = tile.ldmatrix_fragments();
            let got = p.load_fragment(t);
            for lane in 0..WARP_SIZE {
                for i in 0..FRAG_ELEMS_PER_LANE {
                    assert_eq!(got[lane][i], sign_extend4(expect[lane][i] as u8));
                }
            }
        }
    }

    #[test]
    fn storage_is_exactly_int4_plus_scales() {
        let q = quantized(64, 64, 6);
        let p = pack_weights_hw_aware(&q);
        assert_eq!(p.words.len() * 4, 64 * 64 / 2);
        assert_eq!(p.storage_bytes(), q.storage_bytes());
    }

    #[test]
    fn odd_tile_count_single_fragment_tail() {
        // 3 tiles: the last pair has only one fragment; round-trip intact.
        let q = quantized(16, 48, 7);
        let p = pack_weights_hw_aware(&q);
        assert_eq!(p.n_tiles(), 3);
        let codes = p.unpack_codes();
        for r in 0..16 {
            for c in 0..48 {
                assert_eq!(codes[r * 48 + c], q.code_at(r, c));
            }
        }
    }

    #[test]
    fn prop_pack_roundtrip_random_shapes() {
        run_prop("pack-roundtrip", 0xFEED, 25, |g| {
            let k = 16 * g.usize_in(1, 6);
            let n = 16 * g.usize_in(1, 6);
            let w = g.f32_vec(k * n, -2.0, 2.0);
            let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(16));
            let p = pack_weights_hw_aware(&q);
            let codes = p.unpack_codes();
            for r in 0..k {
                for c in 0..n {
                    assert_eq!(codes[r * n + c], q.code_at(r, c));
                }
            }
            for t in 0..p.n_tiles() {
                let rep = p.runtime_load_report(t, 128);
                assert!(rep.is_fully_coalesced() && rep.is_conflict_free());
            }
        });
    }

    #[test]
    #[should_panic(expected = "multiples of 16")]
    fn rejects_unaligned_shapes() {
        let w = vec![0f32; 8 * 8];
        let q = QuantizedMatrix::quantize(&w, 8, 8, GroupwiseQuant::int4(8));
        pack_weights_hw_aware(&q);
    }
}

//! Quantization substrate: groupwise weight quantization, per-token KV-cache
//! quantization, and the paper's §4.1 *hardware-aware weight packing*.
//!
//! Layout notes
//! ------------
//! * Weights are quantized **groupwise along the input (K) dimension** with
//!   symmetric scales (AWQ/GPTQ-style, group size 64 by default) — the same
//!   scheme `python/compile/quantize.py` implements; the two are
//!   cross-validated by shared test vectors.
//! * KV cache entries are quantized **per token per KV-head** (asymmetric
//!   max-abs symmetric scale), matching the paper's KV8/KV4 formats.
//! * [`packing`] implements the four offline packing steps of §4.1 on an
//!   emulated 32-lane warp, and [`access`] provides the transaction /
//!   bank-conflict analyzer used to verify the packed layout's three
//!   built-in guarantees (coalesced, conflict-free, MMA-aligned).

pub mod access;
pub mod fragment;
pub mod groupwise;
pub mod kv;
pub mod packing;
pub mod qrearrange;
pub mod swizzle;
pub mod transcode;
pub mod word;

pub use groupwise::{GroupwiseQuant, QuantizedMatrix};
pub use kv::{dequantize_kv, int4_from_int8, quantize_kv_int4, quantize_kv_int8};
pub use transcode::{f32_row_to_int4, f32_row_to_int8, int8_row_to_int4};
pub use packing::{pack_weights_hw_aware, PackedWeights};

//! Warp fragment layouts and an `ldmatrix` emulator.
//!
//! This module models the lane-level data movement the paper's §4.1 packing
//! relies on: the `mma.sync.m16n8k16` operand-A fragment layout (PTX ISA
//! §9.7.13) and the `ldmatrix` crossbar redistribution (Figure 5 of the
//! paper). Operating on emulated 32-lane warps lets the offline packing run
//! — and be *verified* — on real buffers without a GPU.

/// Lanes per warp on every modeled architecture.
pub const WARP_SIZE: usize = 32;

/// Elements each lane holds of a 16×16 16-bit operand-A fragment.
pub const FRAG_ELEMS_PER_LANE: usize = 8;

/// The (row, col) element coordinates lane `lane` holds for a 16×16
/// `mma.sync.m16n8k16` operand-A tile, in register order `a0..a7`.
///
/// PTX layout: `groupID = lane >> 2`, `tid = lane % 4`;
/// `a0,a1 -> (groupID, tid*2 + {0,1})`, `a2,a3 -> (groupID+8, tid*2 + {0,1})`,
/// `a4,a5 -> (groupID, tid*2+8 + {0,1})`, `a6,a7 -> (groupID+8, tid*2+8+{0,1})`.
pub fn mma_a_lane_coords(lane: usize) -> [(usize, usize); FRAG_ELEMS_PER_LANE] {
    debug_assert!(lane < WARP_SIZE);
    let group = lane >> 2;
    let tid = lane & 3;
    [
        (group, tid * 2),
        (group, tid * 2 + 1),
        (group + 8, tid * 2),
        (group + 8, tid * 2 + 1),
        (group, tid * 2 + 8),
        (group, tid * 2 + 8 + 1),
        (group + 8, tid * 2 + 8),
        (group + 8, tid * 2 + 8 + 1),
    ]
}

/// A 16×16 tile of 16-bit-extended values in row-major "shared memory"
/// order, plus fragment extraction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tile16x16 {
    /// Row-major `[16][16]` values (bit-extended low-bit codes).
    pub data: [u16; 256],
}

impl Tile16x16 {
    pub fn from_fn(mut f: impl FnMut(usize, usize) -> u16) -> Self {
        let mut data = [0u16; 256];
        for r in 0..16 {
            for c in 0..16 {
                data[r * 16 + c] = f(r, c);
            }
        }
        Self { data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u16 {
        self.data[r * 16 + c]
    }

    /// Emulate `ldmatrix.x4`: produce each lane's 8-element register
    /// fragment in the `mma.m16n8k16` operand-A layout. This is step (ii)
    /// of §4.1 — the instruction's internal crossbar redistributes words
    /// across lanes (paper Figure 5), which this function reproduces.
    pub fn ldmatrix_fragments(&self) -> [[u16; FRAG_ELEMS_PER_LANE]; WARP_SIZE] {
        let mut frags = [[0u16; FRAG_ELEMS_PER_LANE]; WARP_SIZE];
        for (lane, frag) in frags.iter_mut().enumerate() {
            for (i, (r, c)) in mma_a_lane_coords(lane).iter().enumerate() {
                frag[i] = self.at(*r, *c);
            }
        }
        frags
    }

    /// The shared-memory *row addresses* each lane supplies to `ldmatrix.x4`
    /// (one 16-byte row of an 8×8 16-bit submatrix per lane), as
    /// (byte_offset, byte_len) pairs relative to the tile base. Used by the
    /// access analyzer to show the pre-redistribution conflict pattern the
    /// paper's Figure 5 describes ("each thread loads one matrix row
    /// (16-byte), resulting in 8-way bank conflict" under a naive layout).
    pub fn ldmatrix_row_addresses(&self) -> [(usize, usize); WARP_SIZE] {
        let mut addrs = [(0usize, 16usize); WARP_SIZE];
        // .x4 loads four 8x8 submatrices; lanes 0-7 address submatrix 0
        // (rows 0-7, cols 0-7), 8-15 submatrix 1 (rows 8-15, cols 0-7),
        // 16-23 submatrix 2 (rows 0-7, cols 8-15), 24-31 submatrix 3.
        for (lane, addr) in addrs.iter_mut().enumerate() {
            let sub = lane / 8;
            let row_in_sub = lane % 8;
            let (row, col) = match sub {
                0 => (row_in_sub, 0),
                1 => (row_in_sub + 8, 0),
                2 => (row_in_sub, 8),
                _ => (row_in_sub + 8, 8),
            };
            *addr = ((row * 16 + col) * 2, 16);
        }
        addrs
    }
}

/// Inverse of [`mma_a_lane_coords`]: map a (row, col) element to its
/// (lane, register index).
pub fn coord_to_lane(r: usize, c: usize) -> (usize, usize) {
    let group = r % 8;
    let tid = (c % 8) / 2;
    let lane = group * 4 + tid;
    let reg = (c % 2) + if r >= 8 { 2 } else { 0 } + if c >= 8 { 4 } else { 0 };
    (lane, reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_coords_cover_tile_exactly_once() {
        let mut seen = [[false; 16]; 16];
        for lane in 0..WARP_SIZE {
            for (r, c) in mma_a_lane_coords(lane) {
                assert!(!seen[r][c], "({r},{c}) covered twice");
                seen[r][c] = true;
            }
        }
        assert!(seen.iter().all(|row| row.iter().all(|&x| x)));
    }

    #[test]
    fn coord_to_lane_inverts_lane_coords() {
        for lane in 0..WARP_SIZE {
            for (i, (r, c)) in mma_a_lane_coords(lane).iter().enumerate() {
                assert_eq!(coord_to_lane(*r, *c), (lane, i), "({r},{c})");
            }
        }
    }

    #[test]
    fn ldmatrix_fragments_match_layout() {
        let tile = Tile16x16::from_fn(|r, c| (r * 16 + c) as u16);
        let frags = tile.ldmatrix_fragments();
        // Lane 0: a0,a1 = (0,0),(0,1); a2 = (8,0) = 128...
        assert_eq!(frags[0][0], 0);
        assert_eq!(frags[0][1], 1);
        assert_eq!(frags[0][2], 128);
        assert_eq!(frags[0][4], 8);
        // Lane 5 (group 1, tid 1): a0 = (1, 2) = 18.
        assert_eq!(frags[5][0], 18);
    }

    #[test]
    fn row_addresses_are_16_byte_rows() {
        let tile = Tile16x16::from_fn(|_, _| 0);
        for (off, len) in tile.ldmatrix_row_addresses() {
            assert_eq!(len, 16);
            assert_eq!(off % 16, 0);
            assert!(off < 512);
        }
    }

    #[test]
    fn row_addresses_distinct() {
        let tile = Tile16x16::from_fn(|_, _| 0);
        let mut offs: Vec<_> = tile.ldmatrix_row_addresses().iter().map(|a| a.0).collect();
        offs.sort_unstable();
        offs.dedup();
        assert_eq!(offs.len(), WARP_SIZE);
    }
}

//! Word-level (SWAR) nibble/byte swizzles shared by the hot codec paths.
//!
//! The paper's kernel-side wins come from handling codes a *word* at a
//! time ("instruction-level parallelism for memory hierarchy
//! exploitation", §4.1's register-resident bit compression). This module
//! is the CPU analogue: every primitive moves 8 codes per `u64` (or per
//! `u32` of packed nibbles) using shift/mask sequences only — **no float
//! math**, so callers can vectorize byte movement while keeping rounding
//! bit-identical to the scalar reference implementations they retain.
//!
//! Conventions: nibble `i` of a packed word is bits `4i..4i+4`
//! (little-endian nibble order), byte lane `i` of a spread word is bits
//! `8i..8i+8` — both match `u32::from_le_bytes`/`u64::to_le_bytes` on the
//! byte streams the KV pool stores.

/// Low-nibble byte-lane mask.
const NIB_LO: u64 = 0x0F0F_0F0F_0F0F_0F0F;

/// Compact the low nibble of each of 8 byte lanes into one `u32`:
/// nibble `i` of the result = low nibble of byte lane `i` of `w`.
/// High nibbles of `w` must be clear (callers mask with [`mask_nibbles`]).
#[inline]
pub fn pack_nibbles8(w: u64) -> u32 {
    debug_assert_eq!(w & !NIB_LO, 0, "high nibbles must be clear");
    // 0x0a0b0c0d... byte lanes -> pairwise merge: 4-bit, 8-bit, 16-bit.
    let x = (w | (w >> 4)) & 0x00FF_00FF_00FF_00FF;
    let x = (x | (x >> 8)) & 0x0000_FFFF_0000_FFFF;
    (x | (x >> 16)) as u32
}

/// Inverse of [`pack_nibbles8`]: spread the 8 nibbles of `w` into the low
/// nibbles of 8 byte lanes (high nibbles zero).
#[inline]
pub fn spread_nibbles8(w: u32) -> u64 {
    let x = w as u64;
    let x = (x | (x << 16)) & 0x0000_FFFF_0000_FFFF;
    let x = (x | (x << 8)) & 0x00FF_00FF_00FF_00FF;
    (x | (x << 4)) & NIB_LO
}

/// Clear the high nibble of every byte lane.
#[inline]
pub fn mask_nibbles(w: u64) -> u64 {
    w & NIB_LO
}

/// Word-wise all-zero scan (8 bytes per compare, scalar tail) — the
/// degenerate-row check on the quantize/transcode paths.
#[inline]
pub fn all_zero_bytes(bytes: &[u8]) -> bool {
    let mut chunks = bytes.chunks_exact(8);
    (&mut chunks).all(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")) == 0)
        && chunks.remainder().iter().all(|&b| b == 0)
}

/// Sign-extend a 4-bit code in each byte lane to a full `i8` byte lane:
/// lanes holding `0x0..=0x7` stay as-is, lanes holding `0x8..=0xF` get
/// their high nibble set to `0xF0` (two's-complement extension). High
/// nibbles of `w` must be clear on entry. Bit-identical per lane to
/// [`super::groupwise::sign_extend4`].
#[inline]
pub fn sign_extend4x8(w: u64) -> u64 {
    debug_assert_eq!(w & !NIB_LO, 0, "high nibbles must be clear");
    // One sign bit per lane, multiplied out to 0xF0 — the per-lane
    // products are < 256 so the multiply never carries across lanes.
    let sign = (w >> 3) & 0x0101_0101_0101_0101;
    w | sign * 0xF0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::groupwise::sign_extend4;
    use crate::util::rng::Rng;

    #[test]
    fn pack_spread_roundtrip_exhaustive_lanes() {
        // Every nibble value in every lane position survives the
        // pack -> spread -> pack cycle.
        for lane in 0..8 {
            for v in 0u64..16 {
                let w = v << (8 * lane);
                let packed = pack_nibbles8(w);
                assert_eq!(packed, (v as u32) << (4 * lane), "lane {lane} v {v}");
                assert_eq!(spread_nibbles8(packed), w);
            }
        }
    }

    #[test]
    fn pack_spread_roundtrip_random_words() {
        let mut rng = Rng::new(0x50AC);
        for _ in 0..2000 {
            let w = mask_nibbles(rng.next_u64());
            assert_eq!(spread_nibbles8(pack_nibbles8(w)), w);
        }
    }

    #[test]
    fn all_zero_scan_matches_scalar_at_every_length() {
        for n in 0..40 {
            let zeros = vec![0u8; n];
            assert!(all_zero_bytes(&zeros), "len {n}");
            for hot in 0..n {
                let mut v = zeros.clone();
                v[hot] = 1;
                assert!(!all_zero_bytes(&v), "len {n} hot {hot}");
            }
        }
    }

    #[test]
    fn sign_extend_matches_scalar_per_lane() {
        for v in 0u8..16 {
            let w = sign_extend4x8((v as u64) * 0x0101_0101_0101_0101);
            for (lane, b) in w.to_le_bytes().iter().enumerate() {
                assert_eq!(*b as i8, sign_extend4(v), "lane {lane} v {v}");
            }
        }
        // Mixed lanes: no cross-lane interference.
        let w = sign_extend4x8(0x0F08_0700_0109_0E02);
        let got: Vec<i8> = w.to_le_bytes().iter().map(|&b| b as i8).collect();
        let want: Vec<i8> =
            [0x2u8, 0xE, 0x9, 0x1, 0x0, 0x7, 0x8, 0xF].iter().map(|&n| sign_extend4(n)).collect();
        assert_eq!(got, want);
    }
}

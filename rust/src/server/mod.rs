//! JSON-lines TCP serving front-end.
//!
//! Protocol (one JSON object per line):
//!   → `{"prompt": [1,2,3], "max_new_tokens": 16}`
//!   ← `{"id": 0, "tokens": [...], "finish": "length", "ttft_s": ..., "latency_s": ...,
//!      "prefix_hit_tokens": 0, "preempt_count": 0, "swapped_in_blocks": 0,
//!      "abort_reason": null}`
//!   → `{"stats": true}`
//!   ← `{"pool_blocks_total": ..., "pool_blocks_free": ..., "pool_utilization": ...,
//!      "prefix_cache_enabled": ..., "prefix_cache_hit_rate": ...,
//!      "preemption_mode": "swap", "swap_blocks_used": ..., "swap_utilization": ..., ...}`
//!
//! The listener thread accepts connections and forwards requests over a
//! channel to the engine thread, which loops `engine.step()`; responses
//! travel back through per-request channels. One engine thread (execution
//! backends are not thread-safe to share mutably) — concurrency comes from
//! continuous batching, exactly like production single-GPU serving. The
//! engine's backend is whatever `EngineConfig.backend` selected: the
//! hermetic sim backend by default, PJRT artifacts behind the feature.
//!
//! Protocol errors (malformed JSON, empty prompt, zero budget) produce a
//! structured `{"error": ...}` line; the connection stays open. Engine
//! rejections (oversized requests) come back as normal outputs with
//! `"finish": "aborted"`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::Cluster;
use crate::coordinator::{Engine, FinishReason, Request, RequestOutput};
use crate::kvcache::SwapBackend;
use crate::metrics::MetricsCollector;
use crate::util::json::{arr, obj, Json};

/// A message forwarded from a connection to the engine thread.
enum Inbound {
    /// A generation request; the output travels back on `reply`.
    Gen { req: Request, reply: Sender<RequestOutput> },
    /// A `{"stats": true}` probe: answered immediately from engine state
    /// (pool utilization, prefix-cache hit rate), no scheduling involved.
    Stats { reply: Sender<Json> },
    /// A `{"trace": true}` / `{"trace": N}` probe: the last-N flight-
    /// recorder ring events (`0` = the whole resident ring), answered
    /// immediately like `Stats`.
    Trace { last: usize, reply: Sender<Json> },
}

/// Serve `engine` on `addr` (e.g. `127.0.0.1:7181`).
///
/// The engine loop runs on the **calling** thread (PJRT handles are not
/// `Send`); a listener thread accepts connections and forwards requests
/// over a channel. Blocks forever unless `max_requests` is set (tests /
/// bounded runs): the loop returns after **answering that many
/// generation requests** (aborted answers count — the client got its
/// response line; the `completed_requests` stats field tracks successes
/// only). `{"stats": true}` probes, protocol errors, and engine-rejected
/// requests never burn the shutdown budget — a monitoring probe must not
/// shorten a bounded run (the pre-fix behavior also capped accepted
/// *connections*, so idle probes starved real clients).
pub fn serve(engine: Engine, addr: &str, max_requests: Option<usize>) -> Result<()> {
    serve_with_trace_out(engine, addr, max_requests, None)
}

/// [`serve`], plus a Chrome-trace export: when `trace_out` is set (and the
/// engine records — `--trace`), the flight-recorder ring is written as
/// Perfetto-loadable trace-event JSON after the serve loop returns
/// (bounded runs; an unbounded serve never reaches the export).
pub fn serve_with_trace_out(
    mut engine: Engine,
    addr: &str,
    max_requests: Option<usize>,
    trace_out: Option<&str>,
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("turbomind serving on {addr}");
    let poke = poke_addr(&listener, addr);
    let (tx, rx): (Sender<Inbound>, Receiver<Inbound>) = mpsc::channel();
    let stop = spawn_listener(listener, tx);
    let result = engine_loop(&mut engine, &rx, max_requests);
    stop_listener(&stop, &poke);
    if let Some(path) = trace_out {
        let dump = engine.trace_dump();
        let track = crate::trace::TraceTrack { tid: 0, label: "engine".into(), dump: &dump };
        crate::trace::write_chrome(path, &[track])?;
        eprintln!(
            "trace: {} events ({} dropped) -> {path}",
            dump.events.len(),
            dump.dropped
        );
    }
    result
}

/// The serve loop body: dispatch finished outputs, admit from the
/// channel, step — on the calling thread, until the bounded-run budget is
/// spent or every sender is gone.
fn engine_loop(
    engine: &mut Engine,
    rx: &Receiver<Inbound>,
    max_requests: Option<usize>,
) -> Result<()> {
    let mut pending: Vec<(u64, Sender<RequestOutput>)> = Vec::new();
    let mut metrics = MetricsCollector::new();
    let started = Instant::now();
    let mut served = 0usize;
    loop {
        // Dispatch finished outputs FIRST — `submit` can finish a request
        // immediately (pool-oversized → Aborted), so outputs may exist
        // before any step runs, and the loop must never block on the
        // channel while a client is still waiting for one.
        for out in engine.take_outputs() {
            if let Some(pos) = pending.iter().position(|(id, _)| *id == out.id) {
                let (_, reply) = pending.remove(pos);
                // Percentiles summarize *successful* completions; an
                // aborted answer's near-zero latency would drag p50
                // toward zero under overload.
                if out.finish != FinishReason::Aborted {
                    metrics.record(
                        out.latency,
                        out.ttft,
                        started.elapsed().as_secs_f64(),
                        out.prompt_len,
                        out.tokens.len(),
                    );
                }
                let _ = reply.send(out);
                served += 1;
            }
        }
        if let Some(maxr) = max_requests {
            if served >= maxr && !engine.has_work() {
                return Ok(());
            }
        }
        // Admit all queued requests without blocking; block only when the
        // engine is idle (and nothing awaits dispatch).
        loop {
            let inbound = if engine.has_work() {
                match rx.try_recv() {
                    Ok(i) => i,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
                }
            } else {
                match rx.recv() {
                    Ok(i) => i,
                    Err(_) => return Ok(()), // listener and all conns gone
                }
            };
            let (req, reply) = match inbound {
                Inbound::Stats { reply } => {
                    // Probes are answered from state and deliberately do
                    // NOT count toward `max_requests`.
                    let _ = reply.send(stats_json(engine, &metrics));
                    continue;
                }
                Inbound::Trace { last, reply } => {
                    let _ = reply.send(trace_json(engine, last));
                    continue;
                }
                Inbound::Gen { req, reply } => (req, reply),
            };
            match engine.submit(req) {
                Ok(id) => {
                    pending.push((id, reply));
                    if !engine.has_work() {
                        // Finished at submit time: dispatch before blocking.
                        break;
                    }
                }
                Err(e) => {
                    // Report rejection as an aborted output; rejections
                    // never count toward the shutdown budget.
                    let _ = reply.send(RequestOutput::rejected(e.to_string()));
                    eprintln!("rejected request: {e}");
                }
            }
        }
        engine.step()?;
    }
}

/// Spawn the accept loop: unbounded accepts, one reader thread per
/// connection. Returns the stop flag [`stop_listener`] uses to shut it
/// down — without it, a bounded run would leak a thread blocked in
/// `accept` holding the port for the rest of the process.
fn spawn_listener(listener: TcpListener, tx: Sender<Inbound>) -> Arc<AtomicBool> {
    let stop = Arc::new(AtomicBool::new(false));
    let lstop = Arc::clone(&stop);
    thread::spawn(move || {
        for stream in listener.incoming() {
            if lstop.load(Ordering::SeqCst) {
                break; // drops the listener, releasing the port
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            thread::spawn(move || {
                if let Err(e) = handle_conn(stream, tx) {
                    eprintln!("connection error: {e}");
                }
            });
        }
        // tx dropped here once the accept loop ends.
    });
    stop
}

/// Signal the accept loop to exit and poke it awake with a throwaway
/// connection (accept blocks otherwise); ignores failures — the listener
/// may already be gone.
fn stop_listener(stop: &Arc<AtomicBool>, poke: &str) {
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(poke);
}

/// A connectable address for the wake-up poke: the listener's actual
/// local address, with an unspecified host (`0.0.0.0` / `::`) rewritten
/// to loopback — connecting to the wildcard address is not portable.
fn poke_addr(listener: &TcpListener, fallback: &str) -> String {
    match listener.local_addr() {
        Ok(mut a) => {
            if a.ip().is_unspecified() {
                a.set_ip(match a.ip() {
                    std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                    std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
                });
            }
            a.to_string()
        }
        Err(_) => fallback.to_string(),
    }
}

/// Serve a replica [`Cluster`] on `addr`: same JSON-lines protocol, same
/// connection handling, but requests route through the cluster's policy
/// to one of N engine replicas (each on its own thread), and the
/// `{"stats": true}` probe answers with the merged [`crate::cluster::
/// ClusterStats`] line instead of single-engine state.
///
/// The calling thread runs the dispatcher: it routes and forwards — the
/// replica threads do the engine work, and replies travel replica →
/// connection directly. A full replica inbox blocks dispatch
/// (backpressure). With `max_requests`, the dispatcher stops after
/// routing that many generation requests, then drains the fleet
/// (outstanding replies still arrive) and returns. Probes and
/// router-level dispatch failures ride free, mirroring [`serve`]; one
/// divergence: a request the *replica engine* rejects at submit still
/// consumed budget, because the dispatcher hands off before the engine
/// decides (it cannot see the rejection from here).
pub fn serve_cluster(mut cluster: Cluster, addr: &str, max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "turbomind cluster serving on {addr} ({} replicas)",
        cluster.n_replicas()
    );
    let poke = poke_addr(&listener, addr);
    let (tx, rx): (Sender<Inbound>, Receiver<Inbound>) = mpsc::channel();
    let stop = spawn_listener(listener, tx);
    let result = dispatch_loop(&mut cluster, &rx, max_requests);
    stop_listener(&stop, &poke);
    // Close inboxes; replicas drain outstanding requests (answering their
    // clients) before exiting.
    cluster.shutdown()?;
    result
}

/// The cluster dispatcher body: route generation requests by policy,
/// answer probes with the merged fleet line, stop once the bounded-run
/// budget is spent or every sender is gone.
fn dispatch_loop(
    cluster: &mut Cluster,
    rx: &Receiver<Inbound>,
    max_requests: Option<usize>,
) -> Result<()> {
    let mut dispatched = 0usize;
    for inbound in rx.iter() {
        match inbound {
            Inbound::Stats { reply } => {
                let _ = reply.send(cluster.stats()?.to_json());
            }
            Inbound::Trace { last, reply } => {
                let _ = reply.send(cluster.trace(last)?);
            }
            Inbound::Gen { req, reply } => {
                if let Err(e) = cluster.submit_with(req, reply.clone()) {
                    let _ = reply.send(RequestOutput::rejected(e.to_string()));
                    continue;
                }
                dispatched += 1;
                if max_requests.is_some_and(|maxr| dispatched >= maxr) {
                    return Ok(());
                }
            }
        }
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, tx: Sender<Inbound>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = if is_stats_request(&line) {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Inbound::Stats { reply: rtx }).map_err(|_| anyhow!("engine gone"))?;
            rrx.recv().map_err(|_| anyhow!("engine dropped stats probe"))?
        } else if let Some(last) = trace_request_last(&line) {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Inbound::Trace { last, reply: rtx })
                .map_err(|_| anyhow!("engine gone"))?;
            rrx.recv().map_err(|_| anyhow!("engine dropped trace probe"))?
        } else {
            match parse_request(&line) {
                Ok(req) => {
                    let (rtx, rrx) = mpsc::channel();
                    tx.send(Inbound::Gen { req, reply: rtx })
                        .map_err(|_| anyhow!("engine gone"))?;
                    let out = rrx.recv().map_err(|_| anyhow!("engine dropped request"))?;
                    encode_output(&out)
                }
                // Malformed input never drops the connection: the client
                // gets a structured error line and the stream stays usable.
                Err(e) => encode_error(&e.to_string()),
            }
        };
        writer.write_all(response.dump().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    eprintln!("connection {peer} closed");
    Ok(())
}

/// Parse a request line. Rejects malformed JSON, non-integer tokens, empty
/// prompts, and a zero `max_new_tokens` budget — all before anything
/// reaches the engine, so protocol errors never consume scheduler
/// iterations.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let prompt = v
        .req_arr("prompt")
        .map_err(|e| anyhow!("{e}"))?
        .iter()
        .map(|t| t.as_i64().map(|x| x as i32).ok_or_else(|| anyhow!("bad token")))
        .collect::<Result<Vec<i32>>>()?;
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let max_new = match v.get("max_new_tokens") {
        None => 16,
        Some(m) => m.as_usize().ok_or_else(|| anyhow!("bad max_new_tokens"))?,
    };
    if max_new == 0 {
        bail!("max_new_tokens must be >= 1");
    }
    let stop = v.get("stop_token").and_then(Json::as_i64).map(|x| x as i32);
    Ok(Request { prompt, max_new_tokens: max_new, stop_token: stop })
}

/// Is this line a `{"stats": true}` probe? (Checked before request
/// parsing; any JSON object carrying a truthy `stats` key qualifies.)
fn is_stats_request(line: &str) -> bool {
    Json::parse(line)
        .ok()
        .and_then(|v| v.get("stats").and_then(Json::as_bool))
        .unwrap_or(false)
}

/// Is this line a `{"trace": ...}` probe, and how many events does it
/// want? `{"trace": true}` → the whole resident ring (`Some(0)`);
/// `{"trace": N}` → the newest N (`Some(N)`, N ≥ 1); anything else →
/// `None` (not a probe).
fn trace_request_last(line: &str) -> Option<usize> {
    let v = Json::parse(line).ok()?;
    match v.get("trace")? {
        Json::Bool(true) => Some(0),
        t => match t.as_usize() {
            Some(n) if n >= 1 => Some(n),
            _ => None,
        },
    }
}

/// Encode the engine's trace-probe answer: `{"trace": {"enabled": ...,
/// "recorded": ..., "dropped": ..., "torn": ..., "events": [...]}}`.
pub fn trace_json(engine: &Engine, last: usize) -> Json {
    let enabled = engine.trace_recorder().is_some();
    let dump =
        if last == 0 { engine.trace_dump() } else { engine.trace_dump_last(last) };
    let mut body = crate::trace::dump_json(&dump);
    if let Json::Obj(m) = &mut body {
        m.insert("enabled".into(), Json::from(enabled));
    }
    obj([("trace", body)])
}

/// Encode the engine-state stats line: pool utilization, the prefix-cache
/// effectiveness summary (hit rate / blocks saved / prefill tokens skipped
/// — zeros with `"prefix_cache_enabled": false`), the swap-pool /
/// preemption summary (mode, host-store occupancy + utilization, victim
/// counts), and p50/p95/p99 percentiles of the completed requests'
/// latency, TTFT, and TPOT series (`metrics` — zeros until something
/// completes).
pub fn stats_json(engine: &Engine, metrics: &MetricsCollector) -> Json {
    let cache = engine.prefix_cache_summary();
    let c = cache.unwrap_or_default();
    let p = engine.preemption_summary();
    let swap = engine.swap_store();
    let mut fields = vec![
        ("pool_blocks_total", Json::from(engine.kv_pool().total_blocks())),
        ("pool_blocks_free", Json::from(engine.kv_pool().free_blocks())),
        ("pool_utilization", Json::from(engine.pool_utilization())),
        ("prefix_cache_enabled", Json::from(cache.is_some())),
        // "resident" (current occupancy), distinct from the
        // `prefix_cache_blocks` config knob (the budget).
        ("prefix_cache_resident_blocks", Json::from(engine.prefix_cached_blocks())),
        ("prefix_cache_lookups", Json::from(c.lookups)),
        ("prefix_cache_hits", Json::from(c.hits)),
        ("prefix_cache_hit_rate", Json::from(c.hit_rate())),
        ("prefix_cache_blocks_saved", Json::from(c.blocks_saved)),
        ("prefill_tokens_skipped", Json::from(c.prefill_tokens_skipped)),
        ("prefix_cache_evicted_blocks", Json::from(c.evicted_blocks)),
        ("prefix_cache_invalidated_blocks", Json::from(c.invalidated_blocks)),
        ("preemption_mode", Json::from(engine.config().preemption_mode.to_string())),
        // The pool's *current* per-layer layout: starts at the admission
        // layout and narrows one rung per ladder event.
        ("kv_layout", Json::from(engine.kv_pool().layout().to_string())),
        ("ladder_policy", Json::from(engine.config().ladder_policy.to_string())),
        ("ladder_events", Json::from(p.ladder_events)),
        ("ladder_preemptions", Json::from(p.ladder_preemptions)),
        ("ladder_transcoded_bytes", Json::from(p.ladder_transcoded_bytes)),
        ("ladder_freed_bytes", Json::from(p.ladder_freed_bytes)),
        ("ladder_dropped_tokens", Json::from(p.ladder_dropped_tokens)),
        ("swap_blocks_used", Json::from(swap.used_blocks())),
        ("swap_budget_blocks", Json::from(swap.budget_blocks())),
        // `null` when the budget is unbounded: there is no denominator,
        // and a fake 0.0 would hide real host pressure (the resident
        // count above is the always-meaningful signal).
        (
            "swap_utilization",
            swap.utilization().map(Json::from).unwrap_or(Json::Null),
        ),
        ("preemptions", Json::from(p.preemptions)),
        ("swap_preemptions", Json::from(p.swap_preemptions)),
        ("recompute_preemptions", Json::from(p.recompute_preemptions)),
        ("swapped_out_blocks", Json::from(p.swapped_out_blocks)),
        ("swapped_in_blocks", Json::from(p.swapped_in_blocks)),
        ("oom_aborts", Json::from(p.oom_aborts)),
        // PR-6 hot-path counters on the wire: modeled gather HBM traffic
        // and padding waste, alongside the modeled clock.
        ("gather_hbm_bytes", Json::from(engine.stats.gather_hbm_bytes)),
        ("padded_slots", Json::from(engine.stats.padded_slots)),
        ("sim_time_s", Json::from(engine.stats.sim_time_s)),
        ("telemetry", engine.telemetry().to_json()),
        ("completed_requests", Json::from(metrics.count())),
    ];
    fields.extend(crate::metrics::percentile_fields(
        crate::metrics::LATENCY_PCTL_KEYS,
        metrics.latency_percentiles(),
    ));
    fields.extend(crate::metrics::percentile_fields(
        crate::metrics::TTFT_PCTL_KEYS,
        metrics.ttft_percentiles(),
    ));
    fields.extend(crate::metrics::percentile_fields(
        crate::metrics::TPOT_PCTL_KEYS,
        metrics.tpot_percentiles(),
    ));
    obj(fields)
}

/// Encode a structured protocol-error line: `{"error": "..."}`.
pub fn encode_error(msg: &str) -> Json {
    obj([("error", Json::from(msg))])
}

/// Encode an output line. `ttft_s` is `null` when no token was ever
/// emitted (aborted requests carry `ttft = NaN` internally, and JSON has
/// no NaN — serializing it bare would corrupt the protocol line).
/// `abort_reason` is the structured detail behind `"finish": "aborted"`
/// (null otherwise); aborted lines still carry the partial generation.
pub fn encode_output(out: &RequestOutput) -> Json {
    let finish = match out.finish {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::Aborted => "aborted",
    };
    let ttft = if out.ttft.is_finite() { Json::from(out.ttft) } else { Json::Null };
    let ttft_sim =
        if out.ttft_sim.is_finite() { Json::from(out.ttft_sim) } else { Json::Null };
    let reason = match &out.abort_reason {
        Some(r) => Json::from(r.as_str()),
        None => Json::Null,
    };
    obj([
        ("id", Json::from(out.id as f64)),
        ("tokens", arr(out.tokens.iter().map(|&t| Json::from(t as i64)))),
        ("finish", Json::from(finish)),
        ("ttft_s", ttft),
        ("latency_s", Json::from(out.latency)),
        ("ttft_sim_s", ttft_sim),
        ("latency_sim_s", Json::from(out.latency_sim)),
        ("prompt_len", Json::from(out.prompt_len)),
        ("prefix_hit_tokens", Json::from(out.prefix_hit_tokens)),
        ("preempt_count", Json::from(out.preempt_count)),
        ("swapped_in_blocks", Json::from(out.swapped_in_blocks)),
        ("ladder_count", Json::from(out.ladder_count)),
        ("final_kv_layout", Json::from(out.final_kv_layout.as_str())),
        ("abort_reason", reason),
    ])
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { stream, reader })
    }

    /// Send one request and wait for its response line.
    pub fn generate(&mut self, prompt: &[i32], max_new_tokens: usize) -> Result<Json> {
        let line = obj([
            ("prompt", arr(prompt.iter().map(|&t| Json::from(t as i64)))),
            ("max_new_tokens", Json::from(max_new_tokens)),
        ]);
        self.stream.write_all(line.dump().as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Json::parse(&buf).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// Probe engine stats (`{"stats": true}` → pool + prefix-cache line).
    pub fn stats(&mut self) -> Result<Json> {
        self.stream.write_all(b"{\"stats\": true}\n")?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Json::parse(&buf).map_err(|e| anyhow!("bad stats response: {e}"))
    }

    /// Probe the flight recorder (`{"trace": N}`, `0` = the whole ring).
    pub fn trace(&mut self, last: usize) -> Result<Json> {
        let line = if last == 0 {
            "{\"trace\": true}\n".to_string()
        } else {
            format!("{{\"trace\": {last}}}\n")
        };
        self.stream.write_all(line.as_bytes())?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Json::parse(&buf).map_err(|e| anyhow!("bad trace response: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full() {
        let r = parse_request(r#"{"prompt": [1, 2, 3], "max_new_tokens": 5, "stop_token": 0}"#)
            .unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.stop_token, Some(0));
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"prompt": [7]}"#).unwrap();
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.stop_token, None);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"max_new_tokens": 5}"#).is_err());
        assert!(parse_request(r#"{"prompt": ["a"]}"#).is_err());
    }

    #[test]
    fn parse_request_rejects_empty_prompt() {
        let err = parse_request(r#"{"prompt": []}"#).unwrap_err();
        assert!(err.to_string().contains("empty prompt"), "{err}");
    }

    #[test]
    fn parse_request_rejects_zero_budget() {
        let err = parse_request(r#"{"prompt": [1], "max_new_tokens": 0}"#).unwrap_err();
        assert!(err.to_string().contains("max_new_tokens"), "{err}");
        // …and a non-integer budget is an error, not a silent default.
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": "lots"}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": 2.5}"#).is_err());
    }

    #[test]
    fn parse_request_rejects_non_integer_tokens() {
        assert!(parse_request(r#"{"prompt": [1, 2.5]}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1, null]}"#).is_err());
        assert!(parse_request(r#"{"prompt": 7}"#).is_err());
    }

    #[test]
    fn aborted_output_with_nan_ttft_is_valid_json() {
        // Submit-time aborts never emit a first token, so ttft is NaN
        // internally; the wire line must still be parseable JSON.
        let out = RequestOutput {
            id: 1,
            tokens: vec![],
            finish: FinishReason::Aborted,
            ttft: f64::NAN,
            latency: 0.0,
            ttft_sim: f64::NAN,
            latency_sim: 0.0,
            prompt_len: 9,
            prefix_hit_tokens: 0,
            preempt_count: 0,
            swapped_in_blocks: 0,
            ladder_count: 0,
            final_kv_layout: "kv8".into(),
            abort_reason: Some("request needs 40 KV blocks but the pool holds 8".into()),
        };
        let line = encode_output(&out).dump();
        let parsed = Json::parse(&line).expect("aborted line must parse");
        assert_eq!(parsed.req_str("finish").unwrap(), "aborted");
        assert_eq!(parsed.get("ttft_s"), Some(&Json::Null));
        assert!(
            parsed.req_str("abort_reason").unwrap().contains("KV blocks"),
            "aborts must carry their structured reason"
        );
    }

    #[test]
    fn aborted_output_keeps_partial_generation_on_the_wire() {
        // A mid-decode OOM abort finishes with whatever was generated —
        // the wire line must ship those tokens, not drop them.
        let out = RequestOutput {
            id: 4,
            tokens: vec![11, 22, 33],
            finish: FinishReason::Aborted,
            ttft: 0.01,
            latency: 0.4,
            ttft_sim: 0.005,
            latency_sim: 0.2,
            prompt_len: 16,
            prefix_hit_tokens: 0,
            preempt_count: 0,
            swapped_in_blocks: 0,
            ladder_count: 0,
            final_kv_layout: "kv16".into(),
            abort_reason: Some("kv pool exhausted mid-decode: KV pool exhausted".into()),
        };
        let parsed = Json::parse(&encode_output(&out).dump()).unwrap();
        assert_eq!(parsed.req_str("finish").unwrap(), "aborted");
        assert_eq!(parsed.req_arr("tokens").unwrap().len(), 3, "partial generation kept");
        assert!(parsed.req_str("abort_reason").unwrap().contains("exhausted"));
    }

    #[test]
    fn error_lines_are_structured_json() {
        let j = encode_error("bad json: trailing characters at byte 3");
        let parsed = Json::parse(&j.dump()).unwrap();
        assert!(parsed.req_str("error").unwrap().contains("bad json"));
    }

    #[test]
    fn encode_roundtrip() {
        let out = RequestOutput {
            id: 3,
            tokens: vec![9, 8],
            finish: FinishReason::Length,
            ttft: 0.25,
            latency: 1.5,
            ttft_sim: 0.125,
            latency_sim: 0.75,
            prompt_len: 4,
            prefix_hit_tokens: 32,
            preempt_count: 2,
            swapped_in_blocks: 5,
            ladder_count: 1,
            final_kv_layout: "l0:kv16,l1:kv8,l2:kv8,l3:kv4".into(),
            abort_reason: None,
        };
        let j = encode_output(&out);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.req_usize("id").unwrap(), 3);
        assert_eq!(parsed.req_str("finish").unwrap(), "length");
        assert_eq!(parsed.req_arr("tokens").unwrap().len(), 2);
        assert_eq!(parsed.req_usize("prefix_hit_tokens").unwrap(), 32);
        assert_eq!(parsed.req_usize("preempt_count").unwrap(), 2);
        assert_eq!(parsed.req_usize("swapped_in_blocks").unwrap(), 5);
        assert_eq!(parsed.req_usize("ladder_count").unwrap(), 1);
        assert_eq!(
            parsed.req_str("final_kv_layout").unwrap(),
            "l0:kv16,l1:kv8,l2:kv8,l3:kv4",
            "the final precision assignment rides every output line"
        );
        assert_eq!(parsed.get("abort_reason"), Some(&Json::Null));
        // The modeled-clock pair rides along for policy comparisons.
        assert_eq!(parsed.get("ttft_sim_s").unwrap().as_f64(), Some(0.125));
        assert_eq!(parsed.get("latency_sim_s").unwrap().as_f64(), Some(0.75));
    }

    #[test]
    fn stats_probe_detection() {
        assert!(is_stats_request(r#"{"stats": true}"#));
        assert!(!is_stats_request(r#"{"stats": false}"#));
        assert!(!is_stats_request(r#"{"prompt": [1]}"#), "generation is not a probe");
        assert!(!is_stats_request("not json"));
    }

    #[test]
    fn stats_json_round_trips_with_cache_disabled() {
        let engine =
            Engine::new(crate::config::EngineConfig::default()).expect("sim engine");
        let line = stats_json(&engine, &MetricsCollector::new()).dump();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("prefix_cache_enabled").unwrap().as_bool(), Some(false));
        assert_eq!(parsed.req_usize("pool_blocks_total").unwrap(), 512);
        assert_eq!(parsed.req_usize("pool_blocks_free").unwrap(), 512);
        assert_eq!(parsed.get("pool_utilization").unwrap().as_f64(), Some(0.0));
        assert_eq!(parsed.get("prefix_cache_hit_rate").unwrap().as_f64(), Some(0.0));
        // Swap-pool summary rides along (abort default: all zeros).
        assert_eq!(parsed.req_str("preemption_mode").unwrap(), "abort");
        assert_eq!(parsed.req_str("kv_layout").unwrap(), "kv16");
        assert_eq!(parsed.req_str("ladder_policy").unwrap(), "off");
        assert_eq!(parsed.req_usize("ladder_events").unwrap(), 0);
        assert_eq!(parsed.req_usize("ladder_freed_bytes").unwrap(), 0);
        assert_eq!(parsed.req_usize("prefix_cache_invalidated_blocks").unwrap(), 0);
        assert_eq!(parsed.req_usize("swap_blocks_used").unwrap(), 0);
        assert_eq!(parsed.req_usize("preemptions").unwrap(), 0);
        assert_eq!(
            parsed.get("swap_utilization"),
            Some(&Json::Null),
            "unbounded budget reports null, not a fake 0"
        );
        assert_eq!(parsed.req_usize("oom_aborts").unwrap(), 0);
        // Percentile fields are present and zero on an idle engine.
        assert_eq!(parsed.req_usize("completed_requests").unwrap(), 0);
        assert_eq!(parsed.get("latency_p95_s").unwrap().as_f64(), Some(0.0));
        assert_eq!(parsed.get("ttft_p50_s").unwrap().as_f64(), Some(0.0));
        assert_eq!(parsed.get("tpot_p99_s").unwrap().as_f64(), Some(0.0));
        // The PR-6 counters and telemetry block ride the wire too.
        assert_eq!(parsed.req_usize("gather_hbm_bytes").unwrap(), 0);
        assert_eq!(parsed.req_usize("padded_slots").unwrap(), 0);
        assert_eq!(parsed.get("sim_time_s").unwrap().as_f64(), Some(0.0));
        let tel = parsed.get("telemetry").unwrap();
        assert_eq!(tel.req_arr("rungs").unwrap().len(), 3);
        assert_eq!(
            tel.req_arr("occupancy_layers_by_rung").unwrap()[0].as_usize(),
            Some(Engine::new(crate::config::EngineConfig::default())
                .unwrap()
                .model()
                .n_layers),
            "default uniform kv16 layout: every layer at rung 0"
        );
    }

    #[test]
    fn stats_json_round_trips_nonzero_counters() {
        // Run real work so the satellite-1 fields carry nonzero values,
        // then demand the wire line reproduces them exactly.
        let mut cfg = crate::config::EngineConfig::default();
        cfg.max_new_tokens = 4;
        let mut engine = Engine::new(cfg).unwrap();
        for _ in 0..3 {
            engine
                .submit(crate::coordinator::Request {
                    prompt: vec![1, 2, 3, 4],
                    max_new_tokens: 4,
                    stop_token: None,
                })
                .unwrap();
        }
        engine.run_to_completion().unwrap();
        assert!(engine.stats.gather_hbm_bytes > 0);
        let parsed = Json::parse(&stats_json(&engine, &MetricsCollector::new()).dump()).unwrap();
        assert_eq!(
            parsed.req_usize("gather_hbm_bytes").unwrap(),
            engine.stats.gather_hbm_bytes
        );
        assert_eq!(parsed.req_usize("padded_slots").unwrap(), engine.stats.padded_slots);
        let tel = parsed.get("telemetry").unwrap();
        let by: Vec<usize> = tel
            .req_arr("gather_hbm_bytes_by_rung")
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(
            by.iter().sum::<usize>(),
            engine.stats.gather_hbm_bytes,
            "per-rung buckets sum exactly to the total on the wire"
        );
    }

    #[test]
    fn trace_probe_detection_and_payload() {
        assert_eq!(trace_request_last(r#"{"trace": true}"#), Some(0));
        assert_eq!(trace_request_last(r#"{"trace": 16}"#), Some(16));
        assert_eq!(trace_request_last(r#"{"trace": false}"#), None);
        assert_eq!(trace_request_last(r#"{"trace": 0}"#), None);
        assert_eq!(trace_request_last(r#"{"stats": true}"#), None);
        assert_eq!(trace_request_last("not json"), None);

        // Tracing off: the probe still answers, flagged disabled.
        let engine = Engine::new(crate::config::EngineConfig::default()).unwrap();
        let j = Json::parse(&trace_json(&engine, 0).dump()).unwrap();
        let t = j.get("trace").unwrap();
        assert_eq!(t.get("enabled").unwrap().as_bool(), Some(false));
        assert_eq!(t.req_arr("events").unwrap().len(), 0);

        // Tracing on: events flow, and `last` bounds the answer.
        let mut cfg = crate::config::EngineConfig::default();
        cfg.trace = true;
        let mut engine = Engine::new(cfg).unwrap();
        engine
            .submit(crate::coordinator::Request {
                prompt: vec![1, 2, 3],
                max_new_tokens: 2,
                stop_token: None,
            })
            .unwrap();
        engine.run_to_completion().unwrap();
        let t_all = Json::parse(&trace_json(&engine, 0).dump()).unwrap();
        let all = t_all.get("trace").unwrap().req_arr("events").unwrap().len();
        assert!(all >= 4, "admit + prefix_lookup + prefill + decode + finish, got {all}");
        let t_two = Json::parse(&trace_json(&engine, 2).dump()).unwrap();
        assert_eq!(t_two.get("trace").unwrap().req_arr("events").unwrap().len(), 2);
        assert_eq!(
            t_two.get("trace").unwrap().get("enabled").unwrap().as_bool(),
            Some(true)
        );
    }

    #[test]
    fn stats_json_reports_latency_ttft_tpot_percentiles() {
        let engine =
            Engine::new(crate::config::EngineConfig::default()).expect("sim engine");
        let mut m = MetricsCollector::new();
        m.record(1.0, 0.2, 1.0, 16, 5); // tpot (1.0−0.2)/4 = 0.2
        m.record(3.0, 0.6, 2.0, 16, 5); // tpot 0.6
        let parsed = Json::parse(&stats_json(&engine, &m).dump()).unwrap();
        assert_eq!(parsed.req_usize("completed_requests").unwrap(), 2);
        // Nearest-rank n=2: p50 = smaller sample, p95/p99 = larger.
        assert_eq!(parsed.get("latency_p50_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(parsed.get("latency_p99_s").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.get("ttft_p95_s").unwrap().as_f64(), Some(0.6));
        assert_eq!(parsed.get("tpot_p50_s").unwrap().as_f64(), Some(0.2));
        assert_eq!(parsed.get("tpot_p99_s").unwrap().as_f64(), Some(0.6));
    }
}

//! JSON-lines TCP serving front-end.
//!
//! Protocol (one JSON object per line):
//!   → `{"prompt": [1,2,3], "max_new_tokens": 16}`
//!   ← `{"id": 0, "tokens": [...], "finish": "length", "ttft_s": ..., "latency_s": ...,
//!      "prefix_hit_tokens": 0, "preempt_count": 0, "swapped_in_blocks": 0,
//!      "abort_reason": null}`
//!   → `{"stats": true}`
//!   ← `{"pool_blocks_total": ..., "pool_blocks_free": ..., "pool_utilization": ...,
//!      "prefix_cache_enabled": ..., "prefix_cache_hit_rate": ...,
//!      "preemption_mode": "swap", "swap_blocks_used": ..., "swap_utilization": ..., ...}`
//!
//! The listener thread accepts connections and forwards requests over a
//! channel to the engine thread, which loops `engine.step()`; responses
//! travel back through per-request channels. One engine thread (execution
//! backends are not thread-safe to share mutably) — concurrency comes from
//! continuous batching, exactly like production single-GPU serving. The
//! engine's backend is whatever `EngineConfig.backend` selected: the
//! hermetic sim backend by default, PJRT artifacts behind the feature.
//!
//! Protocol errors (malformed JSON, empty prompt, zero budget) produce a
//! structured `{"error": ...}` line; the connection stays open. Engine
//! rejections (oversized requests) come back as normal outputs with
//! `"finish": "aborted"`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::{Engine, FinishReason, Request, RequestOutput};
use crate::util::json::{arr, obj, Json};

/// A message forwarded from a connection to the engine thread.
enum Inbound {
    /// A generation request; the output travels back on `reply`.
    Gen { req: Request, reply: Sender<RequestOutput> },
    /// A `{"stats": true}` probe: answered immediately from engine state
    /// (pool utilization, prefix-cache hit rate), no scheduling involved.
    Stats { reply: Sender<Json> },
}

/// Serve `engine` on `addr` (e.g. `127.0.0.1:7181`).
///
/// The engine loop runs on the **calling** thread (PJRT handles are not
/// `Send`); a listener thread accepts connections and forwards requests
/// over a channel. Blocks forever unless `max_requests` is set (tests /
/// bounded runs): the loop returns after serving that many requests
/// (generation responses and `{"stats": true}` probes both count).
pub fn serve(mut engine: Engine, addr: &str, max_requests: Option<usize>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("turbomind serving on {addr}");
    let (tx, rx): (Sender<Inbound>, Receiver<Inbound>) = mpsc::channel();

    // Listener thread: accept and spawn per-connection readers.
    thread::spawn(move || {
        let mut accepted = 0usize;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            thread::spawn(move || {
                if let Err(e) = handle_conn(stream, tx) {
                    eprintln!("connection error: {e}");
                }
            });
            accepted += 1;
            if let Some(maxr) = max_requests {
                if accepted >= maxr {
                    break;
                }
            }
        }
        // tx dropped here once the accept loop ends.
    });

    // Engine loop on this thread: dispatch, admit from the channel, step.
    let mut pending: Vec<(u64, Sender<RequestOutput>)> = Vec::new();
    let mut served = 0usize;
    loop {
        // Dispatch finished outputs FIRST — `submit` can finish a request
        // immediately (pool-oversized → Aborted), so outputs may exist
        // before any step runs, and the loop must never block on the
        // channel while a client is still waiting for one.
        for out in engine.take_outputs() {
            if let Some(pos) = pending.iter().position(|(id, _)| *id == out.id) {
                let (_, reply) = pending.remove(pos);
                let _ = reply.send(out);
                served += 1;
            }
        }
        if let Some(maxr) = max_requests {
            if served >= maxr && !engine.has_work() {
                return Ok(());
            }
        }
        // Admit all queued requests without blocking; block only when the
        // engine is idle (and, per the above, nothing awaits dispatch).
        loop {
            let inbound = if engine.has_work() {
                match rx.try_recv() {
                    Ok(i) => i,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
                }
            } else {
                match rx.recv() {
                    Ok(i) => i,
                    Err(_) => return Ok(()), // listener and all conns gone
                }
            };
            let (req, reply) = match inbound {
                Inbound::Stats { reply } => {
                    let _ = reply.send(stats_json(&engine));
                    // Probes count toward `max_requests` (bounded runs stay
                    // bounded) and break to the outer loop when idle so the
                    // served-count exit check runs.
                    served += 1;
                    if !engine.has_work() {
                        break;
                    }
                    continue;
                }
                Inbound::Gen { req, reply } => (req, reply),
            };
            match engine.submit(req) {
                Ok(id) => {
                    pending.push((id, reply));
                    if !engine.has_work() {
                        // Finished at submit time: dispatch before blocking.
                        break;
                    }
                }
                Err(e) => {
                    // Report rejection as an aborted output.
                    let _ = reply.send(RequestOutput {
                        id: u64::MAX,
                        tokens: vec![],
                        finish: FinishReason::Aborted,
                        ttft: f64::NAN,
                        latency: 0.0,
                        prompt_len: 0,
                        prefix_hit_tokens: 0,
                        preempt_count: 0,
                        swapped_in_blocks: 0,
                        abort_reason: Some(e.to_string()),
                    });
                    eprintln!("rejected request: {e}");
                }
            }
        }
        engine.step()?;
    }
}

fn handle_conn(stream: TcpStream, tx: Sender<Inbound>) -> Result<()> {
    let peer = stream.peer_addr()?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = if is_stats_request(&line) {
            let (rtx, rrx) = mpsc::channel();
            tx.send(Inbound::Stats { reply: rtx }).map_err(|_| anyhow!("engine gone"))?;
            rrx.recv().map_err(|_| anyhow!("engine dropped stats probe"))?
        } else {
            match parse_request(&line) {
                Ok(req) => {
                    let (rtx, rrx) = mpsc::channel();
                    tx.send(Inbound::Gen { req, reply: rtx })
                        .map_err(|_| anyhow!("engine gone"))?;
                    let out = rrx.recv().map_err(|_| anyhow!("engine dropped request"))?;
                    encode_output(&out)
                }
                // Malformed input never drops the connection: the client
                // gets a structured error line and the stream stays usable.
                Err(e) => encode_error(&e.to_string()),
            }
        };
        writer.write_all(response.dump().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    eprintln!("connection {peer} closed");
    Ok(())
}

/// Parse a request line. Rejects malformed JSON, non-integer tokens, empty
/// prompts, and a zero `max_new_tokens` budget — all before anything
/// reaches the engine, so protocol errors never consume scheduler
/// iterations.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).map_err(|e| anyhow!("bad json: {e}"))?;
    let prompt = v
        .req_arr("prompt")
        .map_err(|e| anyhow!("{e}"))?
        .iter()
        .map(|t| t.as_i64().map(|x| x as i32).ok_or_else(|| anyhow!("bad token")))
        .collect::<Result<Vec<i32>>>()?;
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let max_new = match v.get("max_new_tokens") {
        None => 16,
        Some(m) => m.as_usize().ok_or_else(|| anyhow!("bad max_new_tokens"))?,
    };
    if max_new == 0 {
        bail!("max_new_tokens must be >= 1");
    }
    let stop = v.get("stop_token").and_then(Json::as_i64).map(|x| x as i32);
    Ok(Request { prompt, max_new_tokens: max_new, stop_token: stop })
}

/// Is this line a `{"stats": true}` probe? (Checked before request
/// parsing; any JSON object carrying a truthy `stats` key qualifies.)
fn is_stats_request(line: &str) -> bool {
    Json::parse(line)
        .ok()
        .and_then(|v| v.get("stats").and_then(Json::as_bool))
        .unwrap_or(false)
}

/// Encode the engine-state stats line: pool utilization, the prefix-cache
/// effectiveness summary (hit rate / blocks saved / prefill tokens skipped
/// — zeros with `"prefix_cache_enabled": false`), and the swap-pool /
/// preemption summary (mode, host-store occupancy + utilization, victim
/// counts).
pub fn stats_json(engine: &Engine) -> Json {
    let cache = engine.prefix_cache_summary();
    let c = cache.unwrap_or_default();
    let p = engine.preemption_summary();
    let swap = engine.swap_store();
    obj([
        ("pool_blocks_total", Json::from(engine.kv_pool().total_blocks())),
        ("pool_blocks_free", Json::from(engine.kv_pool().free_blocks())),
        ("pool_utilization", Json::from(engine.pool_utilization())),
        ("prefix_cache_enabled", Json::from(cache.is_some())),
        // "resident" (current occupancy), distinct from the
        // `prefix_cache_blocks` config knob (the budget).
        ("prefix_cache_resident_blocks", Json::from(engine.prefix_cached_blocks())),
        ("prefix_cache_lookups", Json::from(c.lookups)),
        ("prefix_cache_hits", Json::from(c.hits)),
        ("prefix_cache_hit_rate", Json::from(c.hit_rate())),
        ("prefix_cache_blocks_saved", Json::from(c.blocks_saved)),
        ("prefill_tokens_skipped", Json::from(c.prefill_tokens_skipped)),
        ("prefix_cache_evicted_blocks", Json::from(c.evicted_blocks)),
        ("preemption_mode", Json::from(engine.config().preemption_mode.to_string())),
        ("swap_blocks_used", Json::from(swap.used_blocks())),
        ("swap_budget_blocks", Json::from(swap.budget_blocks())),
        ("swap_utilization", Json::from(swap.utilization())),
        ("preemptions", Json::from(p.preemptions)),
        ("swap_preemptions", Json::from(p.swap_preemptions)),
        ("recompute_preemptions", Json::from(p.recompute_preemptions)),
        ("swapped_out_blocks", Json::from(p.swapped_out_blocks)),
        ("swapped_in_blocks", Json::from(p.swapped_in_blocks)),
        ("oom_aborts", Json::from(p.oom_aborts)),
    ])
}

/// Encode a structured protocol-error line: `{"error": "..."}`.
pub fn encode_error(msg: &str) -> Json {
    obj([("error", Json::from(msg))])
}

/// Encode an output line. `ttft_s` is `null` when no token was ever
/// emitted (aborted requests carry `ttft = NaN` internally, and JSON has
/// no NaN — serializing it bare would corrupt the protocol line).
/// `abort_reason` is the structured detail behind `"finish": "aborted"`
/// (null otherwise); aborted lines still carry the partial generation.
pub fn encode_output(out: &RequestOutput) -> Json {
    let finish = match out.finish {
        FinishReason::Length => "length",
        FinishReason::Stop => "stop",
        FinishReason::Aborted => "aborted",
    };
    let ttft = if out.ttft.is_finite() { Json::from(out.ttft) } else { Json::Null };
    let reason = match &out.abort_reason {
        Some(r) => Json::from(r.as_str()),
        None => Json::Null,
    };
    obj([
        ("id", Json::from(out.id as f64)),
        ("tokens", arr(out.tokens.iter().map(|&t| Json::from(t as i64)))),
        ("finish", Json::from(finish)),
        ("ttft_s", ttft),
        ("latency_s", Json::from(out.latency)),
        ("prompt_len", Json::from(out.prompt_len)),
        ("prefix_hit_tokens", Json::from(out.prefix_hit_tokens)),
        ("preempt_count", Json::from(out.preempt_count)),
        ("swapped_in_blocks", Json::from(out.swapped_in_blocks)),
        ("abort_reason", reason),
    ])
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { stream, reader })
    }

    /// Send one request and wait for its response line.
    pub fn generate(&mut self, prompt: &[i32], max_new_tokens: usize) -> Result<Json> {
        let line = obj([
            ("prompt", arr(prompt.iter().map(|&t| Json::from(t as i64)))),
            ("max_new_tokens", Json::from(max_new_tokens)),
        ]);
        self.stream.write_all(line.dump().as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Json::parse(&buf).map_err(|e| anyhow!("bad response: {e}"))
    }

    /// Probe engine stats (`{"stats": true}` → pool + prefix-cache line).
    pub fn stats(&mut self) -> Result<Json> {
        self.stream.write_all(b"{\"stats\": true}\n")?;
        let mut buf = String::new();
        self.reader.read_line(&mut buf)?;
        Json::parse(&buf).map_err(|e| anyhow!("bad stats response: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full() {
        let r = parse_request(r#"{"prompt": [1, 2, 3], "max_new_tokens": 5, "stop_token": 0}"#)
            .unwrap();
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
        assert_eq!(r.stop_token, Some(0));
    }

    #[test]
    fn parse_request_defaults() {
        let r = parse_request(r#"{"prompt": [7]}"#).unwrap();
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.stop_token, None);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"max_new_tokens": 5}"#).is_err());
        assert!(parse_request(r#"{"prompt": ["a"]}"#).is_err());
    }

    #[test]
    fn parse_request_rejects_empty_prompt() {
        let err = parse_request(r#"{"prompt": []}"#).unwrap_err();
        assert!(err.to_string().contains("empty prompt"), "{err}");
    }

    #[test]
    fn parse_request_rejects_zero_budget() {
        let err = parse_request(r#"{"prompt": [1], "max_new_tokens": 0}"#).unwrap_err();
        assert!(err.to_string().contains("max_new_tokens"), "{err}");
        // …and a non-integer budget is an error, not a silent default.
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": "lots"}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1], "max_new_tokens": 2.5}"#).is_err());
    }

    #[test]
    fn parse_request_rejects_non_integer_tokens() {
        assert!(parse_request(r#"{"prompt": [1, 2.5]}"#).is_err());
        assert!(parse_request(r#"{"prompt": [1, null]}"#).is_err());
        assert!(parse_request(r#"{"prompt": 7}"#).is_err());
    }

    #[test]
    fn aborted_output_with_nan_ttft_is_valid_json() {
        // Submit-time aborts never emit a first token, so ttft is NaN
        // internally; the wire line must still be parseable JSON.
        let out = RequestOutput {
            id: 1,
            tokens: vec![],
            finish: FinishReason::Aborted,
            ttft: f64::NAN,
            latency: 0.0,
            prompt_len: 9,
            prefix_hit_tokens: 0,
            preempt_count: 0,
            swapped_in_blocks: 0,
            abort_reason: Some("request needs 40 KV blocks but the pool holds 8".into()),
        };
        let line = encode_output(&out).dump();
        let parsed = Json::parse(&line).expect("aborted line must parse");
        assert_eq!(parsed.req_str("finish").unwrap(), "aborted");
        assert_eq!(parsed.get("ttft_s"), Some(&Json::Null));
        assert!(
            parsed.req_str("abort_reason").unwrap().contains("KV blocks"),
            "aborts must carry their structured reason"
        );
    }

    #[test]
    fn aborted_output_keeps_partial_generation_on_the_wire() {
        // A mid-decode OOM abort finishes with whatever was generated —
        // the wire line must ship those tokens, not drop them.
        let out = RequestOutput {
            id: 4,
            tokens: vec![11, 22, 33],
            finish: FinishReason::Aborted,
            ttft: 0.01,
            latency: 0.4,
            prompt_len: 16,
            prefix_hit_tokens: 0,
            preempt_count: 0,
            swapped_in_blocks: 0,
            abort_reason: Some("kv pool exhausted mid-decode: KV pool exhausted".into()),
        };
        let parsed = Json::parse(&encode_output(&out).dump()).unwrap();
        assert_eq!(parsed.req_str("finish").unwrap(), "aborted");
        assert_eq!(parsed.req_arr("tokens").unwrap().len(), 3, "partial generation kept");
        assert!(parsed.req_str("abort_reason").unwrap().contains("exhausted"));
    }

    #[test]
    fn error_lines_are_structured_json() {
        let j = encode_error("bad json: trailing characters at byte 3");
        let parsed = Json::parse(&j.dump()).unwrap();
        assert!(parsed.req_str("error").unwrap().contains("bad json"));
    }

    #[test]
    fn encode_roundtrip() {
        let out = RequestOutput {
            id: 3,
            tokens: vec![9, 8],
            finish: FinishReason::Length,
            ttft: 0.25,
            latency: 1.5,
            prompt_len: 4,
            prefix_hit_tokens: 32,
            preempt_count: 2,
            swapped_in_blocks: 5,
            abort_reason: None,
        };
        let j = encode_output(&out);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.req_usize("id").unwrap(), 3);
        assert_eq!(parsed.req_str("finish").unwrap(), "length");
        assert_eq!(parsed.req_arr("tokens").unwrap().len(), 2);
        assert_eq!(parsed.req_usize("prefix_hit_tokens").unwrap(), 32);
        assert_eq!(parsed.req_usize("preempt_count").unwrap(), 2);
        assert_eq!(parsed.req_usize("swapped_in_blocks").unwrap(), 5);
        assert_eq!(parsed.get("abort_reason"), Some(&Json::Null));
    }

    #[test]
    fn stats_probe_detection() {
        assert!(is_stats_request(r#"{"stats": true}"#));
        assert!(!is_stats_request(r#"{"stats": false}"#));
        assert!(!is_stats_request(r#"{"prompt": [1]}"#), "generation is not a probe");
        assert!(!is_stats_request("not json"));
    }

    #[test]
    fn stats_json_round_trips_with_cache_disabled() {
        let engine =
            Engine::new(crate::config::EngineConfig::default()).expect("sim engine");
        let line = stats_json(&engine).dump();
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("prefix_cache_enabled").unwrap().as_bool(), Some(false));
        assert_eq!(parsed.req_usize("pool_blocks_total").unwrap(), 512);
        assert_eq!(parsed.req_usize("pool_blocks_free").unwrap(), 512);
        assert_eq!(parsed.get("pool_utilization").unwrap().as_f64(), Some(0.0));
        assert_eq!(parsed.get("prefix_cache_hit_rate").unwrap().as_f64(), Some(0.0));
        // Swap-pool summary rides along (abort default: all zeros).
        assert_eq!(parsed.req_str("preemption_mode").unwrap(), "abort");
        assert_eq!(parsed.req_usize("swap_blocks_used").unwrap(), 0);
        assert_eq!(parsed.req_usize("preemptions").unwrap(), 0);
        assert_eq!(parsed.get("swap_utilization").unwrap().as_f64(), Some(0.0));
        assert_eq!(parsed.req_usize("oom_aborts").unwrap(), 0);
    }
}

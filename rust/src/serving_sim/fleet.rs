//! Abstract fleet model at the paper's real model scale: the
//! discrete-event [`ServingSim`] replicated over N heterogeneous replica
//! configs with a routing policy on top — the simulator-side mirror of
//! the engine-level [`crate::cluster`] tier.
//!
//! Each replica is an independent `ServingSim` (its own model/device/
//! framework/precision/TP config, so a w4a16/kv8 A100 can serve next to a
//! w8a8/kv16 H100); the fleet router assigns every trace request to one
//! replica, preserving arrival times, and each replica then runs its
//! sub-trace through the usual continuous-batching event loop. Replicas
//! are independent devices, so fleet makespan is the slowest replica's
//! clock and per-request latencies merge directly.
//!
//! Routing is a deliberately *abstract analogue* of the engine router
//! ([`crate::cluster::RouterPolicy`] names the policies; this is not the
//! same state machine): it works at trace granularity, so
//! `prefix_affinity` pins declared [`TraceRequest::prefix_group`] ids
//! (falling back to least-loaded for group 0 — nothing to keep resident)
//! instead of hashing token blocks, keeps groups unbounded (traces are
//! finite), and `least_loaded` tie-breaks by assigned tokens then index.
//! The engine-level `cluster::Router` is the authoritative
//! implementation; this model answers "what would the fleet shape do at
//! paper scale", not "what will the live router pick".

use crate::cluster::RouterPolicy;
use crate::metrics::MetricsCollector;
use crate::workload::TraceRequest;

use super::{ServingSim, SimConfig, SimResult};

/// A fleet of replica configs plus the routing policy.
#[derive(Debug, Clone)]
pub struct FleetSim {
    pub replicas: Vec<SimConfig>,
    pub policy: RouterPolicy,
}

/// Result of one fleet run.
#[derive(Debug)]
pub struct FleetSimResult {
    pub per_replica: Vec<SimResult>,
    /// Which replica served each trace request.
    pub assignments: Vec<usize>,
    /// Merged per-request completion series across the fleet.
    pub metrics: MetricsCollector,
}

impl FleetSimResult {
    /// Slowest replica's simulated clock (replicas run in parallel).
    pub fn makespan_s(&self) -> f64 {
        self.per_replica.iter().map(|r| r.makespan_s).fold(0.0, f64::max)
    }

    pub fn prefill_tokens_skipped(&self) -> usize {
        self.per_replica.iter().map(|r| r.prefill_tokens_skipped).sum()
    }

    pub fn aborted(&self) -> usize {
        self.per_replica.iter().map(|r| r.aborted).sum()
    }

    /// Generated tokens per fleet-second.
    pub fn token_throughput(&self) -> f64 {
        let (_, gen) = self.metrics.total_tokens();
        let t = self.makespan_s();
        if t > 0.0 {
            gen as f64 / t
        } else {
            0.0
        }
    }
}

impl FleetSim {
    pub fn new(replicas: Vec<SimConfig>, policy: RouterPolicy) -> Self {
        assert!(!replicas.is_empty(), "fleet needs at least one replica");
        Self { replicas, policy }
    }

    /// Assign each trace request to a replica. Deterministic: round robin
    /// rotates, least_loaded balances assigned `prompt + gen` tokens (the
    /// static proxy — trace assignment happens before anything runs), and
    /// prefix_affinity pins each `prefix_group` to the replica with the
    /// fewest groups at first touch (group 0 — no shared prefix — falls
    /// back to least_loaded, there is nothing to keep resident).
    pub fn assign(&self, trace: &[TraceRequest]) -> Vec<usize> {
        use crate::cluster::router::argmin_by;

        let n = self.replicas.len();
        let mut out = Vec::with_capacity(trace.len());
        let mut assigned_tokens = vec![0usize; n];
        let mut groups: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        let mut groups_per_replica = vec![0usize; n];
        let mut rr = 0usize;
        for r in trace {
            let i = match self.policy {
                RouterPolicy::RoundRobin => {
                    let i = rr % n;
                    rr += 1;
                    i
                }
                RouterPolicy::LeastLoaded => argmin_by(&assigned_tokens, |&t| t),
                RouterPolicy::PrefixAffinity => {
                    if r.prefix_group == 0 {
                        argmin_by(&assigned_tokens, |&t| t)
                    } else {
                        *groups.entry(r.prefix_group).or_insert_with(|| {
                            let i = argmin_by(&groups_per_replica, |&g| g);
                            groups_per_replica[i] += 1;
                            i
                        })
                    }
                }
            };
            assigned_tokens[i] += r.prompt_tokens + r.gen_tokens;
            out.push(i);
        }
        out
    }

    /// Route and run the whole trace.
    pub fn run(&self, trace: &[TraceRequest]) -> FleetSimResult {
        let assignments = self.assign(trace);
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        let mut metrics = MetricsCollector::new();
        for (i, cfg) in self.replicas.iter().enumerate() {
            let sub: Vec<TraceRequest> = trace
                .iter()
                .zip(&assignments)
                .filter(|(_, &a)| a == i)
                .map(|(r, _)| *r)
                .collect();
            // An idle replica (empty sub-trace) contributes an empty
            // result without panicking.
            let res = ServingSim::new(cfg.clone()).run(&sub);
            metrics.merge(&res.metrics);
            per_replica.push(res);
        }
        FleetSimResult { per_replica, assignments, metrics }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::find_model;
    use crate::config::DeviceProfile;
    use crate::gpusim::Framework;
    use crate::serving_sim::SimPrecision;
    use crate::workload::MultiTenantGen;

    fn replica(dev: DeviceProfile, prec: SimPrecision, prefix_cache: bool) -> SimConfig {
        let mut cfg =
            SimConfig::new(find_model("qwen3-8b").unwrap(), dev, Framework::TurboMind, prec);
        cfg.max_batch = 16;
        cfg.prefix_cache = prefix_cache;
        cfg
    }

    fn tenant_trace() -> Vec<TraceRequest> {
        MultiTenantGen {
            tenants: 4,
            users: 4,
            turns: 3,
            shared_tokens: 2048,
            turn_tokens: 64,
            gen_tokens: 32,
            rate: 6.0,
            seed: 17,
        }
        .generate()
    }

    #[test]
    fn fleet_completes_everything_and_merges_metrics() {
        let fleet = FleetSim::new(
            vec![
                replica(DeviceProfile::a100(), SimPrecision::w4a16kv8(), true),
                replica(DeviceProfile::h100(), SimPrecision::w4a16kv8(), true),
            ],
            RouterPolicy::RoundRobin,
        );
        let trace = tenant_trace();
        let r = fleet.run(&trace);
        assert_eq!(r.metrics.count(), trace.len(), "no request lost");
        assert_eq!(r.assignments.len(), trace.len());
        assert_eq!(r.aborted(), 0);
        assert!(r.makespan_s() > 0.0);
        // Round robin splits evenly.
        assert_eq!(r.assignments.iter().filter(|&&a| a == 0).count(), trace.len() / 2);
    }

    #[test]
    fn affinity_pins_groups_and_beats_round_robin_on_ttft() {
        // The tentpole claim at simulator scale: keeping each tenant's
        // shared 2k-token prefix on one replica skips more prefill than
        // spraying it, and the saved work shows up in fleet p95 TTFT.
        let mk = |policy| {
            FleetSim::new(
                vec![
                    replica(DeviceProfile::a100(), SimPrecision::w4a16kv8(), true),
                    replica(DeviceProfile::a100(), SimPrecision::w4a16kv8(), true),
                ],
                policy,
            )
        };
        let trace = tenant_trace();
        let aff = mk(RouterPolicy::PrefixAffinity).run(&trace);
        let rr = mk(RouterPolicy::RoundRobin).run(&trace);
        assert_eq!(aff.metrics.count(), trace.len());
        // Every group's requests land on one replica.
        for (i, r) in trace.iter().enumerate() {
            let first = trace.iter().position(|x| x.prefix_group == r.prefix_group).unwrap();
            assert_eq!(aff.assignments[i], aff.assignments[first], "group split");
        }
        assert!(
            aff.prefill_tokens_skipped() > rr.prefill_tokens_skipped(),
            "affinity {} !> rr {}",
            aff.prefill_tokens_skipped(),
            rr.prefill_tokens_skipped()
        );
        let (t_aff, t_rr) = (
            aff.metrics.ttft_percentiles().unwrap().p95,
            rr.metrics.ttft_percentiles().unwrap().p95,
        );
        assert!(t_aff <= t_rr, "affinity p95 TTFT {t_aff} !<= rr {t_rr}");
    }

    #[test]
    fn heterogeneous_replicas_diverge_in_speed_not_completeness() {
        let w8a8kv16 = SimPrecision { w_bits: 8, a_bits: 8, kv_bits: 16 };
        let fleet = FleetSim::new(
            vec![
                replica(DeviceProfile::a100(), SimPrecision::w4a16kv8(), false),
                replica(DeviceProfile::h100(), w8a8kv16, false),
            ],
            RouterPolicy::LeastLoaded,
        );
        let trace = tenant_trace();
        let r = fleet.run(&trace);
        assert_eq!(r.metrics.count(), trace.len());
        assert!(r.per_replica[0].metrics.count() > 0);
        assert!(r.per_replica[1].metrics.count() > 0);
        assert!(r.token_throughput() > 0.0);
    }
}

//! Discrete-event serving simulator: continuous batching over the `gpusim`
//! kernel models at the paper's real model scale.
//!
//! Regenerates the end-to-end comparisons (Figs 14-21, 27): requests arrive
//! by Poisson process, the simulated engine interleaves chunked prefill and
//! decode iterations (prefill-priority continuous batching), iteration
//! latency comes from the per-layer GEMM + attention kernel models plus the
//! framework's CPU overhead and (optionally) tensor-parallel all-reduces,
//! and per-request latency/TTFT/throughput fall out of the event clock.
//!
//! Batch capacity is derived from device memory: weights at the serving
//! precision plus KV at the serving KV precision must fit the TP group.

pub mod fleet;

pub use fleet::{FleetSim, FleetSimResult};

use std::collections::HashMap;

use crate::config::{DeviceProfile, ModelConfig};
use crate::gpusim::{
    AttentionKernelModel, AttnWorkload, Framework, GemmKernelModel, GemmWorkload, KernelTraits,
};
use crate::metrics::MetricsCollector;
use crate::parallel::TpPlan;
use crate::workload::TraceRequest;

/// Serving precision configuration for the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimPrecision {
    pub w_bits: usize,
    pub a_bits: usize,
    pub kv_bits: usize,
}

impl SimPrecision {
    pub fn w4a16kv16() -> Self {
        Self { w_bits: 4, a_bits: 16, kv_bits: 16 }
    }
    pub fn w4a16kv8() -> Self {
        Self { w_bits: 4, a_bits: 16, kv_bits: 8 }
    }
    pub fn w4a16kv4() -> Self {
        Self { w_bits: 4, a_bits: 16, kv_bits: 4 }
    }
    pub fn w4a8kv4() -> Self {
        Self { w_bits: 4, a_bits: 8, kv_bits: 4 }
    }
    pub fn w16a16kv16() -> Self {
        Self { w_bits: 16, a_bits: 16, kv_bits: 16 }
    }
    pub fn label(&self) -> String {
        format!("W{}A{}KV{}", self.w_bits, self.a_bits, self.kv_bits)
    }
}

/// Abstract analogue of the engine's `PreemptionMode` (DESIGN.md §8):
/// what happens when decode growth exceeds [`SimConfig::kv_budget_tokens`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimPreemption {
    /// Drop the youngest running sequence (it counts as aborted).
    #[default]
    Abort,
    /// Swap the youngest out, paying `kv_bytes / swap_bw` each way, and
    /// swap it back in when the budget clears.
    Swap,
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: ModelConfig,
    pub dev: DeviceProfile,
    pub fw: Framework,
    pub precision: SimPrecision,
    pub tp: usize,
    /// Cap on concurrent decode sequences (0 = derive from memory only).
    pub max_batch: usize,
    /// Prefill chunk length (tokens per prefill iteration).
    pub chunk: usize,
    /// Model the prefix-sharing KV cache: a request whose
    /// [`TraceRequest::prefix_group`] prefix is already resident skips
    /// that much prefill (abstract analogue of the engine's radix index).
    pub prefix_cache: bool,
    /// Abstract KV-pressure model: max resident decode KV tokens before
    /// preemption kicks in (0 = unbounded, the default — capacity then
    /// comes only from the memory-derived batch bound).
    pub kv_budget_tokens: usize,
    /// Reaction to exceeding the budget (see [`SimPreemption`]).
    pub preemption: SimPreemption,
    /// Host↔device bandwidth for swapped KV, bytes/s.
    pub swap_bw: f64,
}

impl SimConfig {
    pub fn new(model: ModelConfig, dev: DeviceProfile, fw: Framework, precision: SimPrecision) -> Self {
        Self {
            model,
            dev,
            fw,
            precision,
            tp: 1,
            max_batch: 0,
            chunk: 512,
            prefix_cache: false,
            kv_budget_tokens: 0,
            preemption: SimPreemption::Abort,
            swap_bw: 16.0e9,
        }
    }
}

/// Result of one simulated serving run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub metrics: MetricsCollector,
    /// Wall-clock (simulated) end time of the run.
    pub makespan_s: f64,
    /// Derived decode batch capacity.
    pub batch_capacity: usize,
    pub decode_iters: usize,
    pub prefill_iters: usize,
    /// Prompt tokens skipped via prefix caching (0 when disabled).
    pub prefill_tokens_skipped: usize,
    /// Requests dropped by `SimPreemption::Abort` under KV pressure.
    pub aborted: usize,
    /// Swap-out events under `SimPreemption::Swap`.
    pub swap_outs: usize,
    /// Modeled host-link time spent on swap traffic, seconds.
    pub swap_time_s: f64,
}

impl SimResult {
    pub fn token_throughput(&self) -> f64 {
        self.metrics.token_throughput()
    }
    pub fn request_throughput(&self) -> f64 {
        self.metrics.request_throughput()
    }
}

struct LiveSeq {
    idx: usize,
    kv_len: usize,
    remaining_gen: usize,
    first_token_at: Option<f64>,
}

struct PendingSeq {
    idx: usize,
    prefilled: usize,
}

/// The simulator.
pub struct ServingSim {
    cfg: SimConfig,
    traits: KernelTraits,
    tp: TpPlan,
}

impl ServingSim {
    pub fn new(cfg: SimConfig) -> Self {
        let traits = cfg.fw.traits_on(&cfg.dev);
        let tp = if cfg.tp <= 1 { TpPlan::single() } else { TpPlan::on(&cfg.dev, cfg.tp) };
        Self { cfg, traits, tp }
    }

    pub fn traits(&self) -> &KernelTraits {
        &self.traits
    }

    /// Does the framework support this precision at all? (QServe is
    /// hard-wired to W4A8KV4; vLLM's quantized KV tops out at 8-bit…)
    pub fn supported(&self) -> bool {
        let p = &self.cfg.precision;
        let t = &self.traits;
        let w_ok = match (p.w_bits, p.a_bits) {
            (16, 16) => true,
            (4, 16) => t.supports_w4a16,
            (4, 8) => t.supports_w4a8,
            (8, 8) => true, // w8a8 smoothquant-style path, universally available
            _ => false,
        };
        w_ok && (p.kv_bits == 16 || t.supports_kv(p.kv_bits))
    }

    /// Decode-batch capacity from device memory and the configured cap.
    pub fn batch_capacity(&self, mean_seq_len: usize) -> usize {
        let m = &self.cfg.model;
        let weights = m.weight_bytes(self.cfg.precision.w_bits) as f64;
        let total = self.tp.total_memory(&self.cfg.dev) * 0.90;
        let kv_budget = (total - weights).max(0.0);
        let per_seq = (m.kv_bytes_per_token(self.cfg.precision.kv_bits) * mean_seq_len) as f64;
        let cap = if per_seq > 0.0 { (kv_budget / per_seq) as usize } else { 0 };
        let cap = cap.clamp(1, 512);
        if self.cfg.max_batch > 0 {
            cap.min(self.cfg.max_batch)
        } else {
            cap
        }
    }

    /// Latency of one decode iteration over `batch` sequences with mean
    /// context `kv_len`.
    pub fn decode_iter_time(&self, batch: usize, kv_len: usize) -> f64 {
        self.iter_time(batch, 1, kv_len)
    }

    /// Latency of one prefill iteration for one sequence: `chunk` new
    /// tokens on top of `past` context.
    pub fn prefill_iter_time(&self, chunk: usize, past: usize) -> f64 {
        self.iter_time(1, chunk, past)
    }

    /// Bytes of KV a `kv_len`-token sequence ships per swap direction —
    /// scales with the serving KV precision, so kv4 swaps ~4× cheaper
    /// than kv16 (the engine-side cost model's byte accounting).
    fn swap_bytes(&self, kv_len: usize) -> f64 {
        (self.cfg.model.kv_bytes_per_token(self.cfg.precision.kv_bits) * kv_len) as f64
    }

    /// Core per-iteration model: `batch` sequences × `q_tokens` each.
    fn iter_time(&self, batch: usize, q_tokens: usize, kv_len: usize) -> f64 {
        let m = &self.cfg.model;
        let p = &self.cfg.precision;
        let dev = &self.cfg.dev;
        let gemm = GemmKernelModel::new(dev, &self.traits);
        let attn = AttentionKernelModel::new(dev, &self.traits);
        let shard = self.tp.shard();
        let tokens = batch * q_tokens;

        let mut t = 0.0;
        for (name, k_in, n_out) in m.layer_gemms() {
            // MoE FFN GEMMs: weight traffic covers the distinct experts
            // activated by the token batch; each expert sees its slice.
            let is_ffn = name.starts_with("w_");
            let (eff_m, n_kernels) = if m.is_moe() && is_ffn {
                let distinct =
                    (tokens * m.experts_per_token).min(m.n_experts).max(1);
                ((tokens * m.experts_per_token).div_ceil(distinct), distinct)
            } else {
                (tokens, 1)
            };
            let w = GemmWorkload {
                m: eff_m,
                k: k_in,
                n: ((n_out as f64 * shard) as usize).max(1),
                w_bits: p.w_bits,
                a_bits: p.a_bits,
                group_size: 128,
            };
            t += gemm.run(&w).time_s * n_kernels as f64;
        }
        // lm_head (always f16, not quantized) once per iteration.
        let lm = GemmWorkload {
            m: tokens,
            k: m.d_model,
            n: ((m.vocab_size as f64 * shard) as usize).max(1),
            w_bits: 16,
            a_bits: 16,
            group_size: 128,
        };
        t += gemm.run(&lm).time_s / m.n_layers as f64; // amortized: one head vs L layers

        // Attention per layer (heads sharded by TP).
        let heads = ((m.n_heads as f64 * shard) as usize).max(1);
        let kv_heads = ((m.n_kv_heads as f64 * shard) as usize).max(1);
        let aw = AttnWorkload {
            batch,
            q_tokens,
            kv_len: kv_len + q_tokens,
            n_heads: heads,
            n_kv_heads: kv_heads,
            head_dim: m.head_dim,
            kv_bits: p.kv_bits,
        };
        t += attn.run(&aw).time_s;

        // The per-layer loop: everything above was one layer's GEMMs; the
        // attention call covers one layer too.
        let mut total = t * m.n_layers as f64;

        // TP all-reduces (two per layer) + scheduler overhead.
        total += self.tp.layer_allreduce_time(tokens, m.d_model) * m.n_layers as f64;
        total += self.traits.cpu_overhead_s;
        total
    }

    /// Run a trace to completion. Prefill-priority continuous batching.
    pub fn run(&self, trace: &[TraceRequest]) -> SimResult {
        let mean_len = (trace
            .iter()
            .map(|r| r.prompt_tokens + r.gen_tokens)
            .sum::<usize>()
            / trace.len().max(1))
        .max(1);
        let capacity = self.batch_capacity(mean_len);

        let mut clock = 0.0f64;
        let mut next_arrival = 0usize;
        let mut queue: Vec<PendingSeq> = Vec::new();
        let mut running: Vec<LiveSeq> = Vec::new();
        // Sequences parked host-side by the abstract swap model.
        let mut swapped: Vec<LiveSeq> = Vec::new();
        let mut metrics = MetricsCollector::new();
        let mut decode_iters = 0usize;
        let mut prefill_iters = 0usize;
        // Abstract prefix cache: group id → longest resident shared prefix.
        let mut cached: HashMap<u64, usize> = HashMap::new();
        let mut prefill_tokens_skipped = 0usize;
        let mut aborted = 0usize;
        let mut swap_outs = 0usize;
        let mut swap_time_s = 0.0f64;
        let budget = self.cfg.kv_budget_tokens;

        let done = |q: &Vec<PendingSeq>, r: &Vec<LiveSeq>, sw: &Vec<LiveSeq>, next: usize| {
            q.is_empty() && r.is_empty() && sw.is_empty() && next >= trace.len()
        };

        while !done(&queue, &running, &swapped, next_arrival) {
            // Admit arrivals up to the clock; a request whose group prefix
            // is already resident skips it (leaving ≥ 1 token to prefill,
            // like the engine's match cap).
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= clock {
                let r = &trace[next_arrival];
                let mut pre = 0usize;
                if self.cfg.prefix_cache && r.prefix_group != 0 {
                    pre = cached
                        .get(&r.prefix_group)
                        .copied()
                        .unwrap_or(0)
                        .min(r.prefix_tokens)
                        .min(r.prompt_tokens.saturating_sub(1));
                    prefill_tokens_skipped += pre;
                }
                queue.push(PendingSeq { idx: next_arrival, prefilled: pre });
                next_arrival += 1;
            }
            // Swap-ins take priority over fresh admissions: a parked
            // sequence resumes (paying the transfer) as soon as the budget
            // allows — or unconditionally when the batch ran empty, so a
            // sole outsized sequence can never strand the run.
            if !swapped.is_empty() && running.len() < capacity {
                let kv_now: usize = running.iter().map(|s| s.kv_len).sum();
                let cand = swapped.last().expect("non-empty").kv_len;
                if running.is_empty()
                    || budget == 0
                    || kv_now + cand + running.len() + 1 <= budget
                {
                    let s = swapped.pop().expect("non-empty");
                    let dt = self.swap_bytes(s.kv_len) / self.cfg.swap_bw;
                    clock += dt;
                    swap_time_s += dt;
                    running.push(s);
                    continue;
                }
            }
            // Nothing runnable: jump to next arrival.
            if queue.is_empty() && running.is_empty() && swapped.is_empty() {
                clock = trace[next_arrival].arrival_s;
                continue;
            }

            let admissible = !queue.is_empty() && running.len() < capacity;
            if admissible {
                // One prefill chunk for the head-of-queue request.
                let head = &mut queue[0];
                let req = &trace[head.idx];
                let remaining = req.prompt_tokens - head.prefilled;
                let chunk = remaining.min(self.cfg.chunk);
                clock += self.prefill_iter_time(chunk, head.prefilled);
                prefill_iters += 1;
                head.prefilled += chunk;
                if head.prefilled >= req.prompt_tokens {
                    // Prompt done → first token emitted this iteration; its
                    // shared prefix is now resident for later arrivals.
                    let idx = head.idx;
                    queue.remove(0);
                    if self.cfg.prefix_cache && trace[idx].prefix_group != 0 {
                        let e = cached.entry(trace[idx].prefix_group).or_insert(0);
                        *e = (*e).max(trace[idx].prefix_tokens);
                    }
                    running.push(LiveSeq {
                        idx,
                        kv_len: req.prompt_tokens,
                        remaining_gen: req.gen_tokens.saturating_sub(1),
                        first_token_at: Some(clock),
                    });
                    let r = &trace[idx];
                    if req.gen_tokens <= 1 {
                        let s = running.pop().unwrap();
                        metrics.record(
                            clock - r.arrival_s,
                            s.first_token_at.unwrap() - r.arrival_s,
                            clock,
                            r.prompt_tokens,
                            r.gen_tokens,
                        );
                    }
                }
            } else if !running.is_empty() {
                // KV pressure: this iteration grows every sequence by one
                // token; preempt youngest-first until that fits the
                // abstract budget (a sole survivor always proceeds — the
                // engine's sole-runner rule).
                if budget > 0 {
                    while running.len() > 1
                        && running.iter().map(|s| s.kv_len).sum::<usize>() + running.len()
                            > budget
                    {
                        let victim = running.pop().expect("len > 1");
                        match self.cfg.preemption {
                            SimPreemption::Abort => aborted += 1,
                            SimPreemption::Swap => {
                                let dt =
                                    self.swap_bytes(victim.kv_len) / self.cfg.swap_bw;
                                clock += dt;
                                swap_time_s += dt;
                                swap_outs += 1;
                                swapped.push(victim);
                            }
                        }
                    }
                }
                // One decode iteration over the whole batch.
                let batch = running.len();
                let mean_kv =
                    running.iter().map(|s| s.kv_len).sum::<usize>() / batch;
                clock += self.decode_iter_time(batch, mean_kv);
                decode_iters += 1;
                let mut finished = Vec::new();
                for (i, s) in running.iter_mut().enumerate() {
                    s.kv_len += 1;
                    s.remaining_gen -= 1;
                    if s.remaining_gen == 0 {
                        finished.push(i);
                    }
                }
                for i in finished.into_iter().rev() {
                    let s = running.remove(i);
                    let r = &trace[s.idx];
                    metrics.record(
                        clock - r.arrival_s,
                        s.first_token_at.unwrap() - r.arrival_s,
                        clock,
                        r.prompt_tokens,
                        r.gen_tokens,
                    );
                }
            } else {
                // Queue non-empty but batch full of prefills? Can't happen:
                // prefill always admissible when queue non-empty and
                // capacity>0; guard against capacity=0 pathologies.
                clock += self.traits.cpu_overhead_s.max(1e-6);
            }
        }

        SimResult {
            metrics,
            makespan_s: clock,
            batch_capacity: capacity,
            decode_iters,
            prefill_iters,
            prefill_tokens_skipped,
            aborted,
            swap_outs,
            swap_time_s,
        }
    }

    /// Offline maximum throughput (Fig 20 / Fig 14 row 1): all requests
    /// available at t=0, report generated tokens/s.
    pub fn max_throughput(&self, n_requests: usize, prompt: usize, gen: usize) -> SimResult {
        let trace: Vec<TraceRequest> = (0..n_requests)
            .map(|_| TraceRequest {
                arrival_s: 0.0,
                prompt_tokens: prompt,
                gen_tokens: gen,
                prefix_group: 0,
                prefix_tokens: 0,
            })
            .collect();
        self.run(&trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model::find_model;
    use crate::workload::{WorkloadGen, WorkloadKind};

    fn sim(fw: Framework, prec: SimPrecision, max_batch: usize) -> ServingSim {
        let mut cfg = SimConfig::new(
            find_model("qwen3-8b").unwrap(),
            DeviceProfile::a100(),
            fw,
            prec,
        );
        cfg.max_batch = max_batch;
        ServingSim::new(cfg)
    }

    fn chat_trace(rate: f64, n: usize) -> Vec<TraceRequest> {
        WorkloadGen::new(WorkloadKind::Chat, rate, 42).generate(n)
    }

    #[test]
    fn sim_precision_labels_match_paper_notation() {
        assert_eq!(SimPrecision::w4a16kv16().label(), "W4A16KV16");
        assert_eq!(SimPrecision::w4a16kv8().label(), "W4A16KV8");
        assert_eq!(SimPrecision::w4a16kv4().label(), "W4A16KV4");
        assert_eq!(SimPrecision::w4a8kv4().label(), "W4A8KV4");
        assert_eq!(SimPrecision::w16a16kv16().label(), "W16A16KV16");
        // Labels round-trip through the engine's PrecisionFormat notation.
        for p in [
            SimPrecision::w4a16kv16(),
            SimPrecision::w4a16kv8(),
            SimPrecision::w4a16kv4(),
            SimPrecision::w4a8kv4(),
            SimPrecision::w16a16kv16(),
        ] {
            let parsed: crate::config::PrecisionFormat = p.label().parse().unwrap();
            assert_eq!(parsed.to_string(), p.label());
        }
    }

    #[test]
    fn completes_all_requests() {
        let s = sim(Framework::TurboMind, SimPrecision::w4a16kv8(), 32);
        let trace = chat_trace(4.0, 200);
        let r = s.run(&trace);
        assert_eq!(r.metrics.count(), 200);
        assert!(r.makespan_s > 0.0);
        assert!(r.decode_iters > 0 && r.prefill_iters >= 200);
    }

    #[test]
    fn turbomind_beats_baselines_on_chat() {
        // The headline direction: TurboMind ≥ every baseline on the same
        // W4A16KV8 workload (Fig 14 / Fig 20 shape).
        let trace = chat_trace(8.0, 150);
        let t_tm = sim(Framework::TurboMind, SimPrecision::w4a16kv8(), 32)
            .run(&trace)
            .metrics
            .latency_percentiles()
            .unwrap();
        for fw in [Framework::VllmMarlin, Framework::TensorRtLlm] {
            let t_fw = sim(fw, SimPrecision::w4a16kv8(), 32)
                .run(&trace)
                .metrics
                .latency_percentiles()
                .unwrap();
            assert!(
                t_tm.p90 < t_fw.p90,
                "{fw:?}: tm p90 {} vs {}",
                t_tm.p90,
                t_fw.p90
            );
        }
    }

    #[test]
    fn higher_rate_increases_latency() {
        let s = sim(Framework::TurboMind, SimPrecision::w4a16kv8(), 16);
        let lo = s.run(&chat_trace(1.0, 100)).metrics.latency_percentiles().unwrap();
        let hi = s.run(&chat_trace(20.0, 100)).metrics.latency_percentiles().unwrap();
        assert!(hi.p90 > lo.p90, "hi {} lo {}", hi.p90, lo.p90);
    }

    #[test]
    fn kv_quant_increases_capacity_and_throughput() {
        // Fig 21 mechanism: lower KV bits → bigger feasible batch → more
        // tokens/s at saturation.
        let t16 = sim(Framework::TurboMind, SimPrecision::w4a16kv16(), 512)
            .max_throughput(256, 512, 256);
        let t8 = sim(Framework::TurboMind, SimPrecision::w4a16kv8(), 512)
            .max_throughput(256, 512, 256);
        let t4 = sim(Framework::TurboMind, SimPrecision::w4a16kv4(), 512)
            .max_throughput(256, 512, 256);
        assert!(t8.batch_capacity >= t16.batch_capacity);
        assert!(t8.token_throughput() > t16.token_throughput());
        assert!(t4.token_throughput() > t8.token_throughput() * 0.99);
    }

    #[test]
    fn w16_parity_with_vllm_without_quant() {
        // Fig 27: in W16A16KV16 the two systems are within a few percent —
        // the gains are mixed-precision-specific, not framework bias.
        let trace = chat_trace(4.0, 100);
        let tm = sim(Framework::TurboMind, SimPrecision::w16a16kv16(), 16).run(&trace);
        let vm = sim(Framework::VllmMarlin, SimPrecision::w16a16kv16(), 16).run(&trace);
        let ratio = vm.metrics.latency_percentiles().unwrap().p50
            / tm.metrics.latency_percentiles().unwrap().p50;
        assert!(
            (0.95..1.25).contains(&ratio),
            "w16 parity ratio {ratio} (should be near 1)"
        );
    }

    #[test]
    fn qserve_unsupported_formats_detected() {
        assert!(!sim(Framework::QServe, SimPrecision::w4a16kv8(), 8).supported());
        assert!(sim(Framework::QServe, SimPrecision::w4a8kv4(), 8).supported());
        assert!(sim(Framework::TurboMind, SimPrecision::w4a16kv4(), 8).supported());
        assert!(!sim(Framework::VllmMarlin, SimPrecision::w4a16kv4(), 8).supported());
    }

    #[test]
    fn prefix_cache_cuts_ttft_on_shared_prefix_workload() {
        use crate::workload::SharedPrefixGen;
        let trace = SharedPrefixGen {
            shared_tokens: 2048,
            users: 8,
            turns: 3,
            turn_tokens: 64,
            gen_tokens: 32,
            rate: 4.0,
            seed: 9,
        }
        .generate();
        let mut cfg = SimConfig::new(
            find_model("qwen3-8b").unwrap(),
            DeviceProfile::a100(),
            Framework::TurboMind,
            SimPrecision::w4a16kv8(),
        );
        cfg.max_batch = 16;
        let off = ServingSim::new(cfg.clone()).run(&trace);
        assert_eq!(off.prefill_tokens_skipped, 0, "cache off skips nothing");
        cfg.prefix_cache = true;
        let on = ServingSim::new(cfg).run(&trace);
        assert_eq!(on.metrics.count(), trace.len());
        assert!(on.prefill_tokens_skipped > 0, "warm cache must skip prefill");
        let (t_on, t_off) = (
            on.metrics.ttft_percentiles().unwrap().p50,
            off.metrics.ttft_percentiles().unwrap().p50,
        );
        assert!(t_on < t_off, "cached TTFT {t_on} vs uncached {t_off}");
        assert!(on.makespan_s < off.makespan_s, "less prefill → earlier finish");
    }

    #[test]
    fn kv_pressure_swap_completes_what_abort_drops() {
        // The abstract §8 model: a KV-token budget far below the trace's
        // working set. Abort mode sheds load; swap mode completes every
        // request at the price of transfer time and a longer makespan.
        let trace = chat_trace(20.0, 60);
        let mut cfg = SimConfig::new(
            find_model("qwen3-8b").unwrap(),
            DeviceProfile::a100(),
            Framework::TurboMind,
            SimPrecision::w4a16kv8(),
        );
        cfg.max_batch = 16;
        let unbounded = ServingSim::new(cfg.clone()).run(&trace);
        assert_eq!(unbounded.aborted, 0);
        assert_eq!(unbounded.swap_outs, 0, "no budget, no preemption");

        cfg.kv_budget_tokens = 2048;
        cfg.preemption = SimPreemption::Abort;
        let ab = ServingSim::new(cfg.clone()).run(&trace);
        assert!(ab.aborted > 0, "pressure must shed load in abort mode");
        assert_eq!(ab.metrics.count() + ab.aborted, trace.len());

        cfg.preemption = SimPreemption::Swap;
        let sw = ServingSim::new(cfg).run(&trace);
        assert_eq!(sw.aborted, 0, "swap mode loses nothing");
        assert_eq!(sw.metrics.count(), trace.len());
        assert!(sw.swap_outs > 0, "the budget must actually bind");
        assert!(sw.swap_time_s > 0.0);
        assert!(
            sw.makespan_s > unbounded.makespan_s,
            "preservation costs time: {} !> {}",
            sw.makespan_s,
            unbounded.makespan_s
        );
        // Goodput (completed tokens/s) beats shedding the same pressure.
        let goodput = |r: &SimResult| {
            let (_, gen) = r.metrics.total_tokens();
            gen as f64 / r.makespan_s
        };
        assert!(goodput(&sw) > goodput(&ab), "{} !> {}", goodput(&sw), goodput(&ab));
    }

    #[test]
    fn swap_traffic_is_cheaper_at_lower_kv_precision() {
        // The precision-aware claim at simulator scale: identical trace
        // and budget, kv4 pays less modeled link time than kv16.
        let trace = chat_trace(20.0, 60);
        let time_at = |prec: SimPrecision| {
            let mut cfg = SimConfig::new(
                find_model("qwen3-8b").unwrap(),
                DeviceProfile::a100(),
                Framework::TurboMind,
                prec,
            );
            cfg.max_batch = 16;
            cfg.kv_budget_tokens = 2048;
            cfg.preemption = SimPreemption::Swap;
            let r = ServingSim::new(cfg).run(&trace);
            assert_eq!(r.metrics.count(), trace.len());
            (r.swap_outs, r.swap_time_s)
        };
        let (o16, t16) = time_at(SimPrecision::w4a16kv16());
        let (o4, t4) = time_at(SimPrecision::w4a16kv4());
        assert!(o16 > 0 && o4 > 0);
        // Per-swap-out link time must drop ~4× with the byte width.
        assert!(
            t4 / o4 as f64 * 3.0 < t16 / o16 as f64,
            "kv4 {:.2e}/swap vs kv16 {:.2e}/swap",
            t4 / o4 as f64,
            t16 / o16 as f64
        );
    }

    #[test]
    fn moe_models_run() {
        let mut cfg = SimConfig::new(
            find_model("mixtral-8x7b").unwrap(),
            DeviceProfile::a100(),
            Framework::TurboMind,
            SimPrecision::w4a16kv8(),
        );
        cfg.tp = 2;
        cfg.max_batch = 16;
        let s = ServingSim::new(cfg);
        let r = s.run(&chat_trace(2.0, 50));
        assert_eq!(r.metrics.count(), 50);
    }

    #[test]
    fn tp_scaling_is_sublinear_but_positive() {
        // Appendix I: 8 GPUs give 4.45-5.18× over 1 GPU (55-65% efficiency).
        let model = find_model("qwen3-32b").unwrap();
        let thr = |tp: usize| {
            let mut cfg = SimConfig::new(
                model.clone(),
                DeviceProfile::a100(),
                Framework::TurboMind,
                SimPrecision::w4a16kv8(),
            );
            cfg.tp = tp;
            cfg.max_batch = 64;
            ServingSim::new(cfg).max_throughput(128, 512, 256).request_throughput()
        };
        let t1 = thr(1);
        let t8 = thr(8);
        let speedup = t8 / t1;
        assert!(speedup > 2.0, "8-way TP speedup {speedup}");
        assert!(speedup < 8.0, "must be sublinear: {speedup}");
    }
}

//! Tensor parallelism: Megatron-style sharding plan + collective cost model
//! (§5.1 "we utilize tensor parallelism to accommodate the large model
//! size"; Appendix I scalability).
//!
//! Per transformer layer, TP splits the QKV/O and FFN GEMMs column/row-wise
//! across `degree` GPUs and issues two all-reduces on the activations (one
//! after attention output, one after the FFN down-projection). All-reduce
//! cost follows the ring model: `2·(p-1)/p · bytes` crossing the
//! interconnect per GPU pair direction.

use crate::config::DeviceProfile;

/// A tensor-parallel execution plan.
#[derive(Debug, Clone, Copy)]
pub struct TpPlan {
    pub degree: usize,
    /// Per-direction interconnect bandwidth, bytes/s (from the device
    /// profile: NVLink on A100/H100, PCIe on workstation parts).
    pub interconnect_bw: f64,
    /// Per-collective launch latency, seconds (NCCL kernel + sync).
    pub collective_latency_s: f64,
}

impl TpPlan {
    pub fn single() -> Self {
        Self { degree: 1, interconnect_bw: f64::INFINITY, collective_latency_s: 0.0 }
    }

    pub fn on(dev: &DeviceProfile, degree: usize) -> Self {
        assert!(degree.is_power_of_two() && degree >= 1, "tp degree {degree}");
        Self {
            degree,
            interconnect_bw: dev.interconnect_bw,
            collective_latency_s: 10e-6,
        }
    }

    /// Ring all-reduce time for `bytes` per GPU.
    pub fn allreduce_time(&self, bytes: f64) -> f64 {
        if self.degree <= 1 {
            return 0.0;
        }
        let p = self.degree as f64;
        self.collective_latency_s + 2.0 * (p - 1.0) / p * bytes / self.interconnect_bw
    }

    /// All-reduce volume per transformer layer for `tokens` activations of
    /// width `d_model` (two f16 all-reduces per layer: attention out + FFN
    /// out, the Megatron pattern).
    pub fn layer_allreduce_time(&self, tokens: usize, d_model: usize) -> f64 {
        let bytes = (tokens * d_model) as f64 * 2.0;
        2.0 * self.allreduce_time(bytes)
    }

    /// Fraction of each sharded GEMM / attention-head workload per GPU.
    pub fn shard(&self) -> f64 {
        1.0 / self.degree as f64
    }

    /// Aggregate device memory available for weights + KV across the group.
    pub fn total_memory(&self, dev: &DeviceProfile) -> f64 {
        (self.degree * dev.mem_capacity) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;

    #[test]
    fn degree_one_is_free() {
        let p = TpPlan::single();
        assert_eq!(p.allreduce_time(1e9), 0.0);
        assert_eq!(p.layer_allreduce_time(4096, 8192), 0.0);
        assert_eq!(p.shard(), 1.0);
    }

    #[test]
    fn ring_allreduce_scales() {
        let dev = DeviceProfile::a100();
        let p2 = TpPlan::on(&dev, 2);
        let p8 = TpPlan::on(&dev, 8);
        let b = 64.0 * 1024.0 * 1024.0;
        // 2(p-1)/p grows with p: 1.0 at p=2 → 1.75 at p=8.
        let t2 = p2.allreduce_time(b) - p2.collective_latency_s;
        let t8 = p8.allreduce_time(b) - p8.collective_latency_s;
        assert!((t8 / t2 - 1.75).abs() < 1e-6, "{}", t8 / t2);
    }

    #[test]
    fn nvlink_beats_pcie() {
        let a100 = DeviceProfile::a100();
        let rtx = DeviceProfile::rtx4090();
        let b = 1e8;
        assert!(TpPlan::on(&a100, 4).allreduce_time(b) < TpPlan::on(&rtx, 4).allreduce_time(b));
    }

    #[test]
    fn shard_and_memory() {
        let dev = DeviceProfile::h100();
        let p = TpPlan::on(&dev, 4);
        assert_eq!(p.shard(), 0.25);
        assert_eq!(p.total_memory(&dev), 4.0 * dev.mem_capacity as f64);
    }

    #[test]
    #[should_panic(expected = "tp degree")]
    fn rejects_non_pow2() {
        TpPlan::on(&DeviceProfile::a100(), 3);
    }
}

//! # turbomind
//!
//! A reproduction of *Efficient Mixed-Precision Large Language Model
//! Inference with TurboMind* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   continuous batching, a paged *quantized* KV-cache manager, a
//!   prefill/decode scheduler, sampling, metrics, a workload generator, and
//!   the GPU microarchitecture simulator (`gpusim`) used to regenerate the
//!   paper's kernel- and cluster-level figures.
//! * **Layer 2 (python/compile/model.py)** — a GQA transformer with prefill
//!   and decode graphs, AOT-lowered to HLO text once at build time.
//! * **Layer 1 (python/compile/kernels/)** — the paper's GEMM and attention
//!   pipelines as Pallas kernels, fused into the Layer-2 graphs.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT C API (`xla` crate) and the coordinator
//! drives them from Rust.
//!
//! See `DESIGN.md` for the full system inventory and the per-figure
//! experiment index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod kvcache;
pub mod metrics;
pub mod parallel;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod serving_sim;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! # turbomind
//!
//! A reproduction of *Efficient Mixed-Precision Large Language Model
//! Inference with TurboMind* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: request routing,
//!   continuous batching, a paged *quantized* KV-cache manager, a
//!   prefill/decode scheduler, sampling, metrics, a workload generator,
//!   the GPU microarchitecture simulator (`gpusim`) used to regenerate the
//!   paper's kernel- and cluster-level figures, and a
//!   precision-heterogeneous multi-replica router tier ([`cluster`],
//!   DESIGN.md §9) that spreads traffic over N engine replicas, each with
//!   its own precision format and device profile.
//! * **Layer 2 (python/compile/model.py)** — a GQA transformer with prefill
//!   and decode graphs, AOT-lowered to HLO text once at build time.
//! * **Layer 1 (python/compile/kernels/)** — the paper's GEMM and attention
//!   pipelines as Pallas kernels, fused into the Layer-2 graphs.
//!
//! The coordinator drives a **pluggable execution backend**
//! ([`runtime::ExecutionBackend`]):
//!
//! * the default build serves through [`runtime::SimBackend`] — a
//!   deterministic pure-Rust model whose logits honor the configured
//!   precision format via the `quant` round-trip error models and whose
//!   iteration latency comes from the [`gpusim`] cost models. The entire
//!   submit → prefill-chunk → paged-KV → decode → sample → finish path,
//!   the JSON-lines TCP server, and the benches run hermetically: no
//!   artifacts, no Python, no network;
//! * with `--features pjrt`, `runtime::PjrtBackend` executes the AOT
//!   artifacts through the PJRT C API (`xla` crate) — Python never runs on
//!   the request path.
//!
//! See `DESIGN.md` (repo root) for the full system inventory, the backend
//! contract, the JSON-lines serving protocol, and the per-figure
//! experiment index; see `EXPERIMENTS.md` for how to run the tier-1
//! verify, the benches, and the `pjrt` feature.

pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod kvcache;
pub mod metrics;
pub mod parallel;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod serving_sim;
pub mod store;
pub mod trace;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

//! Mixed-precision GEMM kernel cost model (the paper's GEMM pipeline, §3.4).
//!
//! Roofline-style with explicit stages: global-memory traffic (scaled by the
//! framework's coalescing), a shared-memory stage (scaled by bank-conflict
//! serialization), tensor-core MMA time (scaled by tile alignment), and
//! dequantization ALU work of which only `1 - dequant_overlap` is exposed
//! (§4.3). Kernel time is the slowest of the overlapped streams plus the
//! exposed dequant and launch overhead.

use super::framework::KernelTraits;
use crate::config::DeviceProfile;

/// One GEMM invocation: activations `[m, k] × weights [k, n]`.
#[derive(Debug, Clone, Copy)]
pub struct GemmWorkload {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Weight bits (4, 8, or 16).
    pub w_bits: usize,
    /// Activation bits (8 or 16).
    pub a_bits: usize,
    /// Quantization group size (scales per group; ignored for w16).
    pub group_size: usize,
}

impl GemmWorkload {
    pub fn w4a16(m: usize, k: usize, n: usize) -> Self {
        Self { m, k, n, w_bits: 4, a_bits: 16, group_size: 128 }
    }

    pub fn f16(m: usize, k: usize, n: usize) -> Self {
        Self { m, k, n, w_bits: 16, a_bits: 16, group_size: 128 }
    }

    /// Weight + scale bytes read from global memory.
    pub fn weight_bytes(&self) -> f64 {
        let w = (self.k * self.n) as f64 * self.w_bits as f64 / 8.0;
        let scales = if self.w_bits < 16 {
            (self.k / self.group_size * self.n) as f64 * 2.0 // f16 scales
        } else {
            0.0
        };
        w + scales
    }

    /// Activation input + output bytes (f16 activations unless a_bits=8).
    pub fn act_bytes(&self) -> f64 {
        let a = (self.m * self.k) as f64 * self.a_bits as f64 / 8.0;
        let o = (self.m * self.n) as f64 * 2.0;
        a + o
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
}

/// Cost breakdown for one GEMM kernel.
#[derive(Debug, Clone, Copy)]
pub struct GemmReport {
    /// Total kernel time, seconds (including launch overhead).
    pub time_s: f64,
    /// HBM-stream time.
    pub t_mem: f64,
    /// Tensor-core time.
    pub t_mma: f64,
    /// Exposed (non-overlapped) dequantization time.
    pub t_dequant_exposed: f64,
    /// Shared-memory stage time.
    pub t_smem: f64,
    /// Achieved HBM bandwidth as a fraction of peak.
    pub bw_utilization: f64,
    /// Achieved tensor-core utilization.
    pub tc_utilization: f64,
}

/// The model.
pub struct GemmKernelModel<'a> {
    pub dev: &'a DeviceProfile,
    pub traits: &'a KernelTraits,
}

impl<'a> GemmKernelModel<'a> {
    pub fn new(dev: &'a DeviceProfile, traits: &'a KernelTraits) -> Self {
        Self { dev, traits }
    }

    /// Time one GEMM kernel.
    pub fn run(&self, w: &GemmWorkload) -> GemmReport {
        let dev = self.dev;
        let tr = self.traits;

        // Layout penalties (coalescing, bank conflicts, fragment
        // misalignment) are properties of *quantized* weight layouts
        // (Challenges I/II/V); dense f16 weights stream near-perfectly in
        // every framework, which is exactly the paper's Fig 27 control.
        let quantized = w.w_bits < 16;
        let coalesce = if quantized { tr.coalescing_eff } else { tr.coalescing_eff.max(0.97) };
        let bank = if quantized { tr.bank_conflict_factor } else { 1.0 };
        let align = if quantized { tr.mma_alignment_eff } else { tr.mma_alignment_eff.max(0.97) };

        // --- global memory stream -----------------------------------------
        // Weight stream pays the coalescing penalty of the layout;
        // activations/outputs are dense row-major and stream at profile
        // efficiency.
        let bw = dev.mem_bw * dev.mem_eff;
        let t_mem = (w.weight_bytes() / coalesce + w.act_bytes()) / bw;

        // --- shared-memory stage -------------------------------------------
        // Every operand byte is staged through SMEM once (cp.async model);
        // bank conflicts serialize the stage.
        let smem_bytes = w.weight_bytes() + w.act_bytes();
        let t_smem = smem_bytes * bank / dev.smem_bw();

        // --- tensor-core stream ---------------------------------------------
        // INT8 activations (QServe-style W4A8) ride the INT8 tensor-core
        // path; otherwise weights are dequantized to f16 and the f16 path
        // applies. Small m under-fills the 16-wide MMA tile M dimension.
        let tc_peak = if w.a_bits == 8 { dev.tc_int8_ops } else { dev.tc_f16_flops };
        let m_fill = (w.m as f64 / 16.0).min(1.0).max(1.0 / 16.0);
        let m_eff = if w.m >= 16 { 1.0 } else { m_fill.max(0.25) };
        let tc_rate = tc_peak * align * m_eff;
        let t_mma = w.flops() / tc_rate;

        // --- dequantization (I2F + FMA on the ALUs) -------------------------
        // Each weight element is dequantized once per M macro-tile pass
        // (weights re-read per 2048 rows of M — the register-reuse window).
        let t_deq_raw = if w.w_bits < 16 {
            let reuse = (w.m as f64 / 2048.0).ceil() * tr.dequant_reuse_mult;
            let deq_elems = (w.k * w.n) as f64 * reuse;
            deq_elems * tr.dequant_instrs_per_elem / dev.alu_f32_flops
        } else {
            0.0
        };
        let t_dequant_exposed = t_deq_raw * (1.0 - tr.dequant_overlap);

        // --- combine ---------------------------------------------------------
        // Memory, SMEM and MMA streams overlap (software pipeline); exposed
        // dequant serializes with the compute stream.
        let t_body = t_mem.max(t_smem).max(t_mma + t_dequant_exposed);
        let time_s = t_body + dev.launch_overhead_s;

        GemmReport {
            time_s,
            t_mem,
            t_mma,
            t_dequant_exposed,
            t_smem,
            bw_utilization: ((w.weight_bytes() + w.act_bytes()) / time_s / dev.mem_bw).min(1.0),
            tc_utilization: (w.flops() / time_s / tc_peak).min(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use crate::gpusim::framework::Framework;

    fn model_for(fw: Framework, dev: &DeviceProfile) -> (KernelTraits, &DeviceProfile) {
        (fw.traits_on(dev), dev)
    }

    #[test]
    fn decode_gemm_is_memory_bound() {
        let dev = DeviceProfile::a100();
        let (tr, dev) = model_for(Framework::TurboMind, &dev);
        let m = GemmKernelModel::new(dev, &tr);
        // Batch-1 decode projection: memory stream dominates.
        let r = m.run(&GemmWorkload::w4a16(1, 4096, 12288));
        assert!(r.t_mem > r.t_mma, "mem {} vs mma {}", r.t_mem, r.t_mma);
    }

    #[test]
    fn w4_beats_f16_at_small_batch() {
        // Fig 13 left side: INT4×FP16 ~2× faster than FP16×FP16 at B=1-16
        // because decode GEMM is weight-bandwidth-bound.
        let dev = DeviceProfile::a100();
        let tr = Framework::TurboMind.traits_on(&dev);
        let m = GemmKernelModel::new(&dev, &tr);
        for batch in [1, 4, 16] {
            let t4 = m.run(&GemmWorkload::w4a16(batch, 8192, 8192)).time_s;
            let t16 = m.run(&GemmWorkload::f16(batch, 8192, 8192)).time_s;
            let speedup = t16 / t4;
            assert!(speedup > 1.5, "B={batch}: speedup {speedup}");
            assert!(speedup < 4.5, "B={batch}: speedup {speedup} (bounded by 4x + scales)");
        }
    }

    #[test]
    fn w4_reaches_parity_at_large_batch() {
        // Fig 13 right side: at B=64+ the kernel turns compute-bound and
        // INT4×FP16 ≈ FP16×FP16 (both MMA-limited in f16).
        let dev = DeviceProfile::a100();
        let tr = Framework::TurboMind.traits_on(&dev);
        let m = GemmKernelModel::new(&dev, &tr);
        let t4 = m.run(&GemmWorkload::w4a16(512, 8192, 8192)).time_s;
        let t16 = m.run(&GemmWorkload::f16(512, 8192, 8192)).time_s;
        let ratio = t4 / t16;
        assert!((0.9..=1.15).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn marlin_loses_more_off_ampere() {
        // §5.3 mechanism: MARLIN's gap vs TurboMind grows on Ada/Hopper.
        let w = GemmWorkload::w4a16(8, 8192, 8192);
        let gap_on = |dev: &DeviceProfile| {
            let tm = Framework::TurboMind.traits_on(dev);
            let ml = Framework::VllmMarlin.traits_on(dev);
            let t_tm = GemmKernelModel::new(dev, &tm).run(&w).time_s;
            let t_ml = GemmKernelModel::new(dev, &ml).run(&w).time_s;
            t_ml / t_tm
        };
        let a100 = DeviceProfile::a100();
        let h100 = DeviceProfile::h100();
        assert!(gap_on(&h100) > gap_on(&a100), "h100 {} a100 {}", gap_on(&h100), gap_on(&a100));
        assert!(gap_on(&a100) >= 1.0);
    }

    #[test]
    fn trt_exposes_dequant() {
        let dev = DeviceProfile::a100();
        let tm = Framework::TurboMind.traits_on(&dev);
        let trt = Framework::TensorRtLlm.traits_on(&dev);
        let w = GemmWorkload::w4a16(256, 8192, 8192);
        let r_tm = GemmKernelModel::new(&dev, &tm).run(&w);
        let r_trt = GemmKernelModel::new(&dev, &trt).run(&w);
        assert!(r_trt.t_dequant_exposed > 5.0 * r_tm.t_dequant_exposed);
    }

    #[test]
    fn bandwidth_utilization_sane() {
        let dev = DeviceProfile::a100();
        let tr = Framework::TurboMind.traits_on(&dev);
        let r = GemmKernelModel::new(&dev, &tr).run(&GemmWorkload::w4a16(1, 8192, 57344));
        assert!(r.bw_utilization > 0.5 && r.bw_utilization <= 1.0, "{}", r.bw_utilization);
        assert!(r.tc_utilization < 0.2, "decode GEMM must not be TC-bound");
    }

    #[test]
    fn weight_bytes_include_scales() {
        let w = GemmWorkload::w4a16(1, 1024, 1024);
        let raw = 1024.0 * 1024.0 * 0.5;
        assert!(w.weight_bytes() > raw);
        assert!(w.weight_bytes() < raw * 1.1);
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let dev = DeviceProfile::a100();
        let tr = Framework::TurboMind.traits_on(&dev);
        let r = GemmKernelModel::new(&dev, &tr).run(&GemmWorkload::w4a16(1, 64, 64));
        assert!(r.time_s >= dev.launch_overhead_s);
    }
}

//! Framework parameterizations: the design deltas the paper names, encoded
//! as kernel-model coefficients.
//!
//! Sources for each choice are the paper's own characterizations (§1, §2,
//! §3) and the cited framework documentation:
//! * **TurboMind** — §4.1 packing gives coalesced/conflict-free/aligned
//!   loads (measured properties of our `quant::packing` implementation);
//!   §4.3 ILP hides most dequant (Table 2: +64.66% instructions → +2.89%
//!   cycles ⇒ ~82% of dequant cycles hidden at full utilization); §4.4
//!   pipelines KV loads.
//! * **MARLIN** — "intrinsic design limitations that prevent it from fully
//!   adapting to … GPU generations other than Ampere" (§1): near-TurboMind
//!   GEMM on Ampere, degraded coalescing/alignment elsewhere; GEMM-only
//!   optimization (§2) — its serving attention is vLLM's fp8-KV kernel,
//!   which dequantizes **before** the matrix-load (§4.2), doubling SMEM
//!   traffic and idling tensor cores during conversion.
//! * **TensorRT-LLM** — "suffers from significant runtime dequantization
//!   overhead with INT4" (§2, citing QServe's measurement): low overlap,
//!   expensive per-element I2F, runtime swizzle cost.
//! * **QServe** — W4A8KV4 only; INT8 tensor-core main loop with per-channel
//!   reorder; good but not layout-free (paper Fig 20: TurboMind +14.1%
//!   despite QServe's more aggressive activation quantization).

use crate::config::{DeviceProfile, GpuArch};

/// The systems compared across the paper's figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    /// LMDeploy + TurboMind (this paper).
    TurboMind,
    /// vLLM + MARLIN kernels.
    VllmMarlin,
    /// TensorRT-LLM.
    TensorRtLlm,
    /// OmniServe + QServe (W4A8KV4).
    QServe,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::TurboMind => "LMDeploy",
            Framework::VllmMarlin => "vLLM+MARLIN",
            Framework::TensorRtLlm => "TensorRT-LLM",
            Framework::QServe => "OmniServe+QServe",
        }
    }

    pub fn all() -> [Framework; 4] {
        [Framework::TurboMind, Framework::VllmMarlin, Framework::TensorRtLlm, Framework::QServe]
    }

    /// Kernel-model coefficients on a given device.
    pub fn traits_on(self, dev: &DeviceProfile) -> KernelTraits {
        let ampere = dev.arch == GpuArch::Ampere;
        match self {
            Framework::TurboMind => KernelTraits {
                coalescing_eff: 1.0,       // §4.1 guarantee (measured)
                bank_conflict_factor: 1.0, // §4.1 guarantee (measured)
                mma_alignment_eff: 1.0,    // §4.1 step (iii) bakes MMA order in
                dequant_overlap: 0.82,     // Table 2: +64.66% instrs → +2.89% cycles
                dequant_instrs_per_elem: 1.3, // lop3-parallel I2F (§4.3)
                dequant_reuse_mult: 1.0,   // §4.1: packed fragments load once
                attn_dequant_before_load: false, // §4.2 rearranges Q instead
                attn_overlap: 0.90,        // §4.4 KV loading pipeline
                cpu_overhead_s: 20e-6,     // C++ scheduler iteration overhead
                supports_w4a16: true,
                supports_w4a8: false,
                supports_kv_bits: &[16, 8, 4],
            },
            Framework::VllmMarlin => KernelTraits {
                // MARLIN's static layout is hand-tuned for Ampere; on other
                // generations its fragment layout mismatches the wider MMA
                // tiles and cache-line behaviour (§1, §2).
                coalescing_eff: if ampere { 0.98 } else { 0.80 },
                bank_conflict_factor: if ampere { 1.0 } else { 1.35 },
                mma_alignment_eff: if ampere { 0.97 } else { 0.85 },
                dequant_overlap: if ampere { 0.78 } else { 0.55 },
                dequant_instrs_per_elem: 1.6,
                dequant_reuse_mult: if ampere { 1.2 } else { 2.5 },
                // vLLM's quantized-KV attention dequantizes to f16 in SMEM
                // before ldmatrix (§4.2 "existing frameworks").
                attn_dequant_before_load: true,
                attn_overlap: 0.55,
                cpu_overhead_s: 150e-6, // python-side scheduling per iteration
                supports_w4a16: true,
                supports_w4a8: false,
                supports_kv_bits: &[16, 8],
            },
            Framework::TensorRtLlm => KernelTraits {
                coalescing_eff: 0.90,
                bank_conflict_factor: 1.15,
                mma_alignment_eff: 0.92,
                // "substantial runtime overhead during dequantization" (§1).
                dequant_overlap: 0.35,
                dequant_instrs_per_elem: 4.0, // naive I2F casts (§3.3)
                dequant_reuse_mult: 6.0, // re-dequant per threadblock pass
                attn_dequant_before_load: true,
                attn_overlap: 0.60,
                cpu_overhead_s: 40e-6,
                supports_w4a16: true,
                supports_w4a8: false,
                supports_kv_bits: &[16, 8],
            },
            Framework::QServe => KernelTraits {
                coalescing_eff: 0.97,
                bank_conflict_factor: 1.05,
                // QServe's INT8 mainloop spends its nominal 2× INT8 tensor-
                // core advantage on per-channel zero-point compensation and
                // the W4→W8 subtraction trick (its own roofline analysis):
                // effective MMA throughput lands near the f16 peak, which is
                // how this paper outruns it despite coarser W4A16 (Fig 20).
                mma_alignment_eff: 0.55,
                dequant_overlap: 0.75, // W4→W8 dequant in the INT8 mainloop
                dequant_instrs_per_elem: 1.8,
                dequant_reuse_mult: 1.5,
                attn_dequant_before_load: false,
                attn_overlap: 0.78,
                cpu_overhead_s: 80e-6,
                supports_w4a16: false,
                supports_w4a8: true, // hard-wired W4A8KV4 (§2)
                supports_kv_bits: &[4],
            },
        }
    }
}

/// Kernel-model coefficients (see the module docs for sourcing).
#[derive(Debug, Clone)]
pub struct KernelTraits {
    /// Fraction of peak coalesced bandwidth achieved on weight/KV streams.
    pub coalescing_eff: f64,
    /// Shared-memory serialization multiplier (1.0 = conflict-free).
    pub bank_conflict_factor: f64,
    /// Tensor-core efficiency from fragment/tile alignment.
    pub mma_alignment_eff: f64,
    /// Fraction of dequant ALU time hidden behind MMA (§4.3).
    pub dequant_overlap: f64,
    /// ALU instructions per dequantized weight element.
    pub dequant_instrs_per_elem: f64,
    /// How many times each weight element is dequantized per kernel pass.
    /// Offline-packed layouts keep fragments register-resident (1.0);
    /// runtime-swizzled kernels re-dequantize per consuming threadblock
    /// (§2: TRT-LLM's "substantial runtime dequantization overhead").
    pub dequant_reuse_mult: f64,
    /// Attention: dequantize the whole KV tile to f16 in SMEM before the
    /// matrix load (doubles SMEM traffic, idles tensor cores) instead of
    /// aligning Q to the quantized K layout (§4.2).
    pub attn_dequant_before_load: bool,
    /// Fraction of KV load+dequant hidden behind attention MMA (§4.4).
    pub attn_overlap: f64,
    /// Scheduler/runtime overhead per engine iteration.
    pub cpu_overhead_s: f64,
    pub supports_w4a16: bool,
    pub supports_w4a8: bool,
    /// KV-cache bit-widths the framework can serve.
    pub supports_kv_bits: &'static [usize],
}

impl KernelTraits {
    pub fn supports_kv(&self, bits: usize) -> bool {
        self.supports_kv_bits.contains(&bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;

    #[test]
    fn turbomind_has_the_packing_guarantees() {
        for dev in DeviceProfile::all() {
            let t = Framework::TurboMind.traits_on(&dev);
            assert_eq!(t.coalescing_eff, 1.0);
            assert_eq!(t.bank_conflict_factor, 1.0);
            assert_eq!(t.mma_alignment_eff, 1.0);
        }
    }

    #[test]
    fn marlin_degrades_off_ampere() {
        let a100 = DeviceProfile::a100();
        let h100 = DeviceProfile::h100();
        let on = Framework::VllmMarlin.traits_on(&a100);
        let off = Framework::VllmMarlin.traits_on(&h100);
        assert!(on.coalescing_eff > off.coalescing_eff);
        assert!(on.mma_alignment_eff > off.mma_alignment_eff);
        assert!(on.dequant_overlap > off.dequant_overlap);
    }

    #[test]
    fn turbomind_beats_all_on_every_coefficient_class() {
        for dev in DeviceProfile::all() {
            let tm = Framework::TurboMind.traits_on(&dev);
            for fw in [Framework::VllmMarlin, Framework::TensorRtLlm, Framework::QServe] {
                let t = fw.traits_on(&dev);
                assert!(tm.coalescing_eff >= t.coalescing_eff, "{fw:?} on {}", dev.name);
                assert!(tm.dequant_overlap >= t.dequant_overlap);
                assert!(tm.cpu_overhead_s <= t.cpu_overhead_s);
            }
        }
    }

    #[test]
    fn qserve_is_hardwired() {
        let t = Framework::QServe.traits_on(&DeviceProfile::a100());
        assert!(!t.supports_w4a16);
        assert!(t.supports_w4a8);
        assert!(t.supports_kv(4));
        assert!(!t.supports_kv(16));
    }

    #[test]
    fn names_are_papers() {
        assert_eq!(Framework::TurboMind.name(), "LMDeploy");
        assert_eq!(Framework::all().len(), 4);
    }
}

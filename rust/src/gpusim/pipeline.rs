//! Software-pipeline simulator: the §4.3 three-stage mainloop at
//! tile granularity, with instruction and cycle accounting (Table 2).
//!
//! The mainloop iterates K-tiles; per tile three stages run on different
//! execution units (LD/ST units, INT/FP ALUs, tensor cores) and the
//! pipeline overlaps stage `i` of tile `k` with stage `i+1` of tile `k-1`
//! (Figure 9). The simulator schedules tiles against per-unit availability
//! and reports both the pipelined makespan and the instruction counts, so
//! Table 2's "+64.66% instructions → +2.89% cycles" is *derived*, not
//! asserted.

use super::framework::KernelTraits;
use crate::config::DeviceProfile;

/// Instruction/cycle counters for one simulated kernel.
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineCounters {
    pub ld_instrs: u64,
    pub mma_instrs: u64,
    pub dequant_instrs: u64,
    pub other_instrs: u64,
    pub cycles: u64,
}

impl PipelineCounters {
    pub fn total_instrs(&self) -> u64 {
        self.ld_instrs + self.mma_instrs + self.dequant_instrs + self.other_instrs
    }

    pub fn runtime_s(&self, dev: &DeviceProfile) -> f64 {
        self.cycles as f64 / dev.clock_hz
    }
}

/// Pipeline simulator for a `[m, k] × [k, n]` GEMM mainloop.
pub struct PipelineSim<'a> {
    pub dev: &'a DeviceProfile,
    pub traits: &'a KernelTraits,
    /// Memory pipeline depth (prefetched tiles; ≥3 on SM80+, §4.4 fn 2).
    pub depth: usize,
}

/// Per-warp MMA tile: m16n8k16 → 2·16·8·16 FLOP per instruction.
const FLOP_PER_MMA: f64 = 2.0 * 16.0 * 8.0 * 16.0;
/// 128-bit vectorized loads.
const BYTES_PER_LD: f64 = 16.0;
/// K-extent of one mainloop tile.
const TILE_K: usize = 64;
/// Address/branch/sync overhead instructions per (tile, SM) iteration.
const OTHER_PER_TILE: f64 = 48.0;
/// Weight register-reuse window along M (one dequant per element per pass).
const M_REUSE: f64 = 2048.0;

impl<'a> PipelineSim<'a> {
    pub fn new(dev: &'a DeviceProfile, traits: &'a KernelTraits) -> Self {
        Self { dev, traits, depth: 3 }
    }

    /// Simulate the mainloop for an `m×k×n` GEMM with `w_bits` weights.
    pub fn gemm(&self, m: usize, k: usize, n: usize, w_bits: usize) -> PipelineCounters {
        let dev = self.dev;
        let tr = self.traits;

        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let mma_instrs = flops / FLOP_PER_MMA;

        let weight_bytes = k as f64 * n as f64 * w_bits as f64 / 8.0;
        let act_bytes = (m * k) as f64 * 2.0 + (m * n) as f64 * 2.0;
        let ld_instrs = (weight_bytes + act_bytes) / BYTES_PER_LD;

        let dequant_instrs = if w_bits < 16 {
            let reuse = (m as f64 / M_REUSE).ceil() * tr.dequant_reuse_mult;
            k as f64 * n as f64 * reuse * tr.dequant_instrs_per_elem
        } else {
            0.0
        };

        let n_tiles = (k / TILE_K).max(1) as f64;
        // Addressing / predication / ldsm companions issued per MMA (the
        // cuBLAS f16 kernel in Table 2 retires ~2.02 instructions per
        // mma.sync: 4.34e9 total for 2.15e9 MMAs at 16384³), plus per-tile
        // loop control.
        let other_instrs =
            mma_instrs * 1.0 + n_tiles * OTHER_PER_TILE * dev.sm_count as f64;

        // Per-unit issue rates (instructions per cycle, whole device).
        let sm = dev.sm_count as f64;
        let tc_ipc = 0.5 * sm; // one mma.sync per ~2 cycles per SM
        let alu_ipc = 4.0 * sm; // 4 warp schedulers issuing ALU ops
        let ld_ipc = 4.0 * sm; // LD/ST unit issue
        // The LD stream is also bounded by HBM bandwidth.
        let mem_cycles =
            (weight_bytes / tr.coalescing_eff + act_bytes) / (dev.mem_bw * dev.mem_eff)
                * dev.clock_hz;

        // Pipelined schedule over tiles: per-tile stage costs in cycles.
        let tiles = n_tiles.max(1.0);
        let ld_tile = (ld_instrs / ld_ipc).max(mem_cycles) / tiles;
        let deq_tile = dequant_instrs / alu_ipc / tiles;
        let mma_tile = mma_instrs / tc_ipc / tiles;

        // Three-stage pipeline with `depth` in-flight tiles: steady-state
        // rate is the slowest stage; the dequant stage overlaps the MMA
        // stage except for its exposed fraction.
        let deq_exposed = deq_tile * (1.0 - tr.dequant_overlap);
        let steady = ld_tile.max(mma_tile + deq_exposed);
        let fill = ld_tile + deq_tile + mma_tile; // first tile through all stages
        let cycles = fill + steady * (tiles - 1.0).max(0.0)
            + self.depth as f64 * OTHER_PER_TILE;

        PipelineCounters {
            ld_instrs: ld_instrs as u64,
            mma_instrs: mma_instrs as u64,
            dequant_instrs: dequant_instrs as u64,
            other_instrs: other_instrs as u64,
            cycles: cycles as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use crate::gpusim::framework::Framework;

    /// The Table 2 setting: 16384³ GEMM at full utilization on A100.
    fn table2(fw: Framework, w_bits: usize) -> PipelineCounters {
        let dev = DeviceProfile::a100();
        let tr = fw.traits_on(&dev);
        let sim = PipelineSim::new(&dev, &tr);
        sim.gemm(16384, 16384, 16384, w_bits)
    }

    #[test]
    fn table2_instruction_overhead_in_range() {
        // Paper: INT4×FP16 needs ~64.66% more instructions than cuBLAS f16.
        let int4 = table2(Framework::TurboMind, 4);
        let f16 = table2(Framework::TurboMind, 16);
        let overhead =
            int4.total_instrs() as f64 / f16.total_instrs() as f64 - 1.0;
        assert!(
            (0.40..=0.90).contains(&overhead),
            "instr overhead {overhead} (paper: 0.6466)"
        );
    }

    #[test]
    fn table2_cycle_overhead_small() {
        // Paper: that instruction overhead costs only ~2.89% extra cycles.
        let int4 = table2(Framework::TurboMind, 4);
        let f16 = table2(Framework::TurboMind, 16);
        let overhead = int4.cycles as f64 / f16.cycles as f64 - 1.0;
        assert!(
            (0.0..=0.10).contains(&overhead),
            "cycle overhead {overhead} (paper: 0.0289)"
        );
    }

    #[test]
    fn table2_absolute_runtime_order_of_magnitude() {
        // Paper: ~29.55 ms (cuBLAS) / 30.28 ms (LMDeploy) on A100.
        let dev = DeviceProfile::a100();
        let f16 = table2(Framework::TurboMind, 16);
        let t = f16.runtime_s(&dev);
        assert!((0.015..0.060).contains(&t), "runtime {t}s (paper 0.0296)");
    }

    #[test]
    fn trt_exposes_far_more_cycles() {
        let tm = table2(Framework::TurboMind, 4);
        let trt = table2(Framework::TensorRtLlm, 4);
        assert!(trt.cycles > tm.cycles, "trt {} tm {}", trt.cycles, tm.cycles);
        // TRT's naive I2F also inflates the instruction count itself.
        assert!(trt.dequant_instrs > 2 * tm.dequant_instrs);
    }

    #[test]
    fn dequant_instrs_zero_for_f16() {
        assert_eq!(table2(Framework::TurboMind, 16).dequant_instrs, 0);
    }

    #[test]
    fn small_gemm_dominated_by_fill() {
        let dev = DeviceProfile::a100();
        let tr = Framework::TurboMind.traits_on(&dev);
        let sim = PipelineSim::new(&dev, &tr);
        let c = sim.gemm(1, 128, 128, 4);
        assert!(c.cycles > 0);
        assert!(c.mma_instrs < 100);
    }
}

//! Attention kernel cost model (the paper's attention pipeline, §3.4).
//!
//! Decode attention is KV-bandwidth-bound: every step streams the entire KV
//! history. Quantized KV cuts that traffic 2-4×, *if* the kernel can consume
//! low-bit tiles directly. The model captures the two designs the paper
//! contrasts (§4.2):
//!
//! * **dequant-before-load** (vLLM/TRT fp8-KV kernels): the low-bit tile is
//!   converted to f16 in shared memory before `ldmatrix` — SMEM traffic
//!   doubles (write f16 + read f16), the conversion is exposed (tensor
//!   cores idle), and the bandwidth win shrinks;
//! * **head-aligned direct consumption** (TurboMind): Q is rearranged once
//!   per head to match the low-bit K fragment layout; dequant rides the
//!   §4.4 loading pipeline and mostly overlaps the MMA stream.

use super::framework::KernelTraits;
use crate::config::DeviceProfile;

/// One attention kernel invocation (whole layer: all heads).
#[derive(Debug, Clone, Copy)]
pub struct AttnWorkload {
    /// Sequences in the batch (decode) or 1 (prefill chunk).
    pub batch: usize,
    /// Query tokens per sequence (1 for decode; chunk length for prefill).
    pub q_tokens: usize,
    /// KV history length attended per sequence.
    pub kv_len: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// KV cache bits (16, 8, 4).
    pub kv_bits: usize,
}

impl AttnWorkload {
    pub fn decode(batch: usize, kv_len: usize, h: usize, hkv: usize, d: usize, kv_bits: usize) -> Self {
        Self { batch, q_tokens: 1, kv_len, n_heads: h, n_kv_heads: hkv, head_dim: d, kv_bits }
    }

    /// KV bytes streamed from HBM (codes + per-token scales when quantized).
    pub fn kv_bytes(&self) -> f64 {
        let rows = (self.batch * self.kv_len * self.n_kv_heads) as f64;
        let codes = rows * 2.0 * self.head_dim as f64 * self.kv_bits as f64 / 8.0;
        let scales = if self.kv_bits < 16 { rows * 2.0 * 2.0 } else { 0.0 };
        codes + scales
    }

    /// Q/O bytes (f16).
    pub fn qo_bytes(&self) -> f64 {
        (self.batch * self.q_tokens * self.n_heads * self.head_dim) as f64 * 2.0 * 2.0
    }

    /// QK^T + PV FLOPs.
    pub fn flops(&self) -> f64 {
        let per_q = 2.0 * 2.0 * (self.kv_len * self.head_dim) as f64;
        // Prefill adds causal attention within the chunk (~q/2 average).
        let intra = if self.q_tokens > 1 {
            2.0 * 2.0 * (self.q_tokens as f64 / 2.0) * self.head_dim as f64
        } else {
            0.0
        };
        (self.batch * self.q_tokens * self.n_heads) as f64 * (per_q + intra)
    }

    /// Elements dequantized (K and V rows consumed).
    pub fn dequant_elems(&self) -> f64 {
        if self.kv_bits >= 16 {
            return 0.0;
        }
        (self.batch * self.kv_len * self.n_kv_heads * 2 * self.head_dim) as f64
    }
}

/// Cost breakdown for one attention kernel.
#[derive(Debug, Clone, Copy)]
pub struct AttentionReport {
    pub time_s: f64,
    pub t_mem: f64,
    pub t_mma: f64,
    pub t_dequant_exposed: f64,
    pub t_smem: f64,
    /// Useful HBM bytes / (time × peak bw) — the Fig 26 metric.
    pub bw_utilization: f64,
}

pub struct AttentionKernelModel<'a> {
    pub dev: &'a DeviceProfile,
    pub traits: &'a KernelTraits,
}

impl<'a> AttentionKernelModel<'a> {
    pub fn new(dev: &'a DeviceProfile, traits: &'a KernelTraits) -> Self {
        Self { dev, traits }
    }

    pub fn run(&self, w: &AttnWorkload) -> AttentionReport {
        let dev = self.dev;
        let tr = self.traits;

        let useful = w.kv_bytes() + w.qo_bytes();
        let bw = dev.mem_bw * dev.mem_eff;
        // Dense f16 KV reads coalesce everywhere; the layout penalty is a
        // low-bit-KV phenomenon (Challenge-I/III). Kernels that rebuild
        // tensor-core tiles with per-lane address arithmetic after
        // disabling ldmatrix (Challenge-III, the dequant-before-load
        // family) additionally stall the load stream.
        let quantized = w.kv_bits < 16;
        let coalesce = if quantized { tr.coalescing_eff } else { tr.coalescing_eff.max(0.97) };
        let reconstruct = if quantized && tr.attn_dequant_before_load { 0.75 } else { 1.0 };
        let t_mem = (w.kv_bytes() / (coalesce * reconstruct) + w.qo_bytes()) / bw;

        // SMEM staging: dequant-before-load writes the f16 copy back to
        // SMEM and re-reads it (16-bit rows), tripling effective SMEM
        // traffic for the KV stream versus direct low-bit consumption.
        let smem_mult = if tr.attn_dequant_before_load && w.kv_bits < 16 {
            let f16_bytes = w.kv_bytes() * 16.0 / w.kv_bits as f64;
            1.0 + 2.0 * f16_bytes / w.kv_bytes()
        } else {
            1.0
        };
        let t_smem = w.kv_bytes() * smem_mult * tr.bank_conflict_factor / dev.smem_bw();

        // MMA stream. Decode q_tokens=1 under-fills the 16-row MMA tile;
        // the paper's Q-rearrangement (§4.2) keeps native tensor-core
        // operation anyway, while misaligned kernels fall back to shuffles
        // (alignment efficiency < 1 covers that).
        let tc_rate = dev.tc_f16_flops * tr.mma_alignment_eff * 0.25; // decode tile fill
        let t_mma = w.flops() / tc_rate;

        // Dequant ALU work; exposure per §4.4 overlap. Dequant-before-load
        // kernels additionally serialize the conversion with the MMA stream
        // (tensor cores idle while converting: zero overlap) and pay the
        // Challenge-III shuffle tax — per-lane tile reconstruction ops on
        // every dequantized element.
        let shuffle_tax = if tr.attn_dequant_before_load { 3.0 } else { 0.0 };
        let deq_ops = w.dequant_elems() * (tr.dequant_instrs_per_elem + shuffle_tax);
        let t_deq_raw = deq_ops / dev.alu_f32_flops;
        let overlap = if tr.attn_dequant_before_load { 0.0 } else { tr.attn_overlap };
        let t_dequant_exposed = t_deq_raw * (1.0 - overlap);

        let t_body = t_mem.max(t_smem).max(t_mma) + t_dequant_exposed;
        let time_s = t_body + dev.launch_overhead_s;

        AttentionReport {
            time_s,
            t_mem,
            t_mma,
            t_dequant_exposed,
            t_smem,
            bw_utilization: (useful / time_s / dev.mem_bw).min(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceProfile;
    use crate::gpusim::framework::Framework;

    fn wl(kv_bits: usize, kv_len: usize, batch: usize) -> AttnWorkload {
        // Qwen3-8B-ish attention shape: 32 heads, 8 KV heads, d=128.
        AttnWorkload::decode(batch, kv_len, 32, 8, 128, kv_bits)
    }

    #[test]
    fn decode_attention_is_bandwidth_bound() {
        let dev = DeviceProfile::a100();
        let tr = Framework::TurboMind.traits_on(&dev);
        let r = AttentionKernelModel::new(&dev, &tr).run(&wl(16, 4096, 16));
        assert!(r.t_mem > r.t_mma, "mem {} mma {}", r.t_mem, r.t_mma);
        assert!(r.bw_utilization > 0.6, "util {}", r.bw_utilization);
    }

    #[test]
    fn kv8_speeds_up_turbomind_but_not_prelaod_kernels_as_much() {
        // §3.3 Challenge-VI: naive kernels lose the bandwidth win to
        // dequant stalls; TurboMind keeps most of it (Fig 18 mechanism).
        let dev = DeviceProfile::a100();
        let tm = Framework::TurboMind.traits_on(&dev);
        let vm = Framework::VllmMarlin.traits_on(&dev);
        let m_tm = AttentionKernelModel::new(&dev, &tm);
        let m_vm = AttentionKernelModel::new(&dev, &vm);
        let sp_tm = m_tm.run(&wl(16, 8192, 32)).time_s / m_tm.run(&wl(8, 8192, 32)).time_s;
        let sp_vm = m_vm.run(&wl(16, 8192, 32)).time_s / m_vm.run(&wl(8, 8192, 32)).time_s;
        assert!(sp_tm > sp_vm, "tm {sp_tm} vm {sp_vm}");
        assert!(sp_tm > 1.4, "kv8 should approach 2x: {sp_tm}");
    }

    #[test]
    fn kv4_fastest_for_turbomind() {
        let dev = DeviceProfile::a100();
        let tm = Framework::TurboMind.traits_on(&dev);
        let m = AttentionKernelModel::new(&dev, &tm);
        let t16 = m.run(&wl(16, 8192, 32)).time_s;
        let t8 = m.run(&wl(8, 8192, 32)).time_s;
        let t4 = m.run(&wl(4, 8192, 32)).time_s;
        assert!(t4 < t8 && t8 < t16, "{t4} {t8} {t16}");
    }

    #[test]
    fn bw_utilization_matches_fig26_range() {
        // Appendix G: up to 86-93% with 8-bit KV at large batch.
        let dev = DeviceProfile::a100();
        let tm = Framework::TurboMind.traits_on(&dev);
        let m = AttentionKernelModel::new(&dev, &tm);
        let r = m.run(&wl(8, 8192, 64));
        assert!(r.bw_utilization > 0.75 && r.bw_utilization <= 0.95, "{}", r.bw_utilization);
        // Small batch: launch overhead dominates, utilization drops.
        let r1 = m.run(&wl(8, 512, 1));
        assert!(r1.bw_utilization < r.bw_utilization);
    }

    #[test]
    fn prefill_attention_has_intra_chunk_flops() {
        let mut w = wl(16, 1024, 1);
        w.q_tokens = 512;
        let base = wl(16, 1024, 1);
        assert!(w.flops() > 500.0 * base.flops());
    }

    #[test]
    fn kv_bytes_scale_with_bits() {
        let b16 = wl(16, 1000, 1).kv_bytes();
        let b8 = wl(8, 1000, 1).kv_bytes();
        let b4 = wl(4, 1000, 1).kv_bytes();
        assert!(b8 < b16 * 0.55 && b8 > b16 * 0.45);
        assert!(b4 < b8 * 0.6);
    }
}

//! GPU microarchitecture cost simulator — the substitute testbed for the
//! paper's CUDA evaluation (DESIGN.md §1).
//!
//! The paper's figures measure the *performance consequences* of data-layout
//! and overlap decisions on real GPUs. This simulator derives those
//! consequences from first principles per device profile:
//!
//! * memory traffic (weights / activations / KV bytes at each precision)
//!   against HBM bandwidth, scaled by each framework's **coalescing
//!   efficiency** (Challenge-I);
//! * a shared-memory stage scaled by **bank-conflict serialization**
//!   (Challenge-II);
//! * tensor-core MMA time at each framework's **tile-alignment efficiency**
//!   (Challenges III & V);
//! * dequantization ALU work, a fraction of which each framework's pipeline
//!   **overlaps** behind the MMA stream (Challenges IV & VI, §4.3-§4.4);
//! * a cycle/instruction-count pipeline model ([`pipeline`]) that reproduces
//!   the paper's nsight numbers (Table 2).
//!
//! Framework parameterizations ([`framework`]) encode the *documented*
//! design differences the paper attributes its wins to: MARLIN's
//! Ampere-specific static layout, TensorRT-LLM's exposed runtime dequant,
//! QServe's W4A8KV4-only path, and vLLM's dequant-before-`ldmatrix` fp8 KV
//! attention. TurboMind's parameters are the measured properties of the
//! §4.1 packed layout (see `quant::packing` tests: fully coalesced,
//! conflict-free) plus its published overlap behaviour.

pub mod attention;
pub mod framework;
pub mod gemm;
pub mod pipeline;

pub use attention::{AttentionKernelModel, AttentionReport, AttnWorkload};
pub use framework::{Framework, KernelTraits};
pub use gemm::{GemmKernelModel, GemmReport, GemmWorkload};
pub use pipeline::{PipelineCounters, PipelineSim};

//! Deterministic flight recorder: typed engine lifecycle events stamped
//! with the **modeled clock** (`EngineStats::sim_time_s`), exported as
//! Perfetto-loadable Chrome trace-event JSON (DESIGN.md §12).
//!
//! The recorder reuses the wait-free atomic-counter + seqlock-ring idiom
//! from [`crate::cluster::accounting::ReplicaRecorder`]: a single
//! producer (the engine's owning thread) publishes fixed-width encoded
//! events into a bounded ring without ever waiting or allocating; any
//! reader snapshots the ring, detecting and skipping torn slots. No
//! `unsafe`, std-only. An overfull ring windows to the most recent
//! `capacity` events — the monotonic `recorded` counter never windows, so
//! wraparound drops are counted **exactly** (`recorded − resident`).
//!
//! Determinism is the contract: events carry modeled time only, never
//! wall clock, so the same requests + the same config produce a
//! bit-identical trace (the harness and CI assert on this). Recording
//! defaults off; the engine's emit guard is a single `Option` test when
//! disabled, cheap enough that `bench hotpath` gates on it.

use std::collections::BTreeMap;
use std::sync::atomic::{fence, AtomicU64, Ordering};

use anyhow::{anyhow, bail, Result};

use crate::util::json::{arr, obj, Json};

/// Default ring capacity (events). Large enough that short bench/CI runs
/// never wrap; a wrapped ring still reports exact drop counts.
pub const DEFAULT_RING_CAPACITY: usize = 8192;

/// Bounded retries before a reader gives up on a slot the writer keeps
/// overwriting (writer is wait-free; the reader yields).
const READ_RETRIES: usize = 64;

/// Fixed slot width: word 0 = tag, word 1 = `sim_time_s` bits, words
/// 2..10 = per-kind payload.
const WORDS: usize = 10;

/// Display names of the three KV precision rungs, indexed by
/// [`crate::kvcache::KvPrecision::ladder_rank`].
pub const RUNG_NAMES: [&str; 3] = ["kv16", "kv8", "kv4"];

/// Preempt-mechanism codes carried in [`EventKind::Preempt`].
pub fn mechanism_name(code: u8) -> &'static str {
    match code {
        0 => "swap",
        1 => "recompute",
        2 => "ladder",
        _ => "unknown",
    }
}

/// Finish-reason codes carried in [`EventKind::Finish`].
pub fn finish_reason_name(code: u8) -> &'static str {
    match code {
        0 => "length",
        1 => "stop",
        2 => "aborted",
        _ => "unknown",
    }
}

/// Sentinel for "no request" in id-valued fields (e.g. a ladder preempt
/// decision that evicts nobody, or a missing runner-up candidate).
pub const NO_ID: u64 = u64::MAX;

/// One typed lifecycle event. All byte fields are *modeled* traffic
/// (the same accounting `EngineStats` sums); `dur_s` fields are the
/// modeled time the operation added to the engine clock, so an event's
/// span is `[sim_time_s, sim_time_s + dur_s]`.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A request entered the engine.
    Admit { id: u64, prompt_len: u64, max_new_tokens: u64 },
    /// Prefix-cache lookup at first admission: hit/miss, adopted blocks
    /// and tokens, and the pool layout fingerprint the key was rooted at.
    PrefixLookup { id: u64, hit: bool, blocks: u64, tokens: u64, fingerprint: u64 },
    /// One prefill chunk: `tokens` appended to the KV cache (0 when the
    /// append failed and the request aborted), padded gather length, HBM
    /// gather bytes split per precision rung, and whether the first
    /// token was sampled (`generated`).
    PrefillChunk {
        id: u64,
        tokens: u64,
        t_pad: u64,
        gather_by_rung: [u64; 3],
        generated: u64,
        dur_s: f64,
    },
    /// One decode iteration over the whole batch.
    DecodeIter {
        batch: u64,
        padded_slots: u64,
        t_pad: u64,
        generated: u64,
        gather_by_rung: [u64; 3],
        dur_s: f64,
    },
    /// A preemption decision: the chosen mechanism plus the losing
    /// candidates' modeled costs. `alt_cost_s` is the rejected mechanism
    /// for the same victim (or the best eviction cost a chosen ladder
    /// beat); `runner_up` is the next-best victim (`NO_ID` when none).
    Preempt {
        victim: u64,
        mechanism: u8,
        chosen_cost_s: f64,
        alt_cost_s: f64,
        candidates: u64,
        runner_up: u64,
        runner_up_cost_s: f64,
    },
    /// An in-place precision-ladder transcode of the whole pool:
    /// widest-changed source rung → narrowest destination rung, modeled
    /// HBM read+write bytes attributed to each destination rung, and the
    /// fingerprint of the layout laddered *to*.
    Ladder {
        rung_from: u8,
        rung_to: u8,
        bytes_by_rung: [u64; 3],
        gained_blocks: u64,
        dropped_tokens: u64,
        to_fingerprint: u64,
        dur_s: f64,
    },
    /// A victim's KV blocks copied to the host swap store (PCIe bytes
    /// split per resident precision rung).
    SwapOut { id: u64, bytes_by_rung: [u64; 3], dur_s: f64 },
    /// A swapped victim's blocks restored to the pool.
    SwapIn { id: u64, bytes_by_rung: [u64; 3], dur_s: f64 },
    /// A sequence's layout-tagged KV snapshot exported for cross-replica
    /// migration (disaggregated prefill → decode handoff, or replica
    /// drain). Bytes are attributed per the *snapshot's* recorded rung
    /// extents, never the pool's current layout.
    MigrateOut { id: u64, bytes_by_rung: [u64; 3], dur_s: f64 },
    /// A snapshot's bytes written to the page-file store's disk tier (a
    /// swap-out landing on disk, or prefix blocks published to the
    /// host-global store). Bytes split per the snapshot's recorded rungs;
    /// `dur_s` is the disk leg only — the PCIe leg is the paired
    /// `SwapOut`/`SwapIn` event.
    StoreWrite { id: u64, bytes_by_rung: [u64; 3], dur_s: f64 },
    /// A snapshot's bytes read back from the page-file store's disk tier
    /// (a disk-tier swap-in, or a shared-prefix chain adopted at
    /// admission).
    StoreRead { id: u64, bytes_by_rung: [u64; 3], dur_s: f64 },
    /// A migrated snapshot imported into this replica's pool.
    MigrateIn { id: u64, bytes_by_rung: [u64; 3], dur_s: f64 },
    /// The request left the engine (finished or aborted).
    Finish { id: u64, reason: u8, tokens: u64, latency_s: f64 },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admit { .. } => "admit",
            EventKind::PrefixLookup { .. } => "prefix_lookup",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::DecodeIter { .. } => "decode_iter",
            EventKind::Preempt { .. } => "preempt",
            EventKind::Ladder { .. } => "ladder",
            EventKind::SwapOut { .. } => "swap_out",
            EventKind::SwapIn { .. } => "swap_in",
            EventKind::MigrateOut { .. } => "migrate_out",
            EventKind::MigrateIn { .. } => "migrate_in",
            EventKind::StoreWrite { .. } => "store_write",
            EventKind::StoreRead { .. } => "store_read",
            EventKind::Finish { .. } => "finish",
        }
    }

    /// The request this event belongs to, when it belongs to one.
    pub fn request_id(&self) -> Option<u64> {
        match self {
            EventKind::Admit { id, .. }
            | EventKind::PrefixLookup { id, .. }
            | EventKind::PrefillChunk { id, .. }
            | EventKind::SwapOut { id, .. }
            | EventKind::SwapIn { id, .. }
            | EventKind::MigrateOut { id, .. }
            | EventKind::MigrateIn { id, .. }
            | EventKind::StoreWrite { id, .. }
            | EventKind::StoreRead { id, .. }
            | EventKind::Finish { id, .. } => Some(*id),
            _ => None,
        }
    }

    /// Modeled duration the operation added to the engine clock (0 for
    /// instantaneous decision events).
    pub fn dur_s(&self) -> f64 {
        match self {
            EventKind::PrefillChunk { dur_s, .. }
            | EventKind::DecodeIter { dur_s, .. }
            | EventKind::Ladder { dur_s, .. }
            | EventKind::SwapOut { dur_s, .. }
            | EventKind::SwapIn { dur_s, .. }
            | EventKind::MigrateOut { dur_s, .. }
            | EventKind::MigrateIn { dur_s, .. }
            | EventKind::StoreWrite { dur_s, .. }
            | EventKind::StoreRead { dur_s, .. } => *dur_s,
            _ => 0.0,
        }
    }
}

/// One recorded event: a kind stamped with the modeled clock at the
/// moment the operation *started*.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub sim_time_s: f64,
    pub kind: EventKind,
}

fn encode(ev: &TraceEvent) -> [u64; WORDS] {
    let mut w = [0u64; WORDS];
    w[1] = ev.sim_time_s.to_bits();
    match &ev.kind {
        EventKind::Admit { id, prompt_len, max_new_tokens } => {
            w[0] = 1;
            w[2] = *id;
            w[3] = *prompt_len;
            w[4] = *max_new_tokens;
        }
        EventKind::PrefixLookup { id, hit, blocks, tokens, fingerprint } => {
            w[0] = 2;
            w[2] = *id;
            w[3] = u64::from(*hit);
            w[4] = *blocks;
            w[5] = *tokens;
            w[6] = *fingerprint;
        }
        EventKind::PrefillChunk { id, tokens, t_pad, gather_by_rung, generated, dur_s } => {
            w[0] = 3;
            w[2] = *id;
            w[3] = *tokens;
            w[4] = *t_pad;
            w[5] = gather_by_rung[0];
            w[6] = gather_by_rung[1];
            w[7] = gather_by_rung[2];
            w[8] = *generated;
            w[9] = dur_s.to_bits();
        }
        EventKind::DecodeIter { batch, padded_slots, t_pad, generated, gather_by_rung, dur_s } => {
            w[0] = 4;
            w[2] = *batch;
            w[3] = *padded_slots;
            w[4] = *t_pad;
            w[5] = gather_by_rung[0];
            w[6] = gather_by_rung[1];
            w[7] = gather_by_rung[2];
            w[8] = *generated;
            w[9] = dur_s.to_bits();
        }
        EventKind::Preempt {
            victim,
            mechanism,
            chosen_cost_s,
            alt_cost_s,
            candidates,
            runner_up,
            runner_up_cost_s,
        } => {
            w[0] = 5;
            w[2] = *victim;
            w[3] = u64::from(*mechanism);
            w[4] = chosen_cost_s.to_bits();
            w[5] = alt_cost_s.to_bits();
            w[6] = *candidates;
            w[7] = *runner_up;
            w[8] = runner_up_cost_s.to_bits();
        }
        EventKind::Ladder {
            rung_from,
            rung_to,
            bytes_by_rung,
            gained_blocks,
            dropped_tokens,
            to_fingerprint,
            dur_s,
        } => {
            w[0] = 6;
            w[2] = (u64::from(*rung_from) << 8) | u64::from(*rung_to);
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[6] = *gained_blocks;
            w[7] = *dropped_tokens;
            w[8] = *to_fingerprint;
            w[9] = dur_s.to_bits();
        }
        EventKind::SwapOut { id, bytes_by_rung, dur_s } => {
            w[0] = 7;
            w[2] = *id;
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[9] = dur_s.to_bits();
        }
        EventKind::SwapIn { id, bytes_by_rung, dur_s } => {
            w[0] = 8;
            w[2] = *id;
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[9] = dur_s.to_bits();
        }
        EventKind::MigrateOut { id, bytes_by_rung, dur_s } => {
            w[0] = 10;
            w[2] = *id;
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[9] = dur_s.to_bits();
        }
        EventKind::MigrateIn { id, bytes_by_rung, dur_s } => {
            w[0] = 11;
            w[2] = *id;
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[9] = dur_s.to_bits();
        }
        EventKind::StoreWrite { id, bytes_by_rung, dur_s } => {
            w[0] = 12;
            w[2] = *id;
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[9] = dur_s.to_bits();
        }
        EventKind::StoreRead { id, bytes_by_rung, dur_s } => {
            w[0] = 13;
            w[2] = *id;
            w[3] = bytes_by_rung[0];
            w[4] = bytes_by_rung[1];
            w[5] = bytes_by_rung[2];
            w[9] = dur_s.to_bits();
        }
        EventKind::Finish { id, reason, tokens, latency_s } => {
            w[0] = 9;
            w[2] = *id;
            w[3] = u64::from(*reason);
            w[4] = *tokens;
            w[5] = latency_s.to_bits();
        }
    }
    w
}

fn decode(w: &[u64; WORDS]) -> Option<TraceEvent> {
    let sim_time_s = f64::from_bits(w[1]);
    let kind = match w[0] {
        1 => EventKind::Admit { id: w[2], prompt_len: w[3], max_new_tokens: w[4] },
        2 => EventKind::PrefixLookup {
            id: w[2],
            hit: w[3] != 0,
            blocks: w[4],
            tokens: w[5],
            fingerprint: w[6],
        },
        3 => EventKind::PrefillChunk {
            id: w[2],
            tokens: w[3],
            t_pad: w[4],
            gather_by_rung: [w[5], w[6], w[7]],
            generated: w[8],
            dur_s: f64::from_bits(w[9]),
        },
        4 => EventKind::DecodeIter {
            batch: w[2],
            padded_slots: w[3],
            t_pad: w[4],
            gather_by_rung: [w[5], w[6], w[7]],
            generated: w[8],
            dur_s: f64::from_bits(w[9]),
        },
        5 => EventKind::Preempt {
            victim: w[2],
            mechanism: w[3] as u8,
            chosen_cost_s: f64::from_bits(w[4]),
            alt_cost_s: f64::from_bits(w[5]),
            candidates: w[6],
            runner_up: w[7],
            runner_up_cost_s: f64::from_bits(w[8]),
        },
        6 => EventKind::Ladder {
            rung_from: (w[2] >> 8) as u8,
            rung_to: (w[2] & 0xff) as u8,
            bytes_by_rung: [w[3], w[4], w[5]],
            gained_blocks: w[6],
            dropped_tokens: w[7],
            to_fingerprint: w[8],
            dur_s: f64::from_bits(w[9]),
        },
        7 => EventKind::SwapOut {
            id: w[2],
            bytes_by_rung: [w[3], w[4], w[5]],
            dur_s: f64::from_bits(w[9]),
        },
        8 => EventKind::SwapIn {
            id: w[2],
            bytes_by_rung: [w[3], w[4], w[5]],
            dur_s: f64::from_bits(w[9]),
        },
        9 => EventKind::Finish {
            id: w[2],
            reason: w[3] as u8,
            tokens: w[4],
            latency_s: f64::from_bits(w[5]),
        },
        10 => EventKind::MigrateOut {
            id: w[2],
            bytes_by_rung: [w[3], w[4], w[5]],
            dur_s: f64::from_bits(w[9]),
        },
        11 => EventKind::MigrateIn {
            id: w[2],
            bytes_by_rung: [w[3], w[4], w[5]],
            dur_s: f64::from_bits(w[9]),
        },
        12 => EventKind::StoreWrite {
            id: w[2],
            bytes_by_rung: [w[3], w[4], w[5]],
            dur_s: f64::from_bits(w[9]),
        },
        13 => EventKind::StoreRead {
            id: w[2],
            bytes_by_rung: [w[3], w[4], w[5]],
            dur_s: f64::from_bits(w[9]),
        },
        _ => return None,
    };
    Some(TraceEvent { sim_time_s, kind })
}

#[derive(Debug, Default)]
struct EventSlot {
    /// Seqlock sequence: even = stable, odd = write in progress.
    seq: AtomicU64,
    words: [AtomicU64; WORDS],
}

/// The bounded, wait-free flight recorder. Single producer (the engine's
/// owning thread); any number of concurrent readers.
#[derive(Debug)]
pub struct TraceRecorder {
    /// Monotonic event count (also the ring cursor). Published last with
    /// `Release` so a reader that observes it observes the slots it
    /// promises.
    recorded: AtomicU64,
    ring: Box<[EventSlot]>,
}

impl Default for TraceRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_RING_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Self {
        let ring = (0..capacity.max(1)).map(|_| EventSlot::default()).collect();
        Self { recorded: AtomicU64::new(0), ring }
    }

    pub fn capacity(&self) -> usize {
        self.ring.len()
    }

    /// Exact events recorded so far (monotonic; never windows).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Acquire)
    }

    /// Record one event. Wait-free: one seqlock slot publish plus one
    /// counter store. Single producer — the engine's owning thread.
    pub fn record(&self, ev: &TraceEvent) {
        let n = self.recorded.load(Ordering::Relaxed);
        let slot = &self.ring[(n % self.ring.len() as u64) as usize];
        let s = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(s + 1, Ordering::Relaxed); // odd: write in progress
        fence(Ordering::Release);
        for (a, v) in slot.words.iter().zip(encode(ev)) {
            a.store(v, Ordering::Relaxed);
        }
        slot.seq.store(s + 2, Ordering::Release); // even: stable
        self.recorded.store(n + 1, Ordering::Release);
    }

    /// Snapshot every resident event in chronological order.
    pub fn dump(&self) -> TraceDump {
        self.dump_last(usize::MAX)
    }

    /// Snapshot the most recent `last` resident events in chronological
    /// order. `dropped` counts ring-wraparound losses exactly
    /// (`recorded − resident`), independent of `last`.
    pub fn dump_last(&self, last: usize) -> TraceDump {
        let recorded = self.recorded.load(Ordering::Acquire);
        let cap = self.ring.len() as u64;
        let resident = recorded.min(cap);
        let keep = resident.min(last as u64);
        let mut events = Vec::with_capacity(keep as usize);
        let mut torn = 0usize;
        for i in (recorded - keep)..recorded {
            let slot = &self.ring[(i % cap) as usize];
            let mut ok = false;
            for _ in 0..READ_RETRIES {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 % 2 == 1 {
                    continue; // mid-write
                }
                let mut w = [0u64; WORDS];
                for (dst, a) in w.iter_mut().zip(slot.words.iter()) {
                    *dst = a.load(Ordering::Relaxed);
                }
                fence(Ordering::Acquire);
                let s2 = slot.seq.load(Ordering::Relaxed);
                if s1 == s2 {
                    // An undecodable tag can only come from a torn or
                    // foreign slot; count it the same way.
                    if let Some(ev) = decode(&w) {
                        events.push(ev);
                    } else {
                        torn += 1;
                    }
                    ok = true;
                    break;
                }
            }
            if !ok {
                torn += 1;
            }
        }
        TraceDump { events, recorded, dropped: recorded - resident, torn }
    }
}

/// A reader's snapshot of the ring.
#[derive(Debug, Clone, Default)]
pub struct TraceDump {
    /// Resident events, oldest first.
    pub events: Vec<TraceEvent>,
    /// Exact events ever recorded (monotonic).
    pub recorded: u64,
    /// Events lost to ring wraparound: `recorded − resident`, exact.
    pub dropped: u64,
    /// Slots skipped because the writer lapped the reader mid-slot (always
    /// 0 for the deterministic offline dumps — the engine is quiescent).
    pub torn: usize,
}

// ---- exporters -----------------------------------------------------------

fn hex(v: u64) -> Json {
    Json::from(format!("{v:#018x}"))
}

fn id_or_null(id: u64) -> Json {
    if id == NO_ID {
        Json::Null
    } else {
        Json::from(id)
    }
}

/// Per-kind argument payload, shared by the Chrome exporter and the
/// server probe.
pub fn args_json(kind: &EventKind) -> Json {
    match kind {
        EventKind::Admit { id, prompt_len, max_new_tokens } => obj([
            ("id", Json::from(*id)),
            ("prompt_len", Json::from(*prompt_len)),
            ("max_new_tokens", Json::from(*max_new_tokens)),
        ]),
        EventKind::PrefixLookup { id, hit, blocks, tokens, fingerprint } => obj([
            ("id", Json::from(*id)),
            ("hit", Json::from(*hit)),
            ("blocks", Json::from(*blocks)),
            ("tokens", Json::from(*tokens)),
            ("layout_fingerprint", hex(*fingerprint)),
        ]),
        EventKind::PrefillChunk { id, tokens, t_pad, gather_by_rung, generated, dur_s } => obj([
            ("id", Json::from(*id)),
            ("tokens", Json::from(*tokens)),
            ("t_pad", Json::from(*t_pad)),
            ("gather_bytes_kv16", Json::from(gather_by_rung[0])),
            ("gather_bytes_kv8", Json::from(gather_by_rung[1])),
            ("gather_bytes_kv4", Json::from(gather_by_rung[2])),
            ("generated", Json::from(*generated)),
            ("dur_s", Json::from(*dur_s)),
        ]),
        EventKind::DecodeIter { batch, padded_slots, t_pad, generated, gather_by_rung, dur_s } => {
            obj([
                ("batch", Json::from(*batch)),
                ("padded_slots", Json::from(*padded_slots)),
                ("t_pad", Json::from(*t_pad)),
                ("generated", Json::from(*generated)),
                ("gather_bytes_kv16", Json::from(gather_by_rung[0])),
                ("gather_bytes_kv8", Json::from(gather_by_rung[1])),
                ("gather_bytes_kv4", Json::from(gather_by_rung[2])),
                ("dur_s", Json::from(*dur_s)),
            ])
        }
        EventKind::Preempt {
            victim,
            mechanism,
            chosen_cost_s,
            alt_cost_s,
            candidates,
            runner_up,
            runner_up_cost_s,
        } => obj([
            ("victim", id_or_null(*victim)),
            ("mechanism", Json::from(mechanism_name(*mechanism))),
            ("chosen_cost_s", Json::from(*chosen_cost_s)),
            ("alt_cost_s", Json::from(*alt_cost_s)),
            ("candidates", Json::from(*candidates)),
            ("runner_up", id_or_null(*runner_up)),
            ("runner_up_cost_s", Json::from(*runner_up_cost_s)),
        ]),
        EventKind::Ladder {
            rung_from,
            rung_to,
            bytes_by_rung,
            gained_blocks,
            dropped_tokens,
            to_fingerprint,
            dur_s,
        } => obj([
            ("rung_from", Json::from(RUNG_NAMES[(*rung_from as usize).min(2)])),
            ("rung_to", Json::from(RUNG_NAMES[(*rung_to as usize).min(2)])),
            ("bytes", Json::from(bytes_by_rung.iter().sum::<u64>())),
            ("bytes_kv16", Json::from(bytes_by_rung[0])),
            ("bytes_kv8", Json::from(bytes_by_rung[1])),
            ("bytes_kv4", Json::from(bytes_by_rung[2])),
            ("gained_blocks", Json::from(*gained_blocks)),
            ("dropped_tokens", Json::from(*dropped_tokens)),
            ("to_layout_fingerprint", hex(*to_fingerprint)),
            ("dur_s", Json::from(*dur_s)),
        ]),
        EventKind::SwapOut { id, bytes_by_rung, dur_s } => obj([
            ("id", Json::from(*id)),
            ("bytes", Json::from(bytes_by_rung.iter().sum::<u64>())),
            ("bytes_kv16", Json::from(bytes_by_rung[0])),
            ("bytes_kv8", Json::from(bytes_by_rung[1])),
            ("bytes_kv4", Json::from(bytes_by_rung[2])),
            ("dur_s", Json::from(*dur_s)),
        ]),
        EventKind::SwapIn { id, bytes_by_rung, dur_s }
        | EventKind::MigrateOut { id, bytes_by_rung, dur_s }
        | EventKind::MigrateIn { id, bytes_by_rung, dur_s }
        | EventKind::StoreWrite { id, bytes_by_rung, dur_s }
        | EventKind::StoreRead { id, bytes_by_rung, dur_s } => obj([
            ("id", Json::from(*id)),
            ("bytes", Json::from(bytes_by_rung.iter().sum::<u64>())),
            ("bytes_kv16", Json::from(bytes_by_rung[0])),
            ("bytes_kv8", Json::from(bytes_by_rung[1])),
            ("bytes_kv4", Json::from(bytes_by_rung[2])),
            ("dur_s", Json::from(*dur_s)),
        ]),
        EventKind::Finish { id, reason, tokens, latency_s } => obj([
            ("id", Json::from(*id)),
            ("reason", Json::from(finish_reason_name(*reason))),
            ("tokens", Json::from(*tokens)),
            ("latency_s", Json::from(*latency_s)),
        ]),
    }
}

/// A single event as probe JSON.
pub fn event_json(ev: &TraceEvent) -> Json {
    obj([
        ("kind", Json::from(ev.kind.name())),
        ("sim_time_s", Json::from(ev.sim_time_s)),
        ("args", args_json(&ev.kind)),
    ])
}

/// A ring snapshot as probe JSON (the `{"trace": N}` server answer).
pub fn dump_json(d: &TraceDump) -> Json {
    obj([
        ("recorded", Json::from(d.recorded)),
        ("dropped", Json::from(d.dropped)),
        ("torn", Json::from(d.torn)),
        ("events", arr(d.events.iter().map(event_json))),
    ])
}

/// One replica's track in a Chrome trace export.
pub struct TraceTrack<'a> {
    /// Chrome `tid`; one track per replica.
    pub tid: usize,
    /// Track label (the replica's identity string).
    pub label: String,
    pub dump: &'a TraceDump,
}

/// Per-request span aggregation used to derive the nested
/// request → phase async spans.
#[derive(Default)]
struct ReqAgg {
    admit: Option<f64>,
    first: Option<f64>,
    last: f64,
    prompt_len: u64,
    prefill_start: Option<f64>,
    prefill_end: Option<f64>,
    finish: Option<f64>,
}

fn chrome_event(
    ph: &str,
    name: &'static str,
    tid: usize,
    extra: impl IntoIterator<Item = (&'static str, Json)>,
) -> Json {
    let mut fields = vec![
        ("ph", Json::from(ph)),
        ("name", Json::from(name)),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(tid)),
    ];
    fields.extend(extra);
    obj(fields)
}

fn push_track(track: &TraceTrack, out: &mut Vec<Json>) {
    let tid = track.tid;
    out.push(chrome_event(
        "M",
        "thread_name",
        tid,
        [("args", obj([("name", Json::from(track.label.as_str()))]))],
    ));
    let mut aggs: BTreeMap<u64, ReqAgg> = BTreeMap::new();
    for ev in &track.dump.events {
        let ts = ev.sim_time_s;
        if let Some(id) = ev.kind.request_id() {
            let a = aggs.entry(id).or_default();
            a.first.get_or_insert(ts);
            a.last = a.last.max(ts + ev.kind.dur_s());
            match &ev.kind {
                EventKind::Admit { prompt_len, .. } => {
                    a.admit.get_or_insert(ts);
                    a.prompt_len = *prompt_len;
                }
                EventKind::PrefillChunk { dur_s, .. } => {
                    a.prefill_start.get_or_insert(ts);
                    let end = ts + dur_s;
                    a.prefill_end = Some(a.prefill_end.map_or(end, |e| e.max(end)));
                }
                EventKind::Finish { .. } => {
                    a.finish = Some(ts);
                }
                _ => {}
            }
        }
        let us = ts * 1e6;
        match &ev.kind {
            EventKind::PrefillChunk { dur_s, .. }
            | EventKind::DecodeIter { dur_s, .. }
            | EventKind::Ladder { dur_s, .. }
            | EventKind::SwapOut { dur_s, .. }
            | EventKind::SwapIn { dur_s, .. }
            | EventKind::MigrateOut { dur_s, .. }
            | EventKind::MigrateIn { dur_s, .. }
            | EventKind::StoreWrite { dur_s, .. }
            | EventKind::StoreRead { dur_s, .. } => {
                out.push(chrome_event(
                    "X",
                    ev.kind.name(),
                    tid,
                    [
                        ("ts", Json::from(us)),
                        ("dur", Json::from(dur_s * 1e6)),
                        ("args", args_json(&ev.kind)),
                    ],
                ));
            }
            EventKind::Admit { .. }
            | EventKind::PrefixLookup { .. }
            | EventKind::Preempt { .. }
            | EventKind::Finish { .. } => {
                out.push(chrome_event(
                    "i",
                    ev.kind.name(),
                    tid,
                    [
                        ("ts", Json::from(us)),
                        ("s", Json::from("t")),
                        ("args", args_json(&ev.kind)),
                    ],
                ));
            }
        }
    }
    // Nested async spans: request ⊃ prefill / decode, one id space per
    // track so replicas never collide. BTreeMap iteration keeps the
    // output deterministic.
    for (id, a) in &aggs {
        let (Some(start), end) = (a.admit.or(a.first), a.finish.unwrap_or(a.last)) else {
            continue;
        };
        let end = end.max(start);
        let span_id = format!("r{tid}.{id}");
        let span = |ph: &str, name: &'static str, ts: f64| {
            chrome_event(
                ph,
                name,
                tid,
                [
                    ("cat", Json::from("req")),
                    ("id", Json::from(span_id.as_str())),
                    ("ts", Json::from(ts * 1e6)),
                ],
            )
        };
        out.push(span("b", "request", start));
        if let (Some(ps), Some(pe)) = (a.prefill_start, a.prefill_end) {
            let ps = ps.clamp(start, end);
            let pe = pe.clamp(ps, end);
            out.push(span("b", "prefill", ps));
            out.push(span("e", "prefill", pe));
            if end > pe {
                out.push(span("b", "decode", pe));
                out.push(span("e", "decode", end));
            }
        }
        out.push(span("e", "request", end));
    }
}

/// Assemble a Perfetto-loadable Chrome trace-event document: one track
/// per replica, spans nested request → phase → iteration, timestamps in
/// microseconds of the modeled clock.
pub fn chrome_trace(tracks: &[TraceTrack]) -> Json {
    let mut events = Vec::new();
    for t in tracks {
        push_track(t, &mut events);
    }
    obj([
        ("displayTimeUnit", Json::from("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Schema lint for exported Chrome traces: every event has a known
/// phase, a non-empty name, numeric pid/tid; complete events carry
/// `ts` + non-negative `dur`; async begin/end events carry `cat` + `id`
/// and balance exactly per `(cat, id, name)`.
pub fn validate(doc: &Json) -> Result<()> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("trace: missing `traceEvents` array"))?;
    let mut balance: BTreeMap<(String, String, String), i64> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace event {i}: missing `ph`"))?;
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace event {i}: missing `name`"))?;
        if name.is_empty() {
            bail!("trace event {i}: empty `name`");
        }
        for key in ["pid", "tid"] {
            if ev.get(key).and_then(Json::as_f64).is_none() {
                bail!("trace event {i} ({name}): missing numeric `{key}`");
            }
        }
        let ts = ev.get("ts").and_then(Json::as_f64);
        match ph {
            "M" => {}
            "X" => {
                if ts.is_none() {
                    bail!("trace event {i} ({name}): X event missing `ts`");
                }
                match ev.get("dur").and_then(Json::as_f64) {
                    Some(d) if d >= 0.0 => {}
                    _ => bail!("trace event {i} ({name}): X event needs `dur` >= 0"),
                }
            }
            "i" => {
                if ts.is_none() {
                    bail!("trace event {i} ({name}): instant missing `ts`");
                }
            }
            "b" | "e" => {
                if ts.is_none() {
                    bail!("trace event {i} ({name}): async event missing `ts`");
                }
                let cat = ev
                    .get("cat")
                    .and_then(Json::as_str)
                    .filter(|c| !c.is_empty())
                    .ok_or_else(|| anyhow!("trace event {i} ({name}): async event needs `cat`"))?;
                let id = match ev.get("id") {
                    Some(Json::Str(s)) => s.clone(),
                    Some(Json::Num(n)) => format!("{n}"),
                    _ => bail!("trace event {i} ({name}): async event needs `id`"),
                };
                let k = (cat.to_string(), id, name.to_string());
                *balance.entry(k).or_insert(0) += if ph == "b" { 1 } else { -1 };
            }
            other => bail!("trace event {i} ({name}): unknown phase `{other}`"),
        }
    }
    for ((cat, id, name), v) in balance {
        if v != 0 {
            bail!("trace: unbalanced async span `{name}` (cat={cat}, id={id}): {v:+}");
        }
    }
    Ok(())
}

/// Export tracks to `path` as validated Chrome trace JSON; returns the
/// serialized document (byte-identical across runs of the same inputs).
pub fn write_chrome(path: &str, tracks: &[TraceTrack]) -> Result<String> {
    let doc = chrome_trace(tracks);
    validate(&doc).map_err(|e| anyhow!("refusing to write invalid trace: {e}"))?;
    let text = doc.dump();
    std::fs::write(path, &text)
        .map_err(|e| anyhow!("writing trace to {path}: {e}"))?;
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent {
                sim_time_s: 0.0,
                kind: EventKind::Admit { id: 0, prompt_len: 24, max_new_tokens: 8 },
            },
            TraceEvent {
                sim_time_s: 0.0,
                kind: EventKind::PrefixLookup {
                    id: 0,
                    hit: true,
                    blocks: 2,
                    tokens: 32,
                    fingerprint: 0xdead_beef_0123_4567,
                },
            },
            TraceEvent {
                sim_time_s: 0.0,
                kind: EventKind::PrefillChunk {
                    id: 0,
                    tokens: 24,
                    t_pad: 32,
                    gather_by_rung: [0, 4096, 0],
                    generated: 1,
                    dur_s: 1e-3,
                },
            },
            TraceEvent {
                sim_time_s: 1e-3,
                kind: EventKind::DecodeIter {
                    batch: 2,
                    padded_slots: 1,
                    t_pad: 64,
                    generated: 1,
                    gather_by_rung: [128, 256, 64],
                    dur_s: 2e-3,
                },
            },
            TraceEvent {
                sim_time_s: 3e-3,
                kind: EventKind::Preempt {
                    victim: 1,
                    mechanism: 0,
                    chosen_cost_s: 1e-4,
                    alt_cost_s: 3e-4,
                    candidates: 2,
                    runner_up: NO_ID,
                    runner_up_cost_s: 0.0,
                },
            },
            TraceEvent {
                sim_time_s: 3e-3,
                kind: EventKind::Ladder {
                    rung_from: 0,
                    rung_to: 1,
                    bytes_by_rung: [0, 8192, 0],
                    gained_blocks: 4,
                    dropped_tokens: 3,
                    to_fingerprint: 0x1122,
                    dur_s: 4e-6,
                },
            },
            TraceEvent {
                sim_time_s: 4e-3,
                kind: EventKind::SwapOut { id: 1, bytes_by_rung: [0, 2048, 0], dur_s: 1e-4 },
            },
            TraceEvent {
                sim_time_s: 5e-3,
                kind: EventKind::SwapIn { id: 1, bytes_by_rung: [0, 2048, 0], dur_s: 1e-4 },
            },
            TraceEvent {
                sim_time_s: 5.5e-3,
                kind: EventKind::MigrateOut { id: 1, bytes_by_rung: [0, 2048, 0], dur_s: 2e-4 },
            },
            TraceEvent {
                sim_time_s: 5.7e-3,
                kind: EventKind::MigrateIn { id: 1, bytes_by_rung: [0, 0, 1024], dur_s: 1e-4 },
            },
            TraceEvent {
                sim_time_s: 6e-3,
                kind: EventKind::Finish { id: 0, reason: 0, tokens: 8, latency_s: 6e-3 },
            },
        ]
    }

    #[test]
    fn encode_decode_round_trips_every_kind() {
        for ev in sample_events() {
            let w = encode(&ev);
            assert_eq!(decode(&w).as_ref(), Some(&ev), "{}", ev.kind.name());
        }
        assert!(decode(&[99, 0, 0, 0, 0, 0, 0, 0, 0, 0]).is_none(), "unknown tag rejected");
    }

    #[test]
    fn ring_windows_and_counts_drops_exactly() {
        let r = TraceRecorder::with_capacity(4);
        for i in 0..10u64 {
            r.record(&TraceEvent {
                sim_time_s: i as f64,
                kind: EventKind::Admit { id: i, prompt_len: 1, max_new_tokens: 1 },
            });
        }
        let d = r.dump();
        assert_eq!(d.recorded, 10);
        assert_eq!(d.dropped, 6, "wraparound drops counted exactly");
        assert_eq!(d.torn, 0);
        let ids: Vec<u64> = d
            .events
            .iter()
            .filter_map(|e| e.kind.request_id())
            .collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "window holds the most recent events in order");
        let last2 = r.dump_last(2);
        assert_eq!(last2.events.len(), 2);
        assert_eq!(last2.events[0].kind.request_id(), Some(8));
        assert_eq!(last2.dropped, 6, "dropped is wraparound loss, not the reader's cap");
    }

    #[test]
    fn concurrent_dumps_never_see_torn_events() {
        // Writer maintains an invariant (latency == 2 * sim_time); readers
        // must only ever observe intact events.
        let r = Arc::new(TraceRecorder::with_capacity(16));
        let w = Arc::clone(&r);
        let writer = thread::spawn(move || {
            for i in 1..=20_000u64 {
                let t = i as f64;
                w.record(&TraceEvent {
                    sim_time_s: t,
                    kind: EventKind::Finish { id: i, reason: 0, tokens: i, latency_s: 2.0 * t },
                });
            }
        });
        let mut readers = Vec::new();
        for _ in 0..3 {
            let rr = Arc::clone(&r);
            readers.push(thread::spawn(move || {
                for _ in 0..200 {
                    let d = rr.dump();
                    for ev in &d.events {
                        if let EventKind::Finish { id, tokens, latency_s, .. } = ev.kind {
                            assert_eq!(id, tokens, "torn event leaked");
                            assert_eq!(latency_s, 2.0 * ev.sim_time_s, "torn event leaked");
                        }
                    }
                }
            }));
        }
        writer.join().unwrap();
        for h in readers {
            h.join().unwrap();
        }
        let d = r.dump();
        assert_eq!(d.recorded, 20_000);
        assert_eq!(d.events.len(), 16);
        assert_eq!(d.torn, 0, "quiescent ring reads clean");
    }

    #[test]
    fn chrome_export_validates_and_nests_spans() {
        let r = TraceRecorder::with_capacity(64);
        for ev in sample_events() {
            r.record(&ev);
        }
        let d = r.dump();
        let tracks =
            [TraceTrack { tid: 0, label: "W4A16KV8@A100".into(), dump: &d }];
        let doc = chrome_trace(&tracks);
        validate(&doc).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Request 0's span: b/e request + b/e prefill + b/e decode.
        let spans: Vec<(&str, &str, f64)> = events
            .iter()
            .filter(|e| matches!(e.req_str("ph"), Ok("b") | Ok("e")))
            .map(|e| {
                (
                    e.req_str("ph").unwrap(),
                    e.req_str("name").unwrap(),
                    e.get("ts").unwrap().as_f64().unwrap(),
                )
            })
            .collect();
        let find = |ph: &str, name: &str| {
            spans
                .iter()
                .find(|(p, n, _)| *p == ph && *n == name)
                .map(|(_, _, t)| *t)
                .unwrap_or_else(|| panic!("missing span {ph} {name}"))
        };
        let (rb, re) = (find("b", "request"), find("e", "request"));
        let (pb, pe) = (find("b", "prefill"), find("e", "prefill"));
        let (db, de) = (find("b", "decode"), find("e", "decode"));
        assert!(rb <= pb && pb <= pe && pe <= de && de <= re, "nested, non-overlapping");
        assert_eq!(db, pe, "decode starts where prefill ends");
        // Determinism: exporting the same dump twice is byte-identical.
        assert_eq!(doc.dump(), chrome_trace(&tracks).dump());
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        assert!(validate(&Json::parse(r#"{"x": 1}"#).unwrap()).is_err(), "no traceEvents");
        let bad_phase = r#"{"traceEvents":[{"ph":"Q","name":"x","pid":1,"tid":0}]}"#;
        assert!(validate(&Json::parse(bad_phase).unwrap()).is_err());
        let no_dur = r#"{"traceEvents":[{"ph":"X","name":"x","pid":1,"tid":0,"ts":1}]}"#;
        assert!(validate(&Json::parse(no_dur).unwrap()).is_err());
        let no_cat =
            r#"{"traceEvents":[{"ph":"b","name":"x","pid":1,"tid":0,"ts":1,"id":"a"}]}"#;
        assert!(validate(&Json::parse(no_cat).unwrap()).is_err());
        let unbalanced = r#"{"traceEvents":[
            {"ph":"b","name":"x","pid":1,"tid":0,"ts":1,"cat":"req","id":"a"}]}"#;
        assert!(validate(&Json::parse(unbalanced).unwrap()).is_err());
        let ok = r#"{"traceEvents":[
            {"ph":"b","name":"x","pid":1,"tid":0,"ts":1,"cat":"req","id":"a"},
            {"ph":"e","name":"x","pid":1,"tid":0,"ts":2,"cat":"req","id":"a"}]}"#;
        validate(&Json::parse(ok).unwrap()).unwrap();
    }

    #[test]
    fn probe_json_carries_counts_and_events() {
        let r = TraceRecorder::with_capacity(4);
        for ev in sample_events() {
            r.record(&ev);
        }
        let j = dump_json(&r.dump_last(2));
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.req_usize("recorded").unwrap(), 11);
        assert_eq!(parsed.req_usize("dropped").unwrap(), 7);
        assert_eq!(parsed.req_arr("events").unwrap().len(), 2);
        let last = &parsed.req_arr("events").unwrap()[1];
        assert_eq!(last.req_str("kind").unwrap(), "finish");
        assert_eq!(last.get("args").unwrap().req_str("reason").unwrap(), "length");
    }
}

//! Precision-aware prefix-sharing index over the paged KV pool.
//!
//! A radix (trie) index keyed by the **chain hash** of full token blocks:
//! node key `k_i = H(k_{i-1}, tokens of block i)` with the root key derived
//! from the pool's [`KvPrecision`] and block size. A node maps one full
//! prompt block to the pool block id holding its quantized KV, so two
//! requests sharing a prefix at the *same* KV precision reuse the resident
//! blocks instead of re-prefilling them; KVmix-style mixed deployments
//! where precision varies per request can never cross-match because the
//! precision seeds the root of every chain.
//!
//! Lifecycle (see DESIGN.md §7):
//! * the engine **inserts** a sequence's completed full prompt blocks after
//!   each prefill chunk — each indexed block gains one pool reference
//!   ([`KvPool::retain_block`]), so it survives its sequence;
//! * admission **looks up** a new request's prompt and the engine seeds the
//!   sequence with the matched blocks ([`KvPool::adopt_blocks`]);
//! * when the free list runs dry, the engine **evicts** least-recently-used
//!   cached blocks that no sequence references ([`PrefixCache::evict_one`]),
//!   leaves before parents so every surviving chain stays matchable.
//!
//! Keys are 64-bit content hashes; a collision would alias two distinct
//! prefixes (the standard trade of hash-keyed prefix caches, cf. vLLM's
//! block hashing). The index never reads block *contents* — at a fixed
//! (seed, precision) the quantized codes are a pure function of the token
//! block and its position, which the chain hash pins.

use std::collections::{HashMap, HashSet};

use super::layout::KvLayout;
use super::pool::{KvPool, KvPrecision};

/// Effectiveness counters (exported through
/// [`crate::metrics::PrefixCacheSummary`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Admission lookups performed.
    pub lookups: usize,
    /// Lookups that matched at least one block.
    pub hits: usize,
    /// Prompt tokens served from resident blocks (prefill skipped).
    pub hit_tokens: usize,
    /// Blocks handed out to requests instead of being re-prefilled.
    pub blocks_shared: usize,
    /// Blocks registered into the index.
    pub inserted_blocks: usize,
    /// Cached blocks evicted back to the free list.
    pub evicted_blocks: usize,
    /// Cached blocks dropped because the pool laddered to a new layout
    /// (their keys belonged to the old precision's key space).
    pub invalidated_blocks: usize,
}

#[derive(Debug)]
struct Node {
    /// Pool block id holding this prefix block's quantized KV.
    block: usize,
    /// Chain key of the parent node (the root key for depth-0 nodes).
    parent: u64,
    /// Child nodes in the index (eviction runs leaves-first).
    children: usize,
    /// LRU clock stamp.
    last_used: u64,
}

/// The prefix index. One instance per pool — and therefore per layout.
#[derive(Debug)]
pub struct PrefixCache {
    layout: KvLayout,
    block_tokens: usize,
    /// Max blocks the index may pin (0 = bounded only by the pool).
    budget_blocks: usize,
    root: u64,
    nodes: HashMap<u64, Node>,
    clock: u64,
    pub stats: PrefixCacheStats,
}

/// Root key: seeds every chain with the full per-layer KV layout and the
/// block geometry, so indexes over pools that differ in *any* layer's
/// precision (kv16/kv8/kv4 uniform tiers included) can never alias each
/// other's entries.
pub(crate) fn layout_root_key(layout: &KvLayout, block_tokens: usize) -> u64 {
    layout.fingerprint().wrapping_add((block_tokens as u64).rotate_left(32))
}

/// Uniform-precision convenience wrapper over [`layout_root_key`].
pub(crate) fn root_key(precision: KvPrecision, block_tokens: usize) -> u64 {
    layout_root_key(&KvLayout::uniform(precision, 1), block_tokens)
}

/// FNV-style chain hash of one token block on top of its prefix's key.
fn chain_key(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = prev ^ 0x9E37_79B9_7F4A_7C15;
    for &t in tokens {
        h = (h ^ (t as u32 as u64)).wrapping_mul(0x0100_0000_01B3);
        h = h.rotate_left(17);
    }
    h
}

/// The chain keys of the first `max_blocks` full token blocks of `prompt`
/// under `root` — the exact key sequence [`PrefixCache::lookup`] walks.
/// The host-global store (`store::resolve_shared_prefix`) uses this to
/// probe and publish under the same key space as the per-replica index, so
/// a block published by one replica resolves on every other.
pub(crate) fn chain_keys_under(
    root: u64,
    prompt: &[i32],
    block_tokens: usize,
    max_blocks: usize,
) -> Vec<u64> {
    let mut keys = Vec::new();
    let mut prev = root;
    for chunk in prompt.chunks_exact(block_tokens) {
        if keys.len() >= max_blocks {
            break;
        }
        prev = chain_key(prev, chunk);
        keys.push(prev);
    }
    keys
}

/// Precision-agnostic routing key over the first `max_blocks` full token
/// blocks of `prompt` — the same chain-hash scheme the index uses, rooted
/// at a fixed routing constant instead of a precision seed. The cluster's
/// `prefix_affinity` policy hashes prompts with this so requests sharing a
/// prompt prefix land on the same replica (whose own index then matches
/// them under *its* precision-seeded chains). Prompts shorter than one
/// block hash their raw tokens, so tiny prompts still spread by content.
///
/// `max_blocks` trades group- against session-affinity: a cap no longer
/// than the fleet's common shared prefix keeps whole tenant groups
/// together; once a session's history exceeds the cap, its growing prompts
/// keep hashing the same leading blocks and stay sticky. The flip side: a
/// session whose *initial* prompt has fewer full blocks than the cap
/// hashes a deeper key as it grows, re-placing by first touch — so size
/// the cap to the workload's stable shared prefix, not above it.
pub fn route_key(prompt: &[i32], block_tokens: usize, max_blocks: usize) -> u64 {
    let mut key = 0x5EED_2007_EC4A_FF1Du64 ^ (block_tokens as u64).rotate_left(32);
    let mut blocks = 0usize;
    for chunk in prompt.chunks_exact(block_tokens) {
        if blocks >= max_blocks.max(1) {
            return key;
        }
        key = chain_key(key, chunk);
        blocks += 1;
    }
    if blocks == 0 {
        key = chain_key(key, prompt);
    }
    key
}

impl PrefixCache {
    /// Uniform-precision index (the pre-`KvLayout` constructor).
    pub fn new(precision: KvPrecision, block_tokens: usize, budget_blocks: usize) -> Self {
        Self::with_layout(KvLayout::uniform(precision, 1), block_tokens, budget_blocks)
    }

    /// Index over a pool with a per-layer precision layout; the root key is
    /// a hash of the full layout, so chains from different layouts never
    /// alias.
    pub fn with_layout(layout: KvLayout, block_tokens: usize, budget_blocks: usize) -> Self {
        let root = layout_root_key(&layout, block_tokens);
        Self {
            layout,
            block_tokens,
            budget_blocks,
            root,
            nodes: HashMap::new(),
            clock: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    /// The layout this index's keys are seeded with.
    pub fn layout(&self) -> &KvLayout {
        &self.layout
    }

    /// Layer-0 precision of the index's layout (uniform-layout callers).
    pub fn precision(&self) -> KvPrecision {
        self.layout.prec(0)
    }

    /// The pool laddered every resident block to `layout`: every cached
    /// entry's key belongs to the *old* layout's key space, so the whole
    /// index is invalidated — nodes are dropped, their pool pins released —
    /// and the root is re-seeded from the new layout. Returns the number of
    /// invalidated blocks. (Blocks re-enter the index organically as
    /// admission-time prefills at the new layout index them; a stale-layout
    /// hit is impossible because lookups walk from the new root.)
    pub fn invalidate_for_relayout(&mut self, pool: &mut KvPool, layout: KvLayout) -> usize {
        let dropped = self.nodes.len();
        for (_, n) in self.nodes.drain() {
            pool.release_block(n.block);
        }
        self.stats.invalidated_blocks += dropped;
        self.root = layout_root_key(&layout, self.block_tokens);
        self.layout = layout;
        dropped
    }

    /// Blocks currently pinned by the index.
    pub fn cached_blocks(&self) -> usize {
        self.nodes.len()
    }

    /// Matched prefix length for `prompt` without touching LRU state or
    /// stats (admission feasibility checks run every scheduler iteration).
    /// At most `max_tokens` tokens match, in whole blocks.
    pub fn peek_hit_tokens(&self, prompt: &[i32], max_tokens: usize) -> usize {
        let mut key = self.root;
        let mut tokens = 0usize;
        for chunk in prompt.chunks_exact(self.block_tokens) {
            if tokens + self.block_tokens > max_tokens {
                break;
            }
            key = chain_key(key, chunk);
            if !self.nodes.contains_key(&key) {
                break;
            }
            tokens += self.block_tokens;
        }
        tokens
    }

    /// Match `prompt`'s longest indexed full-block prefix (≤ `max_tokens`
    /// tokens): returns the matched token count and the resident pool block
    /// ids, in order. Bumps LRU stamps and records stats — call once per
    /// admission; the caller adopts the blocks via [`KvPool::adopt_blocks`].
    pub fn lookup(&mut self, prompt: &[i32], max_tokens: usize) -> (usize, Vec<usize>) {
        self.stats.lookups += 1;
        let mut key = self.root;
        let mut tokens = 0usize;
        let mut blocks = Vec::new();
        for chunk in prompt.chunks_exact(self.block_tokens) {
            if tokens + self.block_tokens > max_tokens {
                break;
            }
            key = chain_key(key, chunk);
            let Some(n) = self.nodes.get_mut(&key) else { break };
            self.clock += 1;
            n.last_used = self.clock;
            blocks.push(n.block);
            tokens += self.block_tokens;
        }
        if tokens > 0 {
            self.stats.hits += 1;
            self.stats.hit_tokens += tokens;
            self.stats.blocks_shared += blocks.len();
        }
        (tokens, blocks)
    }

    /// Register `prompt`'s full blocks (backed by `blocks`, one pool block
    /// id per full block, in order) into the index. Already-indexed
    /// prefixes just get their LRU stamps refreshed; new nodes retain their
    /// pool block. Inserting stops early if the budget is full and nothing
    /// is evictable.
    pub fn insert(&mut self, pool: &mut KvPool, prompt: &[i32], blocks: &[usize]) {
        let mut key = self.root;
        for (i, chunk) in prompt.chunks_exact(self.block_tokens).enumerate() {
            if i >= blocks.len() {
                break;
            }
            let parent = key;
            key = chain_key(key, chunk);
            if let Some(n) = self.nodes.get_mut(&key) {
                // Prefix already cached (possibly backed by another
                // sequence's block) — keep the first mapping, refresh LRU.
                self.clock += 1;
                n.last_used = self.clock;
                continue;
            }
            if self.budget_blocks > 0 && self.nodes.len() >= self.budget_blocks {
                // Make room within the budget; if every cached block is in
                // use, stop indexing this chain (deeper nodes would be
                // unreachable anyway).
                if !self.evict_one(pool) {
                    break;
                }
            }
            pool.retain_block(blocks[i]);
            self.clock += 1;
            self.nodes.insert(
                key,
                Node { block: blocks[i], parent, children: 0, last_used: self.clock },
            );
            if parent != self.root {
                if let Some(p) = self.nodes.get_mut(&parent) {
                    p.children += 1;
                }
            }
            self.stats.inserted_blocks += 1;
        }
    }

    /// Cached blocks that could be reclaimed by (possibly repeated)
    /// [`PrefixCache::evict_one`] calls right now: nodes whose block no
    /// sequence references and whose subtree holds no in-use block either.
    /// The engine adds this to the free-block count when deciding
    /// admissibility.
    pub fn evictable_blocks(&self, pool: &KvPool) -> usize {
        let mut pinned: HashSet<u64> = HashSet::new();
        for (&key, node) in &self.nodes {
            if pool.block_ref_count(node.block) > 1 {
                // In use by a sequence: pin this node and all ancestors.
                let mut cur = key;
                while pinned.insert(cur) {
                    match self.nodes.get(&cur) {
                        Some(n) if n.parent != self.root => cur = n.parent,
                        _ => break,
                    }
                }
            }
        }
        self.nodes.len() - pinned.len()
    }

    /// Evict the least-recently-used unreferenced **leaf** back to the
    /// pool's free list. Returns false when nothing is evictable (every
    /// cached block is owned by a live sequence or shields one). Leaves go
    /// first so every surviving chain remains matchable from the root.
    pub fn evict_one(&mut self, pool: &mut KvPool) -> bool {
        let victim = self
            .nodes
            .iter()
            .filter(|(_, n)| n.children == 0 && pool.block_ref_count(n.block) == 1)
            .min_by_key(|(_, n)| n.last_used)
            .map(|(&k, _)| k);
        let Some(k) = victim else { return false };
        let n = self.nodes.remove(&k).expect("victim exists");
        if n.parent != self.root {
            if let Some(p) = self.nodes.get_mut(&n.parent) {
                p.children -= 1;
            }
        }
        pool.release_block(n.block);
        self.stats.evicted_blocks += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BT: usize = 4;

    /// 1-layer, 1-head, head_dim-4 pool with 4-token blocks.
    fn pool(blocks: usize) -> KvPool {
        KvPool::new(KvPrecision::Int8, 1, 1, 4, BT, blocks * BT).unwrap()
    }

    /// Append `prompt` into a fresh sequence; returns its full-block ids.
    fn fill(p: &mut KvPool, prompt: &[i32]) -> (crate::kvcache::SeqHandle, Vec<usize>) {
        let h = p.alloc_seq();
        for &t in prompt {
            let k = vec![t as u8; 4];
            let s = vec![1.0f32];
            p.append_token(h, &k, &s, &k, &s).unwrap();
        }
        let full = prompt.len() / BT;
        (h, p.seq_blocks(h)[..full].to_vec())
    }

    fn prompt(n: usize, tag: i32) -> Vec<i32> {
        (0..n as i32).map(|i| tag * 1000 + i).collect()
    }

    #[test]
    fn insert_then_lookup_matches_whole_blocks() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(KvPrecision::Int8, BT, 0);
        let pr = prompt(12, 1); // 3 full blocks
        let (_h, blocks) = fill(&mut p, &pr);
        c.insert(&mut p, &pr, &blocks);
        assert_eq!(c.cached_blocks(), 3);

        let (tokens, got) = c.lookup(&pr, usize::MAX);
        assert_eq!(tokens, 12);
        assert_eq!(got, blocks);

        // Diverging in the last block matches only the first two.
        let mut pr2 = pr.clone();
        pr2[10] = -7;
        let (tokens, got) = c.lookup(&pr2, usize::MAX);
        assert_eq!(tokens, 8);
        assert_eq!(got, blocks[..2]);

        // Shorter than one block: no match, counted as a miss.
        let (tokens, got) = c.lookup(&pr[..3], usize::MAX);
        assert_eq!((tokens, got.len()), (0, 0));
        assert_eq!(c.stats.lookups, 3);
        assert_eq!(c.stats.hits, 2);
        assert_eq!(c.stats.hit_tokens, 20);
    }

    #[test]
    fn lookup_respects_max_tokens_cap() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(KvPrecision::Int8, BT, 0);
        let pr = prompt(16, 2);
        let (_h, blocks) = fill(&mut p, &pr);
        c.insert(&mut p, &pr, &blocks);
        // Cap below one block → nothing; cap mid-block → whole blocks only.
        assert_eq!(c.peek_hit_tokens(&pr, 3), 0);
        assert_eq!(c.peek_hit_tokens(&pr, 9), 8);
        let (tokens, got) = c.lookup(&pr, 9);
        assert_eq!(tokens, 8);
        assert_eq!(got.len(), 2);
    }

    #[test]
    fn peek_is_pure() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(KvPrecision::Int8, BT, 0);
        let pr = prompt(8, 3);
        let (_h, blocks) = fill(&mut p, &pr);
        c.insert(&mut p, &pr, &blocks);
        let stats_before = c.stats;
        assert_eq!(c.peek_hit_tokens(&pr, usize::MAX), 8);
        assert_eq!(c.stats, stats_before, "peek must not touch stats");
    }

    #[test]
    fn precision_and_geometry_seed_distinct_key_spaces() {
        // kv16/kv8/kv4 chains can never alias: the precision seeds the
        // root, so the same token block hashes to different keys.
        let roots = [
            root_key(KvPrecision::F32, BT),
            root_key(KvPrecision::Int8, BT),
            root_key(KvPrecision::Int4, BT),
            root_key(KvPrecision::Int8, 2 * BT),
        ];
        for i in 0..roots.len() {
            for j in i + 1..roots.len() {
                assert_ne!(roots[i], roots[j], "roots {i} and {j} collide");
            }
        }
        let toks = prompt(BT, 4);
        assert_ne!(
            chain_key(root_key(KvPrecision::Int8, BT), &toks),
            chain_key(root_key(KvPrecision::Int4, BT), &toks),
            "same tokens at different KV precisions must never match"
        );
    }

    #[test]
    fn cached_blocks_survive_their_sequence_and_evict_lru() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(KvPrecision::Int8, BT, 0);
        let pr_a = prompt(8, 5);
        let (ha, blocks_a) = fill(&mut p, &pr_a);
        c.insert(&mut p, &pr_a, &blocks_a);
        let pr_b = prompt(8, 6);
        let (hb, blocks_b) = fill(&mut p, &pr_b);
        c.insert(&mut p, &pr_b, &blocks_b);

        p.free_seq(ha);
        p.free_seq(hb);
        assert_eq!(p.used_blocks(), 4, "index keeps all 4 blocks resident");
        assert_eq!(c.evictable_blocks(&p), 4);

        // Touch chain A so B becomes the LRU chain; evictions then take
        // B's leaf, then B's root, then A's leaf, then A's root.
        let (tokens, _) = c.lookup(&pr_a, usize::MAX);
        assert_eq!(tokens, 8);
        assert!(c.evict_one(&mut p));
        assert!(c.evict_one(&mut p));
        assert_eq!(c.cached_blocks(), 2);
        assert_eq!(c.lookup(&pr_b, usize::MAX).0, 0, "B fully evicted");
        assert_eq!(c.lookup(&pr_a, usize::MAX).0, 8, "A untouched");
        assert!(c.evict_one(&mut p));
        assert!(c.evict_one(&mut p));
        assert!(!c.evict_one(&mut p), "index empty");
        assert_eq!(p.free_blocks(), p.total_blocks());
        assert_eq!(c.stats.evicted_blocks, 4);
    }

    #[test]
    fn in_use_blocks_are_never_evicted_and_pin_ancestors() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(KvPrecision::Int8, BT, 0);
        let pr = prompt(12, 7); // blocks: b0 → b1 → b2
        let (h, blocks) = fill(&mut p, &pr);
        c.insert(&mut p, &pr, &blocks);
        p.free_seq(h);

        // A second sequence adopts the first two blocks: b0, b1 in use.
        let h2 = p.alloc_seq();
        p.adopt_blocks(h2, &blocks[..2], 8).unwrap();
        assert_eq!(c.evictable_blocks(&p), 1, "only the b2 leaf is free to go");
        assert!(c.evict_one(&mut p), "evicts b2");
        assert!(!c.evict_one(&mut p), "b0/b1 are in use");
        assert_eq!(c.cached_blocks(), 2);

        p.free_seq(h2);
        assert_eq!(c.evictable_blocks(&p), 2);
        assert!(c.evict_one(&mut p) && c.evict_one(&mut p));
        assert_eq!(p.free_blocks(), p.total_blocks());
    }

    #[test]
    fn route_key_groups_shared_prefixes_and_caps_depth() {
        let shared = prompt(2 * BT, 1); // two full shared blocks
        let mut a = shared.clone();
        a.extend(prompt(BT, 2));
        let mut b = shared.clone();
        b.extend(prompt(BT, 3));
        // Capped at the shared depth: both sessions hash identically.
        assert_eq!(route_key(&a, BT, 2), route_key(&b, BT, 2));
        // Uncapped, they diverge in block 3.
        assert_ne!(route_key(&a, BT, 8), route_key(&b, BT, 8));
        // A session's growing prompt keeps its key once past the cap.
        let mut a_next = a.clone();
        a_next.extend(prompt(3 * BT, 4));
        assert_eq!(route_key(&a, BT, 2), route_key(&a_next, BT, 2));
        // Different leading blocks → different keys.
        assert_ne!(route_key(&shared, BT, 4), route_key(&prompt(2 * BT, 9), BT, 4));
        // Sub-block prompts hash their raw tokens instead of colliding.
        assert_ne!(route_key(&[1, 2], BT, 4), route_key(&[3, 4], BT, 4));
        // Trailing partial blocks are ignored past the first full block.
        let mut c = shared.clone();
        c.push(77);
        assert_eq!(route_key(&c, BT, 8), route_key(&shared, BT, 8));
    }

    #[test]
    fn relayout_invalidates_instead_of_serving_stale_precision() {
        let mut p = pool(8); // uniform kv8, 1 layer
        let mut c = PrefixCache::with_layout(p.layout().clone(), BT, 0);
        let pr = prompt(8, 11);
        let (h, blocks) = fill(&mut p, &pr);
        c.insert(&mut p, &pr, &blocks);
        p.free_seq(h);
        assert_eq!(c.lookup(&pr, usize::MAX).0, 8, "shared prefix resident");

        // Ladder the shared prefix down pool-wide: kv8 → kv4.
        let target = KvLayout::uniform(KvPrecision::Int4, 1);
        let dropped = c.invalidate_for_relayout(&mut p, target.clone());
        p.relayout(&target).unwrap();
        assert_eq!(dropped, 2);
        assert_eq!(c.stats.invalidated_blocks, 2);
        assert_eq!(c.cached_blocks(), 0);
        // Never a stale hit: old chains cannot match under the new root.
        assert_eq!(c.lookup(&pr, usize::MAX).0, 0);
        assert_eq!(c.peek_hit_tokens(&pr, usize::MAX), 0);
        // The index released its pins; nothing leaks.
        assert_eq!(p.free_blocks(), p.total_blocks());
        assert_eq!(c.layout(), &target);
    }

    #[test]
    fn layout_roots_diverge_on_any_layer() {
        let a = KvLayout::parse("l0:kv8,l1:kv8", 2).unwrap();
        let b = KvLayout::parse("l0:kv8,l1:kv4", 2).unwrap();
        assert_ne!(layout_root_key(&a, BT), layout_root_key(&b, BT));
        assert_ne!(layout_root_key(&a, BT), layout_root_key(&a, 2 * BT));
        let toks = prompt(BT, 12);
        assert_ne!(
            chain_key(layout_root_key(&a, BT), &toks),
            chain_key(layout_root_key(&b, BT), &toks),
            "same tokens under different layouts must never match"
        );
    }

    #[test]
    fn budget_caps_the_index() {
        let mut p = pool(16);
        let mut c = PrefixCache::new(KvPrecision::Int8, BT, 2);
        let pr_a = prompt(12, 8); // wants 3 nodes, budget is 2
        let (ha, blocks_a) = fill(&mut p, &pr_a);
        c.insert(&mut p, &pr_a, &blocks_a);
        assert_eq!(c.cached_blocks(), 2, "third block skipped: nothing evictable");

        // Once A's sequence is gone, a new chain displaces the old one.
        p.free_seq(ha);
        let pr_b = prompt(12, 9);
        let (_hb, blocks_b) = fill(&mut p, &pr_b);
        c.insert(&mut p, &pr_b, &blocks_b);
        assert_eq!(c.cached_blocks(), 2);
        assert!(c.stats.evicted_blocks >= 1);
        assert_eq!(c.lookup(&pr_b, usize::MAX).0, 8);
    }
}

//! Host-side KV swap tier: where preempted sequences' quantized blocks
//! live while the device pool is oversubscribed (DESIGN.md §8, §14).
//!
//! The tier is a [`SwapBackend`] with two implementations:
//!
//! * [`SwapStore`] — the original in-memory store: byte-exact
//!   [`SeqSnapshot`]s keyed by request id, budget in pool blocks mirroring
//!   a pinned-host-memory allocation. Fast, RAM-bounded, dies with the
//!   process.
//! * [`PagedSwapStore`] — the same contract backed by a
//!   [`PageFileStore`](crate::store::PageFileStore) page file: snapshots
//!   persist across restarts, capacity is disk-bounded, and every read
//!   re-validates checksums (corruption fails closed instead of feeding
//!   garbage KV).
//!
//! Because snapshots carry the pool's *quantized* codes, swap traffic
//! scales with [`KvPrecision::row_bytes`] — a kv4 sequence ships ~4× fewer
//! bytes than the same sequence at kv16, which is exactly why the victim
//! cost model ([`crate::coordinator::preempt`]) prices low-precision
//! victims cheaper.
//!
//! Transfers are modeled, not executed: [`transfer_time_s`] converts a
//! payload size into PCIe time that the engine accumulates in
//! `EngineStats::sim_time_s`, and the paged tier adds a
//! [`disk_transfer_time_s`] term on the same modeled clock (NVMe-class
//! bandwidth with a deeper latency floor).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::pool::SeqSnapshot;
use crate::store::PageFileStore;

/// Modeled host↔device interconnect bandwidth, bytes/second (PCIe 4.0 x16
/// effective ≈ 25 GB/s; we model the conservative end).
pub const PCIE_BANDWIDTH_BPS: f64 = 16.0e9;
/// Fixed per-transfer latency (DMA setup + driver), seconds.
pub const PCIE_LATENCY_S: f64 = 10.0e-6;

/// Modeled disk-tier bandwidth, bytes/second (NVMe-class sequential ≈
/// 6 GB/s).
pub const DISK_BANDWIDTH_BPS: f64 = 6.0e9;
/// Fixed per-operation disk latency (submission + flash), seconds.
pub const DISK_LATENCY_S: f64 = 80.0e-6;

/// Modeled one-way transfer time for `bytes` over the host link.
pub fn transfer_time_s(bytes: usize) -> f64 {
    PCIE_LATENCY_S + bytes as f64 / PCIE_BANDWIDTH_BPS
}

/// Modeled one-way disk time for `bytes` — the extra term a paged-backend
/// swap pays on top of the PCIe hop.
pub fn disk_transfer_time_s(bytes: usize) -> f64 {
    DISK_LATENCY_S + bytes as f64 / DISK_BANDWIDTH_BPS
}

/// Total transfer payload of one snapshot: quantized codes plus the f32
/// scale rows — exactly the bytes the engine charges to `sim_time_s` per
/// transfer (and attributes per rung in trace events).
pub fn snapshot_bytes(snap: &SeqSnapshot) -> usize {
    snap.code_bytes() + snap.scales.len() * 4
}

/// Lifetime counters (exported through
/// [`crate::metrics::PreemptionSummary`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SwapStats {
    /// Sequences swapped out to the host.
    pub swap_outs: usize,
    /// Sequences swapped back into the pool.
    pub swap_ins: usize,
    /// Pool blocks shipped host-ward (cumulative).
    pub swapped_out_blocks: usize,
    /// Pool blocks restored device-ward (cumulative).
    pub swapped_in_blocks: usize,
    /// Snapshots discarded without a swap-in (victim downgraded to
    /// recompute because the pool could not take the restore, or its
    /// request ended while parked).
    pub dropped: usize,
    /// High-water mark of resident host blocks.
    pub peak_blocks: usize,
}

/// The swap-tier contract the engine programs against. Backends differ in
/// where parked bytes live (RAM vs page file) and what a transfer costs on
/// the modeled clock; the preemption state machine is backend-agnostic.
pub trait SwapBackend: std::fmt::Debug + Send {
    /// Park a victim's snapshot under its request id. Errors if the id is
    /// already swapped or capacity cannot take it (the caller should have
    /// checked [`SwapBackend::can_hold`] and fallen back to recompute).
    fn insert(&mut self, id: u64, snap: SeqSnapshot) -> Result<()>;

    /// Remove and return a snapshot for swap-in. Counts as a swap-in.
    /// `Err` is the fail-closed path: the parked bytes exist but cannot be
    /// trusted (paged backend checksum mismatch) — never silently `None`.
    fn take(&mut self, id: u64) -> Result<Option<SeqSnapshot>>;

    /// Remove and return a snapshot for *migration* (replica drain): the
    /// payload leaves the store but is neither a swap-in nor a drop, so
    /// only residency accounting moves. Keeping [`SwapStats`] untouched
    /// preserves the engine invariant that swap counters reconcile with
    /// preemption counters even across a drain.
    fn evacuate(&mut self, id: u64) -> Result<Option<SeqSnapshot>>;

    /// Discard a snapshot without restoring it (the victim was downgraded
    /// to recompute, or its request ended while parked).
    fn drop_entry(&mut self, id: u64) -> bool;

    /// Is this request currently swapped out?
    fn contains(&self, id: u64) -> bool;

    /// KV tokens parked for `id` (0 when not swapped).
    fn tokens_of(&self, id: u64) -> usize;

    /// Would a `tokens`-token snapshot fit the remaining capacity?
    fn can_hold(&self, tokens: usize) -> bool;

    /// Host blocks currently resident.
    fn used_blocks(&self) -> usize;

    /// Max resident blocks (0 = unbounded).
    fn budget_blocks(&self) -> usize;

    /// Swapped-out sequences currently resident.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fraction of the budget in use, or `None` when the budget is
    /// unbounded — there is no denominator to report against. Callers
    /// must not coerce `None` to 0: an unbounded store with resident
    /// blocks is under real host pressure, and the old fake-zero answer
    /// hid it from the stats JSON. Pair with
    /// [`used_blocks`](SwapBackend::used_blocks), meaningful always.
    fn utilization(&self) -> Option<f64> {
        (self.budget_blocks() > 0)
            .then(|| self.used_blocks() as f64 / self.budget_blocks() as f64)
    }

    /// Lifetime counters.
    fn stats(&self) -> SwapStats;

    /// Whether transfers through this backend also cross the disk tier
    /// (the engine adds [`disk_transfer_time_s`] and emits
    /// `StoreWrite`/`StoreRead` events when true).
    fn disk_tier(&self) -> bool {
        false
    }

    /// The shared page-file store, when this backend is disk-backed.
    fn store(&self) -> Option<&Arc<PageFileStore>> {
        None
    }
}

/// The in-memory backend. One per engine; budget in pool-sized blocks.
#[derive(Debug, Default)]
pub struct SwapStore {
    /// Max resident blocks (0 = unbounded).
    budget_blocks: usize,
    /// Pool block size in tokens (for sizing snapshots in blocks).
    block_tokens: usize,
    used_blocks: usize,
    entries: HashMap<u64, (SeqSnapshot, usize)>,
    pub stats: SwapStats,
}

impl SwapStore {
    pub fn new(block_tokens: usize, budget_blocks: usize) -> Self {
        Self { budget_blocks, block_tokens, ..Self::default() }
    }

    fn blocks_of(&self, snap: &SeqSnapshot) -> usize {
        snap.len.div_ceil(self.block_tokens.max(1))
    }
}

impl SwapBackend for SwapStore {
    fn insert(&mut self, id: u64, snap: SeqSnapshot) -> Result<()> {
        if self.entries.contains_key(&id) {
            return Err(anyhow!("request {id} is already swapped out"));
        }
        let blocks = self.blocks_of(&snap);
        if self.budget_blocks > 0 && self.used_blocks + blocks > self.budget_blocks {
            return Err(anyhow!(
                "swap budget full ({} + {blocks} > {} blocks)",
                self.used_blocks,
                self.budget_blocks
            ));
        }
        self.used_blocks += blocks;
        self.stats.swap_outs += 1;
        self.stats.swapped_out_blocks += blocks;
        self.stats.peak_blocks = self.stats.peak_blocks.max(self.used_blocks);
        self.entries.insert(id, (snap, blocks));
        Ok(())
    }

    fn take(&mut self, id: u64) -> Result<Option<SeqSnapshot>> {
        let Some((snap, blocks)) = self.entries.remove(&id) else { return Ok(None) };
        self.used_blocks -= blocks;
        self.stats.swap_ins += 1;
        self.stats.swapped_in_blocks += blocks;
        Ok(Some(snap))
    }

    fn evacuate(&mut self, id: u64) -> Result<Option<SeqSnapshot>> {
        let Some((snap, blocks)) = self.entries.remove(&id) else { return Ok(None) };
        self.used_blocks -= blocks;
        Ok(Some(snap))
    }

    fn drop_entry(&mut self, id: u64) -> bool {
        match self.entries.remove(&id) {
            Some((_, blocks)) => {
                self.used_blocks -= blocks;
                self.stats.dropped += 1;
                true
            }
            None => false,
        }
    }

    fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    fn tokens_of(&self, id: u64) -> usize {
        self.entries.get(&id).map(|(s, _)| s.len).unwrap_or(0)
    }

    fn can_hold(&self, tokens: usize) -> bool {
        self.budget_blocks == 0
            || self.used_blocks + tokens.div_ceil(self.block_tokens.max(1)) <= self.budget_blocks
    }

    fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    fn budget_blocks(&self) -> usize {
        self.budget_blocks
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn stats(&self) -> SwapStats {
        self.stats
    }
}

/// The page-file-backed backend: same contract, parked bytes live in the
/// shared [`PageFileStore`] under this engine's namespace. Blocks-based
/// budget still applies (it models pinned staging memory); on top of it
/// the store's own page capacity backpressures through
/// [`SwapBackend::can_hold`].
#[derive(Debug)]
pub struct PagedSwapStore {
    store: Arc<PageFileStore>,
    /// Snapshot namespace in the shared store (one per engine, so replicas
    /// sharing a file never collide on request ids).
    ns: u64,
    block_tokens: usize,
    budget_blocks: usize,
    used_blocks: usize,
    /// id → blocks charged at insert (sizing must not require disk reads).
    entries: HashMap<u64, usize>,
    stats: SwapStats,
    /// Upper-bound wire bytes per token for sizing `can_hold` probes,
    /// taken from the pool layout at construction. The ladder only ever
    /// narrows precision, so the construction-time layout bounds every
    /// later snapshot.
    bytes_per_token_hint: usize,
}

impl PagedSwapStore {
    pub fn new(
        store: Arc<PageFileStore>,
        block_tokens: usize,
        budget_blocks: usize,
        bytes_per_token_hint: usize,
    ) -> Self {
        let ns = store.alloc_namespace();
        Self {
            store,
            ns,
            block_tokens,
            budget_blocks,
            used_blocks: 0,
            entries: HashMap::new(),
            stats: SwapStats::default(),
            bytes_per_token_hint,
        }
    }

    /// This backend's snapshot namespace in the shared store.
    pub fn namespace(&self) -> u64 {
        self.ns
    }

    fn blocks_of(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens.max(1))
    }
}

impl SwapBackend for PagedSwapStore {
    fn insert(&mut self, id: u64, snap: SeqSnapshot) -> Result<()> {
        if self.entries.contains_key(&id) {
            return Err(anyhow!("request {id} is already swapped out"));
        }
        let blocks = self.blocks_of(snap.len);
        if self.budget_blocks > 0 && self.used_blocks + blocks > self.budget_blocks {
            return Err(anyhow!(
                "swap budget full ({} + {blocks} > {} blocks)",
                self.used_blocks,
                self.budget_blocks
            ));
        }
        self.store.put_snapshot(self.ns, id, &snap)?;
        self.used_blocks += blocks;
        self.stats.swap_outs += 1;
        self.stats.swapped_out_blocks += blocks;
        self.stats.peak_blocks = self.stats.peak_blocks.max(self.used_blocks);
        self.entries.insert(id, blocks);
        Ok(())
    }

    fn take(&mut self, id: u64) -> Result<Option<SeqSnapshot>> {
        let Some(blocks) = self.entries.remove(&id) else { return Ok(None) };
        self.used_blocks -= blocks;
        // Fail closed: a checksum mismatch surfaces as Err with the entry
        // already released — the bytes are untrusted either way.
        let got = self.store.get_snapshot(self.ns, id)?;
        let Some((snap, _)) = got else {
            return Err(anyhow!("swapped request {id} missing from the page file"));
        };
        self.store.delete_snapshot(self.ns, id)?;
        self.stats.swap_ins += 1;
        self.stats.swapped_in_blocks += blocks;
        Ok(Some(snap))
    }

    fn evacuate(&mut self, id: u64) -> Result<Option<SeqSnapshot>> {
        let Some(blocks) = self.entries.remove(&id) else { return Ok(None) };
        self.used_blocks -= blocks;
        let got = self.store.get_snapshot(self.ns, id)?;
        let Some((snap, _)) = got else {
            return Err(anyhow!("swapped request {id} missing from the page file"));
        };
        self.store.delete_snapshot(self.ns, id)?;
        Ok(Some(snap))
    }

    fn drop_entry(&mut self, id: u64) -> bool {
        match self.entries.remove(&id) {
            Some(blocks) => {
                self.used_blocks -= blocks;
                // Best-effort page free; the entry is gone either way.
                let _ = self.store.delete_snapshot(self.ns, id);
                self.stats.dropped += 1;
                true
            }
            None => false,
        }
    }

    fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    fn tokens_of(&self, id: u64) -> usize {
        if !self.entries.contains_key(&id) {
            return 0;
        }
        self.store.snapshot_tokens(self.ns, id).unwrap_or(0)
    }

    fn can_hold(&self, tokens: usize) -> bool {
        let within_budget = self.budget_blocks == 0
            || self.used_blocks + self.blocks_of(tokens) <= self.budget_blocks;
        within_budget
            && self.store.has_room(self.store.pages_for(tokens * self.bytes_per_token_hint))
    }

    fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    fn budget_blocks(&self) -> usize {
        self.budget_blocks
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn stats(&self) -> SwapStats {
        self.stats
    }

    fn disk_tier(&self) -> bool {
        true
    }

    fn store(&self) -> Option<&Arc<PageFileStore>> {
        Some(&self.store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::StoreConfig;

    fn snap(tokens: usize) -> SeqSnapshot {
        // 1 layer × 1 head × head_dim 3 at Int8: 2 × 1 × 3 = 6 code bytes
        // and 2 scales per token.
        SeqSnapshot {
            len: tokens,
            codes: vec![0xAB; tokens * 6],
            scales: vec![1.0; tokens * 2],
            kv_heads: 1,
            head_dim: 3,
            layout: crate::kvcache::layout::KvLayout::uniform(
                crate::kvcache::pool::KvPrecision::Int8,
                1,
            ),
        }
    }

    #[test]
    fn budget_accounting_balances() {
        let mut s = SwapStore::new(4, 4); // 4-token blocks, 4-block budget
        assert!(s.can_hold(16));
        s.insert(1, snap(9)).unwrap(); // 3 blocks
        assert_eq!(s.used_blocks(), 3);
        assert_eq!(s.utilization(), Some(0.75));
        assert!(s.can_hold(4));
        assert!(!s.can_hold(5), "two blocks would overflow");
        assert!(s.insert(2, snap(8)).is_err(), "budget enforced");
        assert!(s.insert(1, snap(1)).is_err(), "double swap-out rejected");

        let got = s.take(1).unwrap().unwrap();
        assert_eq!(got, snap(9), "snapshot returned intact");
        assert_eq!(s.used_blocks(), 0);
        assert!(s.is_empty());
        assert_eq!(s.stats.swap_outs, 1);
        assert_eq!(s.stats.swap_ins, 1);
        assert_eq!(s.stats.swapped_out_blocks, 3);
        assert_eq!(s.stats.swapped_in_blocks, 3);
        assert_eq!(s.stats.peak_blocks, 3);
    }

    #[test]
    fn unbounded_budget_and_drop_path() {
        let mut s = SwapStore::new(4, 0);
        assert!(s.can_hold(usize::MAX / 8), "0 = unbounded");
        s.insert(7, snap(12)).unwrap();
        assert_eq!(s.tokens_of(7), 12);
        assert!(s.contains(7));
        assert_eq!(s.utilization(), None, "no budget → no fake 0 utilization");
        assert_eq!(s.used_blocks(), 3, "…but used blocks always report");
        assert!(s.drop_entry(7));
        assert!(!s.drop_entry(7));
        assert!(s.take(7).unwrap().is_none());
        assert_eq!(s.stats.dropped, 1);
        assert_eq!(s.used_blocks(), 0);
    }

    #[test]
    fn evacuate_moves_blocks_without_touching_stats() {
        let mut s = SwapStore::new(4, 8);
        s.insert(3, snap(9)).unwrap(); // 3 blocks
        let before = s.stats;
        let got = s.evacuate(3).unwrap().expect("entry present");
        assert_eq!(got, snap(9), "payload intact for migration");
        assert_eq!(s.used_blocks(), 0, "residency released");
        assert!(s.evacuate(3).unwrap().is_none(), "gone after evacuation");
        // Neither a swap-in nor a drop: lifetime counters unchanged.
        assert_eq!(s.stats, before, "drain must not perturb swap stats");
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let t1 = transfer_time_s(1 << 20);
        let t4 = transfer_time_s(4 << 20);
        assert!(t4 > t1);
        // Latency floor dominates tiny transfers.
        assert!(transfer_time_s(0) >= PCIE_LATENCY_S);
        // 16 MB at 16 GB/s ≈ 1 ms.
        let t = transfer_time_s(16 << 20);
        assert!((0.9e-3..1.2e-3).contains(&t), "{t}");
        // The disk hop is strictly slower than the PCIe hop.
        assert!(disk_transfer_time_s(16 << 20) > t);
        assert!(disk_transfer_time_s(0) >= DISK_LATENCY_S);
    }

    fn paged(name: &str, budget_blocks: usize, max_pages: usize) -> PagedSwapStore {
        let dir = std::env::temp_dir().join(format!("tmkv-swap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        let store =
            crate::store::PageFileStore::open(StoreConfig::with_geometry(path, 512, max_pages))
                .unwrap();
        // snap() wire bytes/token: 6 code + 2×4 scale = 14.
        PagedSwapStore::new(store, 4, budget_blocks, 14)
    }

    #[test]
    fn paged_backend_honours_the_swap_contract() {
        let mut s = paged("contract.pages", 4, 0);
        assert!(s.disk_tier());
        s.insert(1, snap(9)).unwrap();
        assert!(s.contains(1));
        assert_eq!(s.tokens_of(1), 9);
        assert_eq!(s.used_blocks(), 3);
        assert!(s.insert(1, snap(1)).is_err(), "double swap-out rejected");
        assert!(!s.can_hold(8), "blocks budget still applies on disk");
        let got = s.take(1).unwrap().unwrap();
        assert_eq!(got, snap(9), "round-trips byte-exactly through the page file");
        assert!(s.is_empty());
        assert_eq!(s.store().unwrap().stats().snapshots, 0, "pages freed after swap-in");
        let st = s.stats();
        assert_eq!((st.swap_outs, st.swap_ins, st.swapped_out_blocks), (1, 1, 3));
        // Drop path frees pages without a swap-in.
        s.insert(2, snap(4)).unwrap();
        assert!(s.drop_entry(2));
        assert_eq!(s.stats().dropped, 1);
        assert_eq!(s.store().unwrap().stats().snapshots, 0);
    }

    #[test]
    fn paged_backend_backpressures_on_page_capacity() {
        // 2 record pages total; each snap(4) record fits in one page.
        let mut s = paged("capacity.pages", 0, 2);
        assert!(s.can_hold(4));
        s.insert(1, snap(4)).unwrap();
        s.insert(2, snap(4)).unwrap();
        assert!(!s.can_hold(4), "page capacity backpressures can_hold");
        assert!(s.insert(3, snap(4)).is_err(), "store full propagates");
        assert!(!s.contains(3));
        s.take(1).unwrap().unwrap();
        assert!(s.can_hold(4), "freed pages reopen capacity");
    }
}

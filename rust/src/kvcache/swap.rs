//! Host-side KV swap store: where preempted sequences' quantized blocks
//! live while the device pool is oversubscribed (DESIGN.md §8).
//!
//! The store holds byte-exact [`SeqSnapshot`]s keyed by request id, with a
//! budget in pool blocks mirroring a pinned-host-memory allocation. Because
//! snapshots carry the pool's *quantized* codes, swap traffic scales with
//! [`KvPrecision::row_bytes`] — a kv4 sequence ships ~4× fewer bytes than
//! the same sequence at kv16, which is exactly why the victim cost model
//! ([`crate::coordinator::preempt`]) prices low-precision victims cheaper.
//!
//! Transfers are modeled, not executed: [`transfer_time_s`] converts a
//! payload size into PCIe time that the engine accumulates in
//! `EngineStats::sim_time_s`, the same bookkeeping the sim backend uses for
//! device iterations.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::pool::SeqSnapshot;

/// Modeled host↔device interconnect bandwidth, bytes/second (PCIe 4.0 x16
/// effective ≈ 25 GB/s; we model the conservative end).
pub const PCIE_BANDWIDTH_BPS: f64 = 16.0e9;
/// Fixed per-transfer latency (DMA setup + driver), seconds.
pub const PCIE_LATENCY_S: f64 = 10.0e-6;

/// Modeled one-way transfer time for `bytes` over the host link.
pub fn transfer_time_s(bytes: usize) -> f64 {
    PCIE_LATENCY_S + bytes as f64 / PCIE_BANDWIDTH_BPS
}

/// Total PCIe payload of one snapshot: quantized codes plus the f32 scale
/// rows — exactly the bytes the engine charges to `sim_time_s` per
/// transfer (and attributes per rung in trace events).
pub fn snapshot_bytes(snap: &SeqSnapshot) -> usize {
    snap.code_bytes() + snap.scales.len() * 4
}

/// Lifetime counters (exported through
/// [`crate::metrics::PreemptionSummary`]).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SwapStats {
    /// Sequences swapped out to the host.
    pub swap_outs: usize,
    /// Sequences swapped back into the pool.
    pub swap_ins: usize,
    /// Pool blocks shipped host-ward (cumulative).
    pub swapped_out_blocks: usize,
    /// Pool blocks restored device-ward (cumulative).
    pub swapped_in_blocks: usize,
    /// Snapshots discarded without a swap-in (victim downgraded to
    /// recompute because the pool could not take the restore).
    pub dropped: usize,
    /// High-water mark of resident host blocks.
    pub peak_blocks: usize,
}

/// The store. One per engine; budget in pool-sized blocks.
#[derive(Debug, Default)]
pub struct SwapStore {
    /// Max resident blocks (0 = unbounded).
    budget_blocks: usize,
    /// Pool block size in tokens (for sizing snapshots in blocks).
    block_tokens: usize,
    used_blocks: usize,
    entries: HashMap<u64, (SeqSnapshot, usize)>,
    pub stats: SwapStats,
}

impl SwapStore {
    pub fn new(block_tokens: usize, budget_blocks: usize) -> Self {
        Self { budget_blocks, block_tokens, ..Self::default() }
    }

    pub fn budget_blocks(&self) -> usize {
        self.budget_blocks
    }

    /// Host blocks currently resident.
    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    /// Swapped-out sequences currently resident.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fraction of the budget in use, or `None` when the budget is
    /// unbounded — there is no denominator to report against. Callers
    /// must not coerce `None` to 0: an unbounded store with resident
    /// blocks is under real host pressure, and the old fake-zero answer
    /// hid it from the stats JSON. Pair with
    /// [`used_blocks`](Self::used_blocks), which is meaningful always.
    pub fn utilization(&self) -> Option<f64> {
        (self.budget_blocks > 0).then(|| self.used_blocks as f64 / self.budget_blocks as f64)
    }

    fn blocks_of(&self, snap: &SeqSnapshot) -> usize {
        snap.len.div_ceil(self.block_tokens.max(1))
    }

    /// Would a `tokens`-token snapshot fit the remaining budget?
    pub fn can_hold(&self, tokens: usize) -> bool {
        self.budget_blocks == 0
            || self.used_blocks + tokens.div_ceil(self.block_tokens.max(1)) <= self.budget_blocks
    }

    /// Park a victim's snapshot under its request id. Errors if the id is
    /// already swapped or the budget cannot take it (the caller should
    /// have checked [`SwapStore::can_hold`] and fallen back to recompute).
    pub fn insert(&mut self, id: u64, snap: SeqSnapshot) -> Result<()> {
        if self.entries.contains_key(&id) {
            return Err(anyhow!("request {id} is already swapped out"));
        }
        let blocks = self.blocks_of(&snap);
        if self.budget_blocks > 0 && self.used_blocks + blocks > self.budget_blocks {
            return Err(anyhow!(
                "swap budget full ({} + {blocks} > {} blocks)",
                self.used_blocks,
                self.budget_blocks
            ));
        }
        self.used_blocks += blocks;
        self.stats.swap_outs += 1;
        self.stats.swapped_out_blocks += blocks;
        self.stats.peak_blocks = self.stats.peak_blocks.max(self.used_blocks);
        self.entries.insert(id, (snap, blocks));
        Ok(())
    }

    /// Is this request currently swapped out?
    pub fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// KV tokens parked for `id` (0 when not swapped).
    pub fn tokens_of(&self, id: u64) -> usize {
        self.entries.get(&id).map(|(s, _)| s.len).unwrap_or(0)
    }

    /// Remove and return a snapshot for swap-in. Counts as a swap-in.
    pub fn take(&mut self, id: u64) -> Option<SeqSnapshot> {
        let (snap, blocks) = self.entries.remove(&id)?;
        self.used_blocks -= blocks;
        self.stats.swap_ins += 1;
        self.stats.swapped_in_blocks += blocks;
        Some(snap)
    }

    /// Remove and return a snapshot for *migration* (replica drain): the
    /// payload leaves the store but is neither a swap-in nor a drop, so
    /// only the residency accounting moves. Keeping [`SwapStats`] untouched
    /// preserves the engine invariant that swap counters reconcile with
    /// preemption counters even across a drain.
    pub fn evacuate(&mut self, id: u64) -> Option<SeqSnapshot> {
        let (snap, blocks) = self.entries.remove(&id)?;
        self.used_blocks -= blocks;
        Some(snap)
    }

    /// Discard a snapshot without restoring it (the victim was downgraded
    /// to recompute).
    pub fn drop_entry(&mut self, id: u64) -> bool {
        match self.entries.remove(&id) {
            Some((_, blocks)) => {
                self.used_blocks -= blocks;
                self.stats.dropped += 1;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(tokens: usize) -> SeqSnapshot {
        // 1 layer × 1 head × head_dim 3 at Int8: 2 × 1 × 3 = 6 code bytes
        // and 2 scales per token.
        SeqSnapshot {
            len: tokens,
            codes: vec![0xAB; tokens * 6],
            scales: vec![1.0; tokens * 2],
            kv_heads: 1,
            head_dim: 3,
            layout: crate::kvcache::layout::KvLayout::uniform(
                crate::kvcache::pool::KvPrecision::Int8,
                1,
            ),
        }
    }

    #[test]
    fn budget_accounting_balances() {
        let mut s = SwapStore::new(4, 4); // 4-token blocks, 4-block budget
        assert!(s.can_hold(16));
        s.insert(1, snap(9)).unwrap(); // 3 blocks
        assert_eq!(s.used_blocks(), 3);
        assert_eq!(s.utilization(), Some(0.75));
        assert!(s.can_hold(4));
        assert!(!s.can_hold(5), "two blocks would overflow");
        assert!(s.insert(2, snap(8)).is_err(), "budget enforced");
        assert!(s.insert(1, snap(1)).is_err(), "double swap-out rejected");

        let got = s.take(1).unwrap();
        assert_eq!(got, snap(9), "snapshot returned intact");
        assert_eq!(s.used_blocks(), 0);
        assert!(s.is_empty());
        assert_eq!(s.stats.swap_outs, 1);
        assert_eq!(s.stats.swap_ins, 1);
        assert_eq!(s.stats.swapped_out_blocks, 3);
        assert_eq!(s.stats.swapped_in_blocks, 3);
        assert_eq!(s.stats.peak_blocks, 3);
    }

    #[test]
    fn unbounded_budget_and_drop_path() {
        let mut s = SwapStore::new(4, 0);
        assert!(s.can_hold(usize::MAX / 8), "0 = unbounded");
        s.insert(7, snap(12)).unwrap();
        assert_eq!(s.tokens_of(7), 12);
        assert!(s.contains(7));
        assert_eq!(s.utilization(), None, "no budget → no fake 0 utilization");
        assert_eq!(s.used_blocks(), 3, "…but used blocks always report");
        assert!(s.drop_entry(7));
        assert!(!s.drop_entry(7));
        assert!(s.take(7).is_none());
        assert_eq!(s.stats.dropped, 1);
        assert_eq!(s.used_blocks(), 0);
    }

    #[test]
    fn evacuate_moves_blocks_without_touching_stats() {
        let mut s = SwapStore::new(4, 8);
        s.insert(3, snap(9)).unwrap(); // 3 blocks
        let before = s.stats;
        let got = s.evacuate(3).expect("entry present");
        assert_eq!(got, snap(9), "payload intact for migration");
        assert_eq!(s.used_blocks(), 0, "residency released");
        assert!(s.evacuate(3).is_none(), "gone after evacuation");
        // Neither a swap-in nor a drop: lifetime counters unchanged.
        assert_eq!(s.stats, before, "drain must not perturb swap stats");
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let t1 = transfer_time_s(1 << 20);
        let t4 = transfer_time_s(4 << 20);
        assert!(t4 > t1);
        // Latency floor dominates tiny transfers.
        assert!(transfer_time_s(0) >= PCIE_LATENCY_S);
        // 16 MB at 16 GB/s ≈ 1 ms.
        let t = transfer_time_s(16 << 20);
        assert!((0.9e-3..1.2e-3).contains(&t), "{t}");
    }
}

//! Paged, *quantized* KV-cache pool — the Rust-owned memory the paper's
//! attention pipeline reads through (§3.4).
//!
//! Layout: fixed-size blocks of `block_tokens` tokens; each token slot holds
//! the codes + scales for **all layers, both K and V, all KV heads** (so one
//! append touches one block). Sequences own ordered block lists (block
//! tables, vLLM-style). Codes are stored exactly as the AOT graphs emit
//! them — the pool never re-quantizes — and gathered into the padded
//! `[L, B, Hkv, T, …]` batch tensors the decode graphs consume.
//!
//! Blocks are ref-counted so they can be **shared across sequences**: the
//! [`prefix`] module keeps a precision-keyed radix index of full prompt
//! blocks over the pool, giving copy-on-write prefix reuse (shared system
//! prompts, multi-turn histories) with LRU eviction of unreferenced cached
//! blocks when the free list runs dry.

pub mod layout;
pub mod pool;
pub mod prefix;
pub mod swap;

pub use layout::KvLayout;
pub use pool::{KvPool, KvPrecision, RelayoutReport, SeqHandle, SeqSnapshot};
pub use prefix::{route_key, PrefixCache, PrefixCacheStats};
pub use swap::{PagedSwapStore, SwapBackend, SwapStats, SwapStore};

//! Paged, *quantized* KV-cache pool — the Rust-owned memory the paper's
//! attention pipeline reads through (§3.4).
//!
//! Layout: fixed-size blocks of `block_tokens` tokens; each token slot holds
//! the codes + scales for **all layers, both K and V, all KV heads** (so one
//! append touches one block). Sequences own ordered block lists (block
//! tables, vLLM-style). Codes are stored exactly as the AOT graphs emit
//! them — the pool never re-quantizes — and gathered into the padded
//! `[L, B, Hkv, T, …]` batch tensors the decode graphs consume.

pub mod pool;

pub use pool::{KvPool, KvPrecision, SeqHandle};

//! Per-layer KV precision layout.
//!
//! The paged pool historically stored one [`KvPrecision`] for every layer.
//! `KvLayout` generalizes that to a per-layer precision vector — the KVmix
//! /SFMP-style mixed-precision assignment — and owns the geometry that used
//! to be derived from the scalar: per-layer `row_bytes`, the layer-offset
//! table inside a token slot, and `token_code_bytes` summed over layers.
//!
//! Precisions are ordered on a one-way ladder `kv16 → kv8 → kv4`; the
//! preemption ladder rung only ever moves layers *down* (transcodable in
//! place, see `quant::transcode`), never up.

use anyhow::{bail, Result};

use super::pool::KvPrecision;
use crate::config::DType;

/// Per-layer KV precision assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvLayout {
    precs: Vec<KvPrecision>,
}

impl KvLayout {
    /// The classic single-precision pool: every layer at `prec`.
    pub fn uniform(prec: KvPrecision, n_layers: usize) -> Self {
        Self { precs: vec![prec; n_layers] }
    }

    /// Uniform layout from a serving dtype (`kv16`/`kv8`/`kv4` tiers).
    pub fn from_dtype(dt: DType, n_layers: usize) -> Result<Self> {
        Ok(Self::uniform(KvPrecision::from_dtype(dt)?, n_layers))
    }

    /// Layout from an explicit per-layer precision list (the store codec's
    /// decode path; the list length is the layer count).
    pub fn from_precs(precs: Vec<KvPrecision>) -> Result<Self> {
        if precs.is_empty() {
            bail!("kv layout needs at least one layer");
        }
        Ok(Self { precs })
    }

    /// Parse a CLI/config layout spec. Accepted forms:
    ///
    /// * `kv8` — uniform across all layers;
    /// * `l0:kv16,l1:kv8,l2:kv4,l3:kv4` — explicit per-layer list covering
    ///   every layer exactly once. `;` is accepted as a separator alongside
    ///   `,` (cluster replica specs already use `,` between their own
    ///   fields).
    pub fn parse(spec: &str, n_layers: usize) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() {
            bail!("empty kv layout spec");
        }
        if !spec.contains(':') {
            return Ok(Self::uniform(KvPrecision::parse_key(spec)?, n_layers));
        }
        let mut precs: Vec<Option<KvPrecision>> = vec![None; n_layers];
        for part in spec.split([',', ';']).map(str::trim).filter(|p| !p.is_empty()) {
            let Some((layer, key)) = part.split_once(':') else {
                bail!("kv layout entry `{part}` is not of the form lN:kvX");
            };
            let Some(idx) = layer.trim().strip_prefix('l') else {
                bail!("kv layout entry `{part}` must name a layer as lN");
            };
            let idx: usize = idx.parse().map_err(|_| {
                anyhow::anyhow!("kv layout entry `{part}` has a non-numeric layer index")
            })?;
            if idx >= n_layers {
                bail!("kv layout names layer l{idx} but the model has {n_layers} layers");
            }
            if precs[idx].is_some() {
                bail!("kv layout assigns layer l{idx} twice");
            }
            precs[idx] = Some(KvPrecision::parse_key(key.trim())?);
        }
        let mut out = Vec::with_capacity(n_layers);
        for (l, p) in precs.into_iter().enumerate() {
            match p {
                Some(p) => out.push(p),
                None => bail!("kv layout leaves layer l{l} unassigned ({n_layers} layers total)"),
            }
        }
        Ok(Self { precs: out })
    }

    pub fn n_layers(&self) -> usize {
        self.precs.len()
    }

    pub fn prec(&self, layer: usize) -> KvPrecision {
        self.precs[layer]
    }

    pub fn precs(&self) -> &[KvPrecision] {
        &self.precs
    }

    /// `Some(prec)` when every layer shares one precision.
    pub fn as_uniform(&self) -> Option<KvPrecision> {
        let first = *self.precs.first()?;
        self.precs.iter().all(|&p| p == first).then_some(first)
    }

    /// Bytes per KV row (one head, one token) at layer `l`.
    pub fn row_bytes(&self, layer: usize, head_dim: usize) -> usize {
        self.precs[layer].row_bytes(head_dim)
    }

    /// Sum of row bytes across layers — the per-layer-heterogeneous
    /// replacement for `n_layers * row_bytes`.
    pub fn sum_row_bytes(&self, head_dim: usize) -> usize {
        self.precs.iter().map(|p| p.row_bytes(head_dim)).sum()
    }

    /// Sum of row bytes of layers *before* `l` — the layer-offset table for
    /// any layer-major tensor: multiply by the caller's per-row context
    /// factor (`2 × Hkv` for a pool token slot, `B × Hkv × T` for a gather
    /// buffer, …) to get the byte offset of layer `l`.
    pub fn prefix_row_bytes(&self, layer: usize, head_dim: usize) -> usize {
        self.precs[..layer].iter().map(|p| p.row_bytes(head_dim)).sum()
    }

    /// Bytes of code storage per pool token slot: `Σ_l 2 × Hkv × rb_l`.
    pub fn token_code_bytes(&self, kv_heads: usize, head_dim: usize) -> usize {
        2 * kv_heads * self.sum_row_bytes(head_dim)
    }

    /// Bytes per full pool block at this layout.
    pub fn bytes_per_block(&self, kv_heads: usize, head_dim: usize, block_tokens: usize) -> usize {
        block_tokens * self.token_code_bytes(kv_heads, head_dim)
    }

    /// Per-rung layer occupancy histogram, indexed by
    /// [`KvPrecision::ladder_rank`] (`[kv16, kv8, kv4]` layer counts) —
    /// the resident-precision view `metrics::TelemetrySummary` reports.
    pub fn rung_histogram(&self) -> [usize; 3] {
        let mut h = [0usize; 3];
        for p in &self.precs {
            h[p.ladder_rank() as usize] += 1;
        }
        h
    }

    /// Order-sensitive hash of the full per-layer assignment — the prefix
    /// index seeds its root key from this, so two layouts that differ in
    /// any single layer's precision hash to disjoint key spaces.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xC0FF_EE00_D15E_A5E5u64 ^ (self.precs.len() as u64);
        for &p in &self.precs {
            let tag = match p {
                KvPrecision::F32 => 16u64,
                KvPrecision::Int8 => 8,
                KvPrecision::Int4 => 4,
            };
            h = (h.rotate_left(7) ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
                .wrapping_mul(0x0100_0000_01B3);
        }
        h
    }

    /// True when `target` is reachable from `self` by only moving layers
    /// down the ladder (every layer same-or-lower precision).
    pub fn can_transcode_to(&self, target: &KvLayout) -> bool {
        self.precs.len() == target.precs.len()
            && self
                .precs
                .iter()
                .zip(&target.precs)
                .all(|(a, b)| b.ladder_rank() >= a.ladder_rank())
    }

    /// Any layer left to downgrade?
    pub fn can_ladder(&self) -> bool {
        self.precs.iter().any(|p| p.next_down().is_some())
    }

    /// One ladder step: downgrade the least-important still-downgradable
    /// layer by one notch (ties break toward the highest layer index — the
    /// default importance profile already ladders late layers first).
    /// Returns the new layout and `(layer, from, to)`.
    pub fn ladder_step(
        &self,
        importance: &[f64],
    ) -> Option<(KvLayout, usize, KvPrecision, KvPrecision)> {
        let mut pick: Option<(usize, f64)> = None;
        for (l, p) in self.precs.iter().enumerate() {
            if p.next_down().is_none() {
                continue;
            }
            let imp = importance.get(l).copied().unwrap_or(1.0);
            match pick {
                Some((_, best)) if imp > best => {}
                Some((bl, best)) if imp == best && l < bl => {}
                _ => pick = Some((l, imp)),
            }
        }
        let (layer, _) = pick?;
        let from = self.precs[layer];
        let to = from.next_down().expect("picked a downgradable layer");
        let mut next = self.clone();
        next.precs[layer] = to;
        Some((next, layer, from, to))
    }
}

impl std::fmt::Display for KvLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if let Some(p) = self.as_uniform() {
            return write!(f, "{}", p.graph_key());
        }
        for (l, p) in self.precs.iter().enumerate() {
            if l > 0 {
                write!(f, ",")?;
            }
            write!(f, "l{l}:{}", p.graph_key())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_parse_and_display_roundtrip() {
        let l = KvLayout::parse("kv8", 4).unwrap();
        assert_eq!(l, KvLayout::uniform(KvPrecision::Int8, 4));
        assert_eq!(l.to_string(), "kv8");
        assert_eq!(l.as_uniform(), Some(KvPrecision::Int8));
    }

    #[test]
    fn per_layer_parse_and_display_roundtrip() {
        let spec = "l0:kv16,l1:kv8,l2:kv4,l3:kv8";
        let l = KvLayout::parse(spec, 4).unwrap();
        assert_eq!(l.prec(0), KvPrecision::F32);
        assert_eq!(l.prec(2), KvPrecision::Int4);
        assert_eq!(l.to_string(), spec);
        // Semicolons work too (cluster replica specs reserve the comma).
        assert_eq!(KvLayout::parse("l0:kv16;l1:kv8;l2:kv4;l3:kv8", 4).unwrap(), l);
        assert_eq!(l.as_uniform(), None);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(KvLayout::parse("", 2).is_err());
        assert!(KvLayout::parse("kv9", 2).is_err());
        assert!(KvLayout::parse("l0:kv8", 2).is_err(), "layer l1 unassigned");
        assert!(KvLayout::parse("l0:kv8,l0:kv4,l1:kv8", 2).is_err(), "duplicate");
        assert!(KvLayout::parse("l2:kv8,l0:kv8,l1:kv8", 2).is_err(), "out of range");
        assert!(KvLayout::parse("x0:kv8,l1:kv8", 2).is_err());
    }

    #[test]
    fn geometry_sums_per_layer_rows() {
        // head_dim 8: kv16 row 32B, kv8 row 8B, kv4 row 4B.
        let l = KvLayout::parse("l0:kv16,l1:kv8,l2:kv4", 3).unwrap();
        assert_eq!(l.sum_row_bytes(8), 32 + 8 + 4);
        assert_eq!(l.prefix_row_bytes(0, 8), 0);
        assert_eq!(l.prefix_row_bytes(1, 8), 32);
        assert_eq!(l.prefix_row_bytes(2, 8), 40);
        assert_eq!(l.token_code_bytes(2, 8), 2 * 2 * 44);
        assert_eq!(l.bytes_per_block(2, 8, 4), 4 * 2 * 2 * 44);
    }

    #[test]
    fn fingerprints_are_layer_order_sensitive() {
        let a = KvLayout::parse("l0:kv16,l1:kv8", 2).unwrap();
        let b = KvLayout::parse("l0:kv8,l1:kv16", 2).unwrap();
        let c = KvLayout::uniform(KvPrecision::Int8, 2);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(b.fingerprint(), c.fingerprint());
        assert_eq!(a.fingerprint(), KvLayout::parse("l0:kv16,l1:kv8", 2).unwrap().fingerprint());
        // Layer count matters even when the uniform precision matches.
        assert_ne!(
            KvLayout::uniform(KvPrecision::Int8, 2).fingerprint(),
            KvLayout::uniform(KvPrecision::Int8, 3).fingerprint()
        );
    }

    #[test]
    fn ladder_steps_walk_importance_order_to_exhaustion() {
        let mut l = KvLayout::uniform(KvPrecision::F32, 3);
        // Default profile: later layers less important.
        let imp = [1.0, 0.66, 0.33];
        let mut seen = vec![];
        while let Some((next, layer, from, to)) = l.ladder_step(&imp) {
            assert!(l.can_transcode_to(&next));
            assert_eq!(from.next_down(), Some(to));
            seen.push(layer);
            l = next;
        }
        // Layer 2 all the way down first, then 1, then 0.
        assert_eq!(seen, vec![2, 2, 1, 1, 0, 0]);
        assert!(!l.can_ladder());
        assert_eq!(l.as_uniform(), Some(KvPrecision::Int4));
    }

    #[test]
    fn transcode_reachability_is_one_way() {
        let hi = KvLayout::parse("l0:kv16,l1:kv8", 2).unwrap();
        let lo = KvLayout::parse("l0:kv8,l1:kv4", 2).unwrap();
        assert!(hi.can_transcode_to(&lo));
        assert!(hi.can_transcode_to(&hi), "identity is reachable");
        assert!(!lo.can_transcode_to(&hi), "no up-laddering");
        assert!(!hi.can_transcode_to(&KvLayout::uniform(KvPrecision::Int4, 3)), "layer count");
    }
}

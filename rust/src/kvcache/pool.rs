//! The paged KV block pool.

use anyhow::{anyhow, bail, Result};

use super::layout::KvLayout;
use crate::config::DType;
use crate::quant::transcode::{f32_row_to_int4, f32_row_to_int8, int8_row_to_int4};

/// Storage precision of the pool (mirrors the serving `KVz` format).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPrecision {
    /// f32 rows (the paper's KV16 tier; f32 is the CPU stand-in).
    F32,
    /// int8 codes + per-(token, head) scale.
    Int8,
    /// packed int4 codes (two per byte along the head dim) + scale.
    Int4,
}

impl KvPrecision {
    pub fn from_dtype(dt: DType) -> Result<Self> {
        Ok(match dt {
            DType::F16 | DType::F32 => KvPrecision::F32,
            DType::Int8 | DType::Fp8 => KvPrecision::Int8,
            DType::Int4 => KvPrecision::Int4,
        })
    }

    /// Bytes per KV row of `head_dim` elements. Int4 packs two codes per
    /// byte and rounds odd head dims *up* to a whole byte (the analogue of
    /// the paper's adaptive head alignment) — `head_dim / 2` would silently
    /// drop the last nibble.
    pub fn row_bytes(self, head_dim: usize) -> usize {
        match self {
            KvPrecision::F32 => head_dim * 4,
            KvPrecision::Int8 => head_dim,
            KvPrecision::Int4 => head_dim.div_ceil(2),
        }
    }

    /// The kv-precision key used in graph names (`kv16`/`kv8`/`kv4`).
    pub fn graph_key(self) -> &'static str {
        match self {
            KvPrecision::F32 => "kv16",
            KvPrecision::Int8 => "kv8",
            KvPrecision::Int4 => "kv4",
        }
    }

    /// Inverse of [`KvPrecision::graph_key`] — used by layout spec parsing.
    pub fn parse_key(s: &str) -> Result<Self> {
        Ok(match s {
            "kv16" => KvPrecision::F32,
            "kv8" => KvPrecision::Int8,
            "kv4" => KvPrecision::Int4,
            other => bail!("unknown kv precision `{other}` (expected kv16, kv8, or kv4)"),
        })
    }

    /// Position on the one-way precision ladder (0 = widest). Transcoding
    /// is only legal toward higher ranks.
    pub fn ladder_rank(self) -> u8 {
        match self {
            KvPrecision::F32 => 0,
            KvPrecision::Int8 => 1,
            KvPrecision::Int4 => 2,
        }
    }

    /// One notch down the ladder, if any.
    pub fn next_down(self) -> Option<Self> {
        match self {
            KvPrecision::F32 => Some(KvPrecision::Int8),
            KvPrecision::Int8 => Some(KvPrecision::Int4),
            KvPrecision::Int4 => None,
        }
    }
}

/// Handle to one sequence's cache state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeqHandle(pub usize);

/// A byte-exact host-side copy of one sequence's cached KV — what a
/// swap-out preemption ships across the (modeled) PCIe link, and, since
/// it is layout-tagged, what cross-replica KV migration ships between
/// pools. Token slots are packed densely in sequence order: `codes[t]` is
/// the `len`-token slice of `token_code_bytes` each, `scales[t]` the
/// matching `L × 2 × Hkv` scale row.
///
/// The wire format carries the geometry (`kv_heads`, `head_dim`) and the
/// per-layer precision `layout` the bytes were exported under. Without
/// the tag, two layouts with equal total `token_code_bytes` (e.g.
/// `l0:kv16,l1:kv4` vs `l0:kv4,l1:kv16`) are indistinguishable to the
/// old aggregate-size check and import "successfully" with every
/// per-layer offset wrong — the latent bug this tag closes.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqSnapshot {
    /// Tokens captured.
    pub len: usize,
    /// `len × token_code_bytes` quantized codes.
    pub codes: Vec<u8>,
    /// `len × (L × 2 × Hkv)` dequantization scales.
    pub scales: Vec<f32>,
    /// KV heads per layer of the exporting pool.
    pub kv_heads: usize,
    /// Elements per KV row of the exporting pool.
    pub head_dim: usize,
    /// Per-layer precision layout the codes were exported under.
    pub layout: KvLayout,
}

impl SeqSnapshot {
    /// Bytes of quantized code payload (the precision-dependent part of
    /// the transfer; scales are a fixed f32 overhead on top).
    pub fn code_bytes(&self) -> usize {
        self.codes.len()
    }

    /// Order-sensitive fingerprint of the export layout — what
    /// [`KvPool::import_seq`] checks against the target pool before
    /// touching any bytes.
    pub fn fingerprint(&self) -> u64 {
        self.layout.fingerprint()
    }

    /// Total wire bytes (codes + f32 scales) split per precision rung of
    /// the *export* layout, indexed by [`KvPrecision::ladder_rank`]. The
    /// three entries sum to exactly `code_bytes() + scales.len() * 4`, so
    /// per-rung transfer attribution reconciles with the headline byte
    /// counters even when the source pool has relayouted since the
    /// export.
    pub fn bytes_by_rung(&self) -> [usize; 3] {
        let mut by = [0usize; 3];
        for l in 0..self.layout.n_layers() {
            let p = self.layout.prec(l);
            by[p.ladder_rank() as usize] +=
                2 * self.kv_heads * (p.row_bytes(self.head_dim) + 4) * self.len;
        }
        by
    }

    /// Re-encode the snapshot at `target` (a downward ladder move per
    /// [`KvLayout::can_transcode_to`]) without touching any pool. The
    /// per-row kernels are the same ones [`KvPool::relayout`] uses, so an
    /// import of the transcoded snapshot is bit-identical to admitting
    /// the original rows directly at `target` — the determinism contract
    /// cross-replica migration depends on.
    pub fn transcode_to(&self, target: &KvLayout) -> Result<SeqSnapshot> {
        if !self.layout.can_transcode_to(target) {
            bail!(
                "snapshot transcode from `{}` to `{}` is not a downward ladder move",
                self.layout,
                target
            );
        }
        if *target == self.layout {
            return Ok(self.clone());
        }
        let hd = self.head_dim;
        let kv_heads = self.kv_heads;
        let n_layers = self.layout.n_layers();
        let old_tcb = self.layout.token_code_bytes(kv_heads, hd);
        let new_tcb = target.token_code_bytes(kv_heads, hd);
        let tsc = n_layers * 2 * kv_heads;
        let mut codes = vec![0u8; self.len * new_tcb];
        let mut scales = self.scales.clone();
        for t in 0..self.len {
            let so = t * old_tcb;
            let dn = t * new_tcb;
            let scale_base = t * tsc;
            for l in 0..n_layers {
                let (from, to) = (self.layout.prec(l), target.prec(l));
                let rb_o = from.row_bytes(hd);
                let rb_n = to.row_bytes(hd);
                let ob = 2 * kv_heads * self.layout.prefix_row_bytes(l, hd);
                let nb = 2 * kv_heads * target.prefix_row_bytes(l, hd);
                for side in 0..2 {
                    for hh in 0..kv_heads {
                        let src = so + ob + (side * kv_heads + hh) * rb_o;
                        let dst = dn + nb + (side * kv_heads + hh) * rb_n;
                        let sidx = scale_base + (l * 2 + side) * kv_heads + hh;
                        if from == to {
                            codes[dst..dst + rb_n]
                                .copy_from_slice(&self.codes[src..src + rb_o]);
                            continue;
                        }
                        let row = &self.codes[src..src + rb_o];
                        let out = &mut codes[dst..dst + rb_n];
                        scales[sidx] = match (from, to) {
                            (KvPrecision::F32, KvPrecision::Int8) => f32_row_to_int8(row, out),
                            (KvPrecision::F32, KvPrecision::Int4) => f32_row_to_int4(row, out),
                            (KvPrecision::Int8, KvPrecision::Int4) => {
                                int8_row_to_int4(row, self.scales[sidx], out)
                            }
                            _ => unreachable!("validated as a downward ladder move"),
                        };
                    }
                }
            }
        }
        Ok(SeqSnapshot {
            len: self.len,
            codes,
            scales,
            kv_heads,
            head_dim: hd,
            layout: target.clone(),
        })
    }

    /// A snapshot of `len` tokens starting at token `start` — both the
    /// code and scale vectors are dense per-token arrays, so a token range
    /// is a straight slice of each. The prefix publisher uses this to cut
    /// one exported sequence into block-sized store entries.
    pub fn slice_tokens(&self, start: usize, len: usize) -> Result<SeqSnapshot> {
        if start + len > self.len {
            bail!(
                "snapshot slice {start}..{} out of range (snapshot holds {} tokens)",
                start + len,
                self.len
            );
        }
        let tcb = self.layout.token_code_bytes(self.kv_heads, self.head_dim);
        let tsc = self.layout.n_layers() * 2 * self.kv_heads;
        Ok(SeqSnapshot {
            len,
            codes: self.codes[start * tcb..(start + len) * tcb].to_vec(),
            scales: self.scales[start * tsc..(start + len) * tsc].to_vec(),
            kv_heads: self.kv_heads,
            head_dim: self.head_dim,
            layout: self.layout.clone(),
        })
    }
}

/// One contiguous extent of a [`GatherPlan`]: `len` tokens of batch entry
/// `bi`, starting at sequence position `t0`, resident in consecutive
/// arena token slots starting at `slot0` (`block × block_tokens +
/// in-block slot`). Extents never cross a block boundary unless the
/// planner merged arena-adjacent blocks into one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatherRun {
    /// Batch index of the owning sequence.
    pub bi: usize,
    /// First sequence position covered.
    pub t0: usize,
    /// First arena token slot covered.
    pub slot0: usize,
    /// Tokens in the extent.
    pub len: usize,
}

/// Phase one of a two-phase batch gather: the run-length description of
/// every contiguous (token-slot) extent the gather will touch, plus the
/// batch geometry it was planned against. Building the plan does all
/// validation and all per-token block arithmetic once; execution is then
/// pure strided copying. The plan is also the unit of modeled-HBM cost
/// accounting ([`GatherPlan::hbm_bytes`]).
#[derive(Debug, Clone)]
pub struct GatherPlan {
    runs: Vec<GatherRun>,
    b: usize,
    t_pad: usize,
    tokens: usize,
    hbm_bytes: usize,
    hbm_bytes_by_rung: [usize; 3],
}

impl GatherPlan {
    /// The planned extents, in batch-then-sequence order.
    pub fn runs(&self) -> &[GatherRun] {
        &self.runs
    }

    /// Batch size the plan was built for (`handles.len()`).
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Padded sequence length the plan was built for.
    pub fn t_pad(&self) -> usize {
        self.t_pad
    }

    /// Total live tokens the gather will move.
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Modeled HBM read traffic of executing the plan: the code and scale
    /// source bytes touched (`tokens × (token_code_bytes +
    /// token_scale_bytes)`). The write side is the caller's output buffer
    /// and is layout-independent, so it is not counted here.
    pub fn hbm_bytes(&self) -> usize {
        self.hbm_bytes
    }

    /// [`GatherPlan::hbm_bytes`] attributed per precision rung (indexed by
    /// [`KvPrecision::ladder_rank`]: `[kv16, kv8, kv4]`). Sums exactly to
    /// `hbm_bytes()` — the precision-attributed telemetry counters stay
    /// reconciled with the unattributed total by construction.
    pub fn hbm_bytes_by_rung(&self) -> [usize; 3] {
        self.hbm_bytes_by_rung
    }
}

/// Word-wide row copy: `u64` chunks plus a byte tail. Quantized KV rows
/// are short (`head_dim/2`..`4·head_dim` bytes), so lowering directly to
/// word moves keeps the gather/append inner loop free of generic memcpy
/// dispatch.
#[inline]
fn copy_row(dst: &mut [u8], src: &[u8]) {
    debug_assert_eq!(dst.len(), src.len());
    let mut s = src.chunks_exact(8);
    let mut d = dst.chunks_exact_mut(8);
    for (dc, sc) in (&mut d).zip(&mut s) {
        let w = u64::from_le_bytes(sc.try_into().expect("8-byte chunk"));
        dc.copy_from_slice(&w.to_le_bytes());
    }
    for (db, sb) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *db = *sb;
    }
}

#[derive(Debug)]
struct SeqState {
    blocks: Vec<usize>,
    len: usize,
    alive: bool,
}

/// The paged pool.
///
/// Blocks are **ref-counted**: a block may be owned by several sequences
/// at once (prefix sharing via [`KvPool::adopt_blocks`] / forking via
/// [`KvPool::fork_seq`]) and additionally retained by an external index
/// (the prefix cache, [`crate::kvcache::PrefixCache`]). A block returns to
/// the free list only when its last reference drops. Appending into a
/// *shared* partially-filled block copies it first (copy-on-write), so
/// divergence never corrupts another owner's view.
#[derive(Debug)]
pub struct KvPool {
    layout: KvLayout,
    n_layers: usize,
    kv_heads: usize,
    head_dim: usize,
    block_tokens: usize,
    n_blocks: usize,
    /// Fixed code-byte budget, set at the admission layout. The codes arena
    /// always spans exactly this many bytes; `relayout` re-divides it into
    /// more (smaller) blocks as layers move down the precision ladder.
    code_budget: usize,
    /// codes arena: `code_budget` bytes, of which the first
    /// `n_blocks × block_tokens × token_code_bytes` are addressable blocks.
    codes: Vec<u8>,
    /// scales arena: `n_blocks × block_tokens × (L × 2 × Hkv)`.
    scales: Vec<f32>,
    free: Vec<usize>,
    /// Per-block reference count (0 = on the free list).
    ref_count: Vec<u32>,
    seqs: Vec<SeqState>,
}

impl KvPool {
    /// The classic single-precision pool: every layer at `precision`.
    pub fn new(
        precision: KvPrecision,
        n_layers: usize,
        kv_heads: usize,
        head_dim: usize,
        block_tokens: usize,
        pool_tokens: usize,
    ) -> Result<Self> {
        Self::with_layout(
            KvLayout::uniform(precision, n_layers),
            kv_heads,
            head_dim,
            block_tokens,
            pool_tokens,
        )
    }

    /// A pool with a per-layer precision layout. `pool_tokens` is counted
    /// at the *admission* layout; laddering down later grows the block
    /// count inside the same byte budget.
    pub fn with_layout(
        layout: KvLayout,
        kv_heads: usize,
        head_dim: usize,
        block_tokens: usize,
        pool_tokens: usize,
    ) -> Result<Self> {
        let n_layers = layout.n_layers();
        if block_tokens == 0 || pool_tokens % block_tokens != 0 {
            bail!("pool_tokens {pool_tokens} must be a positive multiple of block_tokens {block_tokens}");
        }
        if n_layers == 0 || kv_heads == 0 || head_dim == 0 {
            bail!(
                "pool geometry must be non-zero (layers {n_layers}, kv heads {kv_heads}, head_dim {head_dim})"
            );
        }
        // Odd head dims are legal at every precision: Int4 rows align up to
        // a whole byte (`KvPrecision::row_bytes`), so the arena below is
        // sized for the rounded row and no nibble is ever truncated.
        let n_blocks = pool_tokens / block_tokens;
        let token_code_bytes = layout.token_code_bytes(kv_heads, head_dim);
        let token_scales = n_layers * 2 * kv_heads;
        let code_budget = n_blocks * block_tokens * token_code_bytes;
        Ok(Self {
            layout,
            n_layers,
            kv_heads,
            head_dim,
            block_tokens,
            n_blocks,
            code_budget,
            codes: vec![0u8; code_budget],
            scales: vec![1f32; n_blocks * block_tokens * token_scales],
            free: (0..n_blocks).rev().collect(),
            ref_count: vec![0; n_blocks],
            seqs: Vec::new(),
        })
    }

    /// The current per-layer precision layout.
    pub fn layout(&self) -> &KvLayout {
        &self.layout
    }

    /// Bytes of code storage per token slot (all layers, K+V, all heads).
    pub fn token_code_bytes(&self) -> usize {
        self.layout.token_code_bytes(self.kv_heads, self.head_dim)
    }

    /// Bytes of scale storage per token slot (one f32 per layer × K/V ×
    /// head) — precision-independent, unlike [`KvPool::token_code_bytes`].
    pub fn token_scale_bytes(&self) -> usize {
        self.token_scales() * 4
    }

    fn token_scales(&self) -> usize {
        self.n_layers * 2 * self.kv_heads
    }

    /// Per-token stored bytes (codes + f32 scales) split per precision
    /// rung, indexed by [`KvPrecision::ladder_rank`]. The three entries
    /// sum to exactly `token_code_bytes() + token_scale_bytes()`, so any
    /// total attributed through this table reconciles with the
    /// unattributed byte counters.
    pub fn token_bytes_by_rung(&self) -> [usize; 3] {
        let mut by = [0usize; 3];
        for l in 0..self.n_layers {
            let p = self.layout.prec(l);
            by[p.ladder_rank() as usize] +=
                2 * self.kv_heads * (p.row_bytes(self.head_dim) + 4);
        }
        by
    }

    /// Bytes per KV row (one head's codes for one token) at layer 0 — only
    /// meaningful for uniform layouts; per-layer consumers should use
    /// [`KvPool::row_bytes_at`] or the layout's offset table.
    pub fn row_bytes(&self) -> usize {
        self.layout.row_bytes(0, self.head_dim)
    }

    /// Bytes per KV row at layer `l`.
    pub fn row_bytes_at(&self, layer: usize) -> usize {
        self.layout.row_bytes(layer, self.head_dim)
    }

    /// Layer-0 precision — only meaningful for uniform layouts (kept for
    /// the pre-`KvLayout` callers); mixed pools should ask [`KvPool::layout`].
    pub fn precision(&self) -> KvPrecision {
        self.layout.prec(0)
    }

    /// Byte offset of layer `l`'s K row for head `hh` within a token slot.
    /// Token-slot layout: `[L][side(K=0,V=1)][Hkv][rb_l]` with per-layer
    /// row bytes.
    fn slot_k_off(&self, l: usize, hh: usize) -> usize {
        2 * self.kv_heads * self.layout.prefix_row_bytes(l, self.head_dim)
            + hh * self.layout.row_bytes(l, self.head_dim)
    }

    /// Byte offset of layer `l`'s V row for head `hh` within a token slot.
    fn slot_v_off(&self, l: usize, hh: usize) -> usize {
        2 * self.kv_heads * self.layout.prefix_row_bytes(l, self.head_dim)
            + (self.kv_heads + hh) * self.layout.row_bytes(l, self.head_dim)
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can `tokens` more tokens be stored right now (ignoring existing
    /// sequences' unfilled block tails)?
    pub fn can_reserve(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate a new (empty) sequence.
    pub fn alloc_seq(&mut self) -> SeqHandle {
        // Reuse a dead slot if any.
        for (i, s) in self.seqs.iter_mut().enumerate() {
            if !s.alive {
                *s = SeqState { blocks: Vec::new(), len: 0, alive: true };
                return SeqHandle(i);
            }
        }
        self.seqs.push(SeqState { blocks: Vec::new(), len: 0, alive: true });
        SeqHandle(self.seqs.len() - 1)
    }

    /// Release a sequence's references; blocks with no remaining owner
    /// (other sequences, the prefix index) return to the free list.
    pub fn free_seq(&mut self, h: SeqHandle) {
        if let Some(s) = self.seqs.get_mut(h.0) {
            if s.alive {
                let blocks = std::mem::take(&mut s.blocks);
                s.len = 0;
                s.alive = false;
                for b in blocks {
                    self.release_block(b);
                }
            }
        }
    }

    /// Add one reference to an in-use block (the prefix index pinning a
    /// cached block). Panics on a free block: retaining one would resurrect
    /// storage another allocation may already have claimed.
    pub fn retain_block(&mut self, blk: usize) {
        assert!(
            blk < self.n_blocks && self.ref_count[blk] > 0,
            "retain of free/out-of-range KV block {blk}"
        );
        self.ref_count[blk] += 1;
    }

    /// Drop one reference; the block returns to the free list when the
    /// count reaches zero. Panics on double free.
    pub fn release_block(&mut self, blk: usize) {
        assert!(blk < self.n_blocks, "release of out-of-range KV block {blk}");
        assert!(self.ref_count[blk] > 0, "double free of KV block {blk}");
        self.ref_count[blk] -= 1;
        if self.ref_count[blk] == 0 {
            self.free.push(blk);
        }
    }

    /// Current reference count of a block (0 = free).
    pub fn block_ref_count(&self, blk: usize) -> u32 {
        self.ref_count.get(blk).copied().unwrap_or(0)
    }

    /// Blocks currently out of the free list.
    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// The ordered pool block ids backing a live sequence (empty for dead
    /// or unknown handles).
    pub fn seq_blocks(&self, h: SeqHandle) -> &[usize] {
        match self.seqs.get(h.0) {
            Some(s) if s.alive => &s.blocks,
            _ => &[],
        }
    }

    /// Clone a sequence's cache state. The fork shares every block with
    /// the parent (ref-counted); whichever side appends into the shared
    /// partial tail block first triggers copy-on-write.
    pub fn fork_seq(&mut self, h: SeqHandle) -> Result<SeqHandle> {
        let (blocks, len) = {
            let s = self.seq_mut(h)?;
            (s.blocks.clone(), s.len)
        };
        for &b in &blocks {
            self.ref_count[b] += 1;
        }
        let nh = self.alloc_seq();
        let s = self.seqs.get_mut(nh.0).expect("fresh handle");
        s.blocks = blocks;
        s.len = len;
        Ok(nh)
    }

    /// Seed an **empty** sequence with already-resident shared blocks
    /// covering exactly `tokens` tokens (full blocks only — the prefix
    /// cache never indexes partial blocks). Each adopted block gains a
    /// reference.
    pub fn adopt_blocks(&mut self, h: SeqHandle, blocks: &[usize], tokens: usize) -> Result<()> {
        if tokens != blocks.len() * self.block_tokens {
            bail!(
                "adopt_blocks: {tokens} tokens != {} full blocks of {}",
                blocks.len(),
                self.block_tokens
            );
        }
        {
            let s = self.seq_mut(h)?;
            if s.len != 0 || !s.blocks.is_empty() {
                bail!("adopt_blocks into a non-empty sequence");
            }
        }
        for &b in blocks {
            if b >= self.n_blocks || self.ref_count[b] == 0 {
                bail!("adopt_blocks: block {b} is free or out of range");
            }
        }
        for &b in blocks {
            self.ref_count[b] += 1;
        }
        let s = self.seq_mut(h)?;
        s.blocks = blocks.to_vec();
        s.len = tokens;
        Ok(())
    }

    /// Copy one block's codes + scales arena regions (CoW backing).
    fn copy_block(&mut self, src: usize, dst: usize) {
        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        let (cs, cd) = (src * self.block_tokens * tcb, dst * self.block_tokens * tcb);
        self.codes.copy_within(cs..cs + self.block_tokens * tcb, cd);
        let (ss, sd) = (src * self.block_tokens * tsc, dst * self.block_tokens * tsc);
        self.scales.copy_within(ss..ss + self.block_tokens * tsc, sd);
    }

    pub fn seq_len(&self, h: SeqHandle) -> usize {
        self.seqs.get(h.0).map(|s| if s.alive { s.len } else { 0 }).unwrap_or(0)
    }

    pub fn live_seqs(&self) -> usize {
        self.seqs.iter().filter(|s| s.alive).count()
    }

    fn seq_mut(&mut self, h: SeqHandle) -> Result<&mut SeqState> {
        let s = self.seqs.get_mut(h.0).ok_or_else(|| anyhow!("bad seq handle"))?;
        if !s.alive {
            bail!("sequence already freed");
        }
        Ok(s)
    }

    /// (block_index, slot_in_block) for token `t`, growing if needed.
    ///
    /// Appending into a block shared with other owners copies it first
    /// (copy-on-write) so the other owners' views never change.
    fn slot_for_append(&mut self, h: SeqHandle) -> Result<(usize, usize)> {
        let block_tokens = self.block_tokens;
        let (len, n_owned) = {
            let s = self.seq_mut(h)?;
            (s.len, s.blocks.len())
        };
        if len % block_tokens == 0 && len / block_tokens == n_owned {
            let blk = self.free.pop().ok_or_else(|| anyhow!("KV pool exhausted"))?;
            self.ref_count[blk] = 1;
            self.seq_mut(h)?.blocks.push(blk);
        } else {
            let idx = len / block_tokens;
            let cur = self.seq_mut(h)?.blocks[idx];
            if self.ref_count[cur] > 1 {
                let fresh = self
                    .free
                    .pop()
                    .ok_or_else(|| anyhow!("KV pool exhausted (copy-on-write)"))?;
                self.ref_count[fresh] = 1;
                self.copy_block(cur, fresh);
                self.ref_count[cur] -= 1; // other owners remain, never hits 0
                self.seq_mut(h)?.blocks[idx] = fresh;
            }
        }
        let s = self.seq_mut(h)?;
        let t = s.len;
        let blk = s.blocks[t / block_tokens];
        s.len += 1;
        Ok((blk, t % block_tokens))
    }

    /// Append one token's KV for **all layers**.
    ///
    /// `k_codes`/`v_codes`: `[L, Hkv, rb_l]` flattened with per-layer row
    /// bytes (exactly the decode graph's per-sequence output layout).
    /// `k_scales`/`v_scales`: `[L, Hkv]`.
    pub fn append_token(
        &mut self,
        h: SeqHandle,
        k_codes: &[u8],
        k_scales: &[f32],
        v_codes: &[u8],
        v_scales: &[f32],
    ) -> Result<()> {
        let per_side = self.kv_heads * self.layout.sum_row_bytes(self.head_dim);
        if k_codes.len() != per_side || v_codes.len() != per_side {
            bail!("append_token codes size {} != {per_side}", k_codes.len());
        }
        let per_side_scales = self.n_layers * self.kv_heads;
        if k_scales.len() != per_side_scales || v_scales.len() != per_side_scales {
            bail!("append_token scales size mismatch");
        }
        let (blk, slot) = self.slot_for_append(h)?;

        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        let code_base = (blk * self.block_tokens + slot) * tcb;
        let scale_base = (blk * self.block_tokens + slot) * tsc;
        // Token-slot layout: [L][side(K=0,V=1)][Hkv][rb_l].
        for l in 0..self.n_layers {
            let rb = self.layout.row_bytes(l, self.head_dim);
            let src_base = self.kv_heads * self.layout.prefix_row_bytes(l, self.head_dim);
            for hh in 0..self.kv_heads {
                let src = src_base + hh * rb;
                let dst_k = code_base + self.slot_k_off(l, hh);
                let dst_v = code_base + self.slot_v_off(l, hh);
                self.codes[dst_k..dst_k + rb].copy_from_slice(&k_codes[src..src + rb]);
                self.codes[dst_v..dst_v + rb].copy_from_slice(&v_codes[src..src + rb]);
                let ssrc = l * self.kv_heads + hh;
                self.scales[scale_base + (l * 2) * self.kv_heads + hh] = k_scales[ssrc];
                self.scales[scale_base + (l * 2 + 1) * self.kv_heads + hh] = v_scales[ssrc];
            }
        }
        Ok(())
    }

    /// Append a prefill chunk's first `s_len` tokens.
    ///
    /// `k_codes`/`v_codes`: `[L, Hkv, S_stride, rb_l]` flattened with
    /// per-layer row bytes (the prefill graph's output layout, where
    /// `s_stride` is the compiled chunk bucket — possibly larger than
    /// `s_len` when the prompt tail was padded); scales `[L, Hkv,
    /// S_stride]`. Only real tokens are stored.
    pub fn append_chunk(
        &mut self,
        h: SeqHandle,
        s_len: usize,
        s_stride: usize,
        k_codes: &[u8],
        k_scales: &[f32],
        v_codes: &[u8],
        v_scales: &[f32],
    ) -> Result<()> {
        if s_len > s_stride {
            bail!("append_chunk: s_len {s_len} > s_stride {s_stride}");
        }
        let sum_rb = self.layout.sum_row_bytes(self.head_dim);
        let expect = self.kv_heads * s_stride * sum_rb;
        if k_codes.len() < expect || v_codes.len() < expect {
            bail!("append_chunk codes too small: {} < {expect}", k_codes.len());
        }
        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        let kv_heads = self.kv_heads;
        // Per-layer tables once per chunk (the old path re-sliced into a
        // per-token scratch and recomputed `prefix_row_bytes` per (token,
        // layer)); token slots are written straight from the chunk buffer.
        let mut rb = Vec::with_capacity(self.n_layers);
        let mut slot_base = Vec::with_capacity(self.n_layers); // in-slot K base
        let mut src_base = Vec::with_capacity(self.n_layers); // src layer base
        let mut prefix = 0usize;
        for l in 0..self.n_layers {
            let r = self.layout.row_bytes(l, self.head_dim);
            rb.push(r);
            slot_base.push(2 * kv_heads * prefix);
            src_base.push(kv_heads * s_stride * prefix);
            prefix += r;
        }
        for t in 0..s_len {
            let (blk, slot) = self.slot_for_append(h)?;
            let code_base = (blk * self.block_tokens + slot) * tcb;
            let scale_base = (blk * self.block_tokens + slot) * tsc;
            // Token-slot layout: [L][side(K=0,V=1)][Hkv][rb_l].
            for l in 0..self.n_layers {
                let r = rb[l];
                let kb = code_base + slot_base[l];
                let vb = kb + kv_heads * r;
                for hh in 0..kv_heads {
                    // src layout [L][Hkv][S_stride][rb_l]
                    let src = src_base[l] + (hh * s_stride + t) * r;
                    let dk = kb + hh * r;
                    let dv = vb + hh * r;
                    copy_row(&mut self.codes[dk..dk + r], &k_codes[src..src + r]);
                    copy_row(&mut self.codes[dv..dv + r], &v_codes[src..src + r]);
                    let ssrc = (l * kv_heads + hh) * s_stride + t;
                    self.scales[scale_base + (l * 2) * kv_heads + hh] = k_scales[ssrc];
                    self.scales[scale_base + (l * 2 + 1) * kv_heads + hh] = v_scales[ssrc];
                }
            }
        }
        Ok(())
    }

    /// Copy a live sequence's cached KV out of the pool (swap-out). The
    /// sequence itself is untouched — the caller typically follows up with
    /// [`KvPool::free_seq`] once the snapshot is safely stored host-side.
    pub fn export_seq(&self, h: SeqHandle) -> Result<SeqSnapshot> {
        let s = self.seqs.get(h.0).ok_or_else(|| anyhow!("bad seq handle"))?;
        if !s.alive {
            bail!("export of freed sequence");
        }
        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        let mut codes = vec![0u8; s.len * tcb];
        let mut scales = vec![0f32; s.len * tsc];
        for t in 0..s.len {
            let blk = s.blocks[t / self.block_tokens];
            let slot = t % self.block_tokens;
            let cb = (blk * self.block_tokens + slot) * tcb;
            codes[t * tcb..(t + 1) * tcb].copy_from_slice(&self.codes[cb..cb + tcb]);
            let sb = (blk * self.block_tokens + slot) * tsc;
            scales[t * tsc..(t + 1) * tsc].copy_from_slice(&self.scales[sb..sb + tsc]);
        }
        Ok(SeqSnapshot {
            len: s.len,
            codes,
            scales,
            kv_heads: self.kv_heads,
            head_dim: self.head_dim,
            layout: self.layout.clone(),
        })
    }

    /// Restore a snapshot into an **empty** sequence (swap-in): allocates
    /// `blocks_for(snap.len)` fresh blocks and writes the token slots back
    /// byte-exactly. Fails — leaving the sequence empty — if the free list
    /// cannot cover the allocation.
    pub fn import_seq(&mut self, h: SeqHandle, snap: &SeqSnapshot) -> Result<()> {
        if snap.kv_heads != self.kv_heads || snap.head_dim != self.head_dim {
            bail!(
                "import_seq: snapshot geometry mismatch (snapshot Hkv={} head_dim={}, \
                 pool Hkv={} head_dim={})",
                snap.kv_heads,
                snap.head_dim,
                self.kv_heads,
                self.head_dim
            );
        }
        // Layout identity, not just aggregate size: two layouts with equal
        // total token bytes (`l0:kv16,l1:kv4` vs `l0:kv4,l1:kv16`) would
        // pass the length check below and silently misinterpret every
        // per-layer offset. The fingerprint is order-sensitive, so only a
        // true per-layer match imports.
        if snap.fingerprint() != self.layout.fingerprint() {
            bail!(
                "import_seq: snapshot layout `{}` does not match pool layout `{}` \
                 (transcode the snapshot to the pool layout first)",
                snap.layout,
                self.layout
            );
        }
        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        if snap.codes.len() != snap.len * tcb || snap.scales.len() != snap.len * tsc {
            bail!(
                "import_seq: snapshot geometry mismatch ({} codes for {} tokens of {tcb})",
                snap.codes.len(),
                snap.len
            );
        }
        {
            let s = self.seq_mut(h)?;
            if s.len != 0 || !s.blocks.is_empty() {
                bail!("import_seq into a non-empty sequence");
            }
        }
        let need = self.blocks_for(snap.len);
        if need > self.free.len() {
            bail!("KV pool exhausted (swap-in needs {need} blocks, {} free)", self.free.len());
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let blk = self.free.pop().expect("checked above");
            self.ref_count[blk] = 1;
            blocks.push(blk);
        }
        for t in 0..snap.len {
            let blk = blocks[t / self.block_tokens];
            let slot = t % self.block_tokens;
            let cb = (blk * self.block_tokens + slot) * tcb;
            self.codes[cb..cb + tcb].copy_from_slice(&snap.codes[t * tcb..(t + 1) * tcb]);
            let sb = (blk * self.block_tokens + slot) * tsc;
            self.scales[sb..sb + tsc].copy_from_slice(&snap.scales[t * tsc..(t + 1) * tsc]);
        }
        let s = self.seq_mut(h)?;
        s.blocks = blocks;
        s.len = snap.len;
        Ok(())
    }

    /// Gather a batch of sequences into the padded decode-graph input
    /// buffers: codes `[L, B, Hkv, T, rb_l]` (per-layer row bytes, so layer
    /// `l` starts at `B × Hkv × T × prefix_row_bytes(l)`), scales `[L, B,
    /// Hkv, T]`. Sequences shorter than `t_pad` leave zeros (masked by
    /// `kv_len`).
    ///
    /// Two-phase: [`plan_gather`](Self::plan_gather) builds the run-length
    /// extent plan (all validation + block arithmetic),
    /// [`execute_gather`](Self::execute_gather) streams it with per-layer
    /// offset tables and word-wide copies. Returns the plan's modeled HBM
    /// read bytes ([`GatherPlan::hbm_bytes`]). Output is byte-identical to
    /// [`gather_batch_scalar`](Self::gather_batch_scalar), the retained
    /// pre-plan reference walk (property-tested below).
    #[allow(clippy::too_many_arguments)]
    pub fn gather_batch(
        &self,
        handles: &[Option<SeqHandle>],
        t_pad: usize,
        k_out: &mut [u8],
        ks_out: &mut [f32],
        v_out: &mut [u8],
        vs_out: &mut [f32],
    ) -> Result<usize> {
        let plan = self.plan_gather(handles, t_pad)?;
        self.execute_gather(&plan, k_out, ks_out, v_out, vs_out)?;
        Ok(plan.hbm_bytes())
    }

    /// Phase one of [`gather_batch`](Self::gather_batch): validate the
    /// batch and reduce it to contiguous token-slot extents. Each
    /// sequence contributes at most one [`GatherRun`] per resident block;
    /// runs whose blocks happen to be adjacent in the arena are merged.
    pub fn plan_gather(&self, handles: &[Option<SeqHandle>], t_pad: usize) -> Result<GatherPlan> {
        let mut runs = Vec::new();
        let mut tokens = 0usize;
        for (bi, h) in handles.iter().enumerate() {
            let Some(h) = h else { continue };
            let s = self.seqs.get(h.0).ok_or_else(|| anyhow!("bad handle"))?;
            if !s.alive {
                bail!("gather of freed sequence");
            }
            if s.len > t_pad {
                bail!("sequence len {} exceeds padded T {t_pad}", s.len);
            }
            tokens += s.len;
            let mut t = 0usize;
            while t < s.len {
                let slot = t % self.block_tokens;
                let len = (self.block_tokens - slot).min(s.len - t);
                let slot0 = s.blocks[t / self.block_tokens] * self.block_tokens + slot;
                let merged = match runs.last_mut() {
                    Some(r) if r.bi == bi && r.slot0 + r.len == slot0 && r.t0 + r.len == t => {
                        r.len += len;
                        true
                    }
                    _ => false,
                };
                if !merged {
                    runs.push(GatherRun { bi, t0: t, slot0, len });
                }
                t += len;
            }
        }
        let hbm_bytes = tokens * (self.token_code_bytes() + self.token_scale_bytes());
        let hbm_bytes_by_rung = self.token_bytes_by_rung().map(|b| b * tokens);
        Ok(GatherPlan { runs, b: handles.len(), t_pad, tokens, hbm_bytes, hbm_bytes_by_rung })
    }

    /// Phase two of [`gather_batch`](Self::gather_batch): stream a plan's
    /// extents into the output buffers. All per-layer offsets (row bytes,
    /// in-slot K/V bases, destination layer bases) are tabled once up
    /// front — the scalar walk recomputed `prefix_row_bytes` (itself
    /// `O(L)`) per (token, layer, head), an `O(B·T·L²·Hkv)` index-math
    /// term this path eliminates.
    pub fn execute_gather(
        &self,
        plan: &GatherPlan,
        k_out: &mut [u8],
        ks_out: &mut [f32],
        v_out: &mut [u8],
        vs_out: &mut [f32],
    ) -> Result<()> {
        let (b, t_pad) = (plan.b, plan.t_pad);
        let expect = b * self.kv_heads * t_pad * self.layout.sum_row_bytes(self.head_dim);
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_batch: out buffer {} != {expect}", k_out.len());
        }
        let sexpect = self.n_layers * b * self.kv_heads * t_pad;
        if ks_out.len() != sexpect || vs_out.len() != sexpect {
            bail!("gather_batch: scale buffer {} != {sexpect}", ks_out.len());
        }
        k_out.fill(0);
        v_out.fill(0);
        ks_out.fill(1.0);
        vs_out.fill(1.0);

        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        let kv_heads = self.kv_heads;
        // Per-layer tables, computed once per gather.
        let mut rb = Vec::with_capacity(self.n_layers);
        let mut k_base = Vec::with_capacity(self.n_layers); // in-slot K base
        let mut dst_base = Vec::with_capacity(self.n_layers); // [L] dst layer base
        let mut prefix = 0usize;
        for l in 0..self.n_layers {
            let r = self.layout.row_bytes(l, self.head_dim);
            rb.push(r);
            k_base.push(2 * kv_heads * prefix);
            dst_base.push(b * kv_heads * t_pad * prefix);
            prefix += r;
        }
        for run in &plan.runs {
            let src0 = run.slot0 * tcb;
            for l in 0..self.n_layers {
                let r = rb[l];
                let kb = k_base[l];
                let vb = kb + kv_heads * r;
                for hh in 0..kv_heads {
                    let mut src_k = src0 + kb + hh * r;
                    let mut src_v = src0 + vb + hh * r;
                    // dst layout [L][B][Hkv][T][rb_l]
                    let mut dst = dst_base[l] + ((run.bi * kv_heads + hh) * t_pad + run.t0) * r;
                    for _ in 0..run.len {
                        copy_row(&mut k_out[dst..dst + r], &self.codes[src_k..src_k + r]);
                        copy_row(&mut v_out[dst..dst + r], &self.codes[src_v..src_v + r]);
                        src_k += tcb;
                        src_v += tcb;
                        dst += r;
                    }
                    // Scales: src strides tsc per token, dst strides 1.
                    let mut ssrc = run.slot0 * tsc + (l * 2) * kv_heads + hh;
                    let sdst0 = ((l * b + run.bi) * kv_heads + hh) * t_pad + run.t0;
                    for sdst in sdst0..sdst0 + run.len {
                        ks_out[sdst] = self.scales[ssrc];
                        vs_out[sdst] = self.scales[ssrc + kv_heads];
                        ssrc += tsc;
                    }
                }
            }
        }
        Ok(())
    }

    /// Token-at-a-time reference for [`gather_batch`](Self::gather_batch)
    /// — the pre-plan implementation retained verbatim for bit-identity
    /// property tests and the `bench hotpath` speedup ratio.
    #[allow(clippy::too_many_arguments)]
    pub fn gather_batch_scalar(
        &self,
        handles: &[Option<SeqHandle>],
        t_pad: usize,
        k_out: &mut [u8],
        ks_out: &mut [f32],
        v_out: &mut [u8],
        vs_out: &mut [f32],
    ) -> Result<()> {
        let b = handles.len();
        let expect = b * self.kv_heads * t_pad * self.layout.sum_row_bytes(self.head_dim);
        if k_out.len() != expect || v_out.len() != expect {
            bail!("gather_batch: out buffer {} != {expect}", k_out.len());
        }
        k_out.fill(0);
        v_out.fill(0);
        ks_out.fill(1.0);
        vs_out.fill(1.0);

        let tcb = self.token_code_bytes();
        let tsc = self.token_scales();
        for (bi, h) in handles.iter().enumerate() {
            let Some(h) = h else { continue };
            let s = self.seqs.get(h.0).ok_or_else(|| anyhow!("bad handle"))?;
            if !s.alive {
                bail!("gather of freed sequence");
            }
            if s.len > t_pad {
                bail!("sequence len {} exceeds padded T {t_pad}", s.len);
            }
            for t in 0..s.len {
                let blk = s.blocks[t / self.block_tokens];
                let slot = t % self.block_tokens;
                let code_base = (blk * self.block_tokens + slot) * tcb;
                let scale_base = (blk * self.block_tokens + slot) * tsc;
                for l in 0..self.n_layers {
                    let rb = self.layout.row_bytes(l, self.head_dim);
                    let dst_layer =
                        b * self.kv_heads * t_pad * self.layout.prefix_row_bytes(l, self.head_dim);
                    for hh in 0..self.kv_heads {
                        let src_k = code_base + self.slot_k_off(l, hh);
                        let src_v = code_base + self.slot_v_off(l, hh);
                        // dst layout [L][B][Hkv][T][rb_l]
                        let dst = dst_layer + ((bi * self.kv_heads + hh) * t_pad + t) * rb;
                        k_out[dst..dst + rb].copy_from_slice(&self.codes[src_k..src_k + rb]);
                        v_out[dst..dst + rb].copy_from_slice(&self.codes[src_v..src_v + rb]);
                        let sdst = ((l * b + bi) * self.kv_heads + hh) * t_pad + t;
                        ks_out[sdst] = self.scales[scale_base + (l * 2) * self.kv_heads + hh];
                        vs_out[sdst] = self.scales[scale_base + (l * 2 + 1) * self.kv_heads + hh];
                    }
                }
            }
        }
        Ok(())
    }

    /// Drop a live sequence's tail back to `keep_tokens` (a block
    /// multiple), releasing the dropped blocks. The ladder rung uses this
    /// to rewind a restarted victim to its resident prompt prefix.
    pub fn truncate_seq(&mut self, h: SeqHandle, keep_tokens: usize) -> Result<usize> {
        let bt = self.block_tokens;
        if keep_tokens % bt != 0 {
            bail!("truncate_seq: keep {keep_tokens} is not a multiple of block_tokens {bt}");
        }
        let len = {
            let s = self.seq_mut(h)?;
            s.len
        };
        if keep_tokens > len {
            bail!("truncate_seq: keep {keep_tokens} > sequence len {len}");
        }
        let dropped = {
            let s = self.seq_mut(h)?;
            s.len = keep_tokens;
            s.blocks.split_off(keep_tokens / bt)
        };
        let n = dropped.len();
        for b in dropped {
            self.release_block(b);
        }
        Ok(n)
    }

    /// In-place precision laddering: transcode every resident block to
    /// `target` (a downward move per [`KvLayout::can_transcode_to`]) and
    /// re-divide the fixed byte budget into the larger block count the
    /// narrower layout affords. Block ids are preserved — sequences, the
    /// prefix index's pins, and ref counts all stay valid — and the newly
    /// affordable block ids join the free list.
    ///
    /// Transcoded codes are bit-identical to quantizing the original rows
    /// directly at the target precision (`quant::transcode`), so a
    /// relayouted pool is indistinguishable from one that admitted at
    /// `target` — the determinism contract the engine's ladder rung
    /// depends on.
    pub fn relayout(&mut self, target: &KvLayout) -> Result<RelayoutReport> {
        if !self.layout.can_transcode_to(target) {
            bail!(
                "relayout from `{}` to `{}` is not a downward ladder move",
                self.layout,
                target
            );
        }
        if *target == self.layout {
            return Ok(RelayoutReport::default());
        }
        let bt = self.block_tokens;
        let hd = self.head_dim;
        let old_tcb = self.token_code_bytes();
        let new_tcb = target.token_code_bytes(self.kv_heads, hd);
        let new_n_blocks = self.code_budget / (bt * new_tcb);
        debug_assert!(new_n_blocks >= self.n_blocks);
        let tsc = self.token_scales();

        // Blocks shrink in place, ascending: block i's new span
        // [i·bt·new_tcb, (i+1)·bt·new_tcb) ends at or before its old span's
        // end, and never reaches block i+1's old data — so with the old
        // bytes scratched out first, the walk is overlap-safe.
        let mut scratch = vec![0u8; bt * old_tcb];
        let mut transcoded_blocks = 0usize;
        for blk in 0..self.n_blocks {
            if self.ref_count[blk] == 0 {
                continue; // free block: bytes are garbage, nothing to move
            }
            transcoded_blocks += 1;
            let old_base = blk * bt * old_tcb;
            scratch.copy_from_slice(&self.codes[old_base..old_base + bt * old_tcb]);
            let new_base = blk * bt * new_tcb;
            for slot in 0..bt {
                let so = slot * old_tcb;
                let dn = new_base + slot * new_tcb;
                let scale_base = (blk * bt + slot) * tsc;
                for l in 0..self.n_layers {
                    let (from, to) = (self.layout.prec(l), target.prec(l));
                    let rb_o = from.row_bytes(hd);
                    let rb_n = to.row_bytes(hd);
                    let ob = 2 * self.kv_heads * self.layout.prefix_row_bytes(l, hd);
                    let nb = 2 * self.kv_heads * target.prefix_row_bytes(l, hd);
                    for side in 0..2 {
                        for hh in 0..self.kv_heads {
                            let src = so + ob + (side * self.kv_heads + hh) * rb_o;
                            let dst = dn + nb + (side * self.kv_heads + hh) * rb_n;
                            let sidx = scale_base + (l * 2 + side) * self.kv_heads + hh;
                            if from == to {
                                self.codes[dst..dst + rb_n]
                                    .copy_from_slice(&scratch[src..src + rb_o]);
                                continue;
                            }
                            let row = &scratch[src..src + rb_o];
                            let out = &mut self.codes[dst..dst + rb_n];
                            self.scales[sidx] = match (from, to) {
                                (KvPrecision::F32, KvPrecision::Int8) => f32_row_to_int8(row, out),
                                (KvPrecision::F32, KvPrecision::Int4) => f32_row_to_int4(row, out),
                                (KvPrecision::Int8, KvPrecision::Int4) => {
                                    int8_row_to_int4(row, self.scales[sidx], out)
                                }
                                _ => unreachable!("validated as a downward ladder move"),
                            };
                        }
                    }
                }
            }
        }

        // Read + write traffic of the changed layers (the modeled HBM
        // cost), attributed to each layer's *destination* rung.
        let per_block_rw_by_rung =
            per_block_rw_by_rung(&self.layout, target, bt, self.kv_heads, hd);

        // Re-divide the budget: same bytes, more (narrower) blocks.
        let gained = new_n_blocks - self.n_blocks;
        self.scales.resize(new_n_blocks * bt * tsc, 1.0);
        self.ref_count.resize(new_n_blocks, 0);
        self.free.extend(self.n_blocks..new_n_blocks);
        self.n_blocks = new_n_blocks;
        self.layout = target.clone();
        Ok(RelayoutReport::from_rw(gained, transcoded_blocks, per_block_rw_by_rung))
    }

    /// Exact dry-run of [`relayout`](Self::relayout): the report it *would*
    /// return, with no bytes moved. The preemption cost model prices a
    /// ladder rung with this before committing to it.
    pub fn relayout_estimate(&self, target: &KvLayout) -> Result<RelayoutReport> {
        if !self.layout.can_transcode_to(target) {
            bail!(
                "relayout from `{}` to `{}` is not a downward ladder move",
                self.layout,
                target
            );
        }
        if *target == self.layout {
            return Ok(RelayoutReport::default());
        }
        let bt = self.block_tokens;
        let hd = self.head_dim;
        let new_tcb = target.token_code_bytes(self.kv_heads, hd);
        let per_block_rw_by_rung =
            per_block_rw_by_rung(&self.layout, target, bt, self.kv_heads, hd);
        let transcoded_blocks = self.used_blocks();
        Ok(RelayoutReport::from_rw(
            self.code_budget / (bt * new_tcb) - self.n_blocks,
            transcoded_blocks,
            per_block_rw_by_rung,
        ))
    }
}

/// Per-block read+write transcode traffic of the layers that change
/// between `from` and `to`, attributed to each changed layer's
/// **destination** rung ([`KvPrecision::ladder_rank`] index). Shared by
/// [`KvPool::relayout`] and [`KvPool::relayout_estimate`] so the dry-run
/// stays exact.
fn per_block_rw_by_rung(
    from: &KvLayout,
    to: &KvLayout,
    block_tokens: usize,
    kv_heads: usize,
    head_dim: usize,
) -> [usize; 3] {
    let mut by = [0usize; 3];
    for l in 0..from.n_layers() {
        let (f, t) = (from.prec(l), to.prec(l));
        if f != t {
            by[t.ladder_rank() as usize] +=
                block_tokens * 2 * kv_heads * (f.row_bytes(head_dim) + t.row_bytes(head_dim));
        }
    }
    by
}

/// What one [`KvPool::relayout`] ladder move did.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RelayoutReport {
    /// Extra blocks the narrower layout affords inside the same budget.
    pub gained_blocks: usize,
    /// Resident blocks that were transcoded in place.
    pub transcoded_blocks: usize,
    /// Modeled read+write HBM traffic of the transcode (changed layers
    /// only), in bytes.
    pub transcoded_bytes: usize,
    /// [`RelayoutReport::transcoded_bytes`] split by each changed layer's
    /// destination rung (`[kv16, kv8, kv4]` by
    /// [`KvPrecision::ladder_rank`]); the entries sum to the total.
    pub transcoded_bytes_by_rung: [usize; 3],
}

impl RelayoutReport {
    fn from_rw(gained_blocks: usize, transcoded_blocks: usize, rw_by_rung: [usize; 3]) -> Self {
        let transcoded_bytes_by_rung = rw_by_rung.map(|b| b * transcoded_blocks);
        Self {
            gained_blocks,
            transcoded_blocks,
            transcoded_bytes: transcoded_bytes_by_rung.iter().sum(),
            transcoded_bytes_by_rung,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run_prop, Gen};

    fn pool(prec: KvPrecision) -> KvPool {
        // 2 layers, 2 kv heads, head_dim 8, 4-token blocks, 32-token pool.
        KvPool::new(prec, 2, 2, 8, 4, 32).unwrap()
    }

    fn tok_data(p: &KvPool, tag: u8) -> (Vec<u8>, Vec<f32>, Vec<u8>, Vec<f32>) {
        let rb = p.row_bytes();
        let n = 2 * 2 * rb;
        let k: Vec<u8> = (0..n).map(|i| tag.wrapping_add(i as u8)).collect();
        let v: Vec<u8> = (0..n).map(|i| tag.wrapping_add(100 + i as u8)).collect();
        let ks: Vec<f32> = (0..4).map(|i| tag as f32 + i as f32 * 0.1).collect();
        let vs: Vec<f32> = (0..4).map(|i| tag as f32 + 50.0 + i as f32 * 0.1).collect();
        (k, ks, v, vs)
    }

    #[test]
    fn append_and_gather_roundtrip() {
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        for t in 0..6 {
            let (k, ks, v, vs) = tok_data(&p, t as u8);
            p.append_token(h, &k, &ks, &v, &vs).unwrap();
        }
        assert_eq!(p.seq_len(h), 6);

        let t_pad = 8;
        let rb = p.row_bytes();
        let mut k_out = vec![0u8; 2 * 1 * 2 * t_pad * rb];
        let mut v_out = k_out.clone();
        let mut ks_out = vec![0f32; 2 * 1 * 2 * t_pad];
        let mut vs_out = ks_out.clone();
        p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
            .unwrap();

        // Check token 5, layer 1, head 0 K codes.
        let (k5, ks5, _, _) = tok_data(&p, 5);
        let src = (1 * 2 + 0) * rb; // l=1,h=0 in [L][Hkv][rb]
        let dst = (((1usize * 1 + 0) * 2 + 0) * t_pad + 5) * rb;
        assert_eq!(&k_out[dst..dst + rb], &k5[src..src + rb]);
        let sdst = ((1 * 1 + 0) * 2 + 0) * t_pad + 5;
        assert_eq!(ks_out[sdst], ks5[1 * 2 + 0]);
        // Padding slots stay zero / scale 1.
        let dst7 = (((0usize * 1 + 0) * 2 + 0) * t_pad + 7) * rb;
        assert!(k_out[dst7..dst7 + rb].iter().all(|&b| b == 0));
        assert_eq!(vs_out[7], 1.0);
    }

    #[test]
    fn blocks_allocated_lazily_and_freed() {
        let mut p = pool(KvPrecision::Int8);
        assert_eq!(p.free_blocks(), 8);
        let h = p.alloc_seq();
        assert_eq!(p.free_blocks(), 8, "no block until first token");
        let (k, ks, v, vs) = tok_data(&p, 1);
        for _ in 0..5 {
            p.append_token(h, &k, &ks, &v, &vs).unwrap();
        }
        assert_eq!(p.free_blocks(), 6, "5 tokens => 2 blocks of 4");
        p.free_seq(h);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.live_seqs(), 0);
    }

    #[test]
    fn pool_exhaustion_errors() {
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 2);
        for _ in 0..32 {
            p.append_token(h, &k, &ks, &v, &vs).unwrap();
        }
        assert!(!p.can_reserve(1));
        let err = p.append_token(h, &k, &ks, &v, &vs).unwrap_err();
        assert!(err.to_string().contains("exhausted"));
    }

    #[test]
    fn freed_seq_rejects_ops() {
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        p.free_seq(h);
        let (k, ks, v, vs) = tok_data(&p, 3);
        assert!(p.append_token(h, &k, &ks, &v, &vs).is_err());
        // Double free is a no-op.
        p.free_seq(h);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn seq_slot_reuse() {
        let mut p = pool(KvPrecision::Int8);
        let h1 = p.alloc_seq();
        p.free_seq(h1);
        let h2 = p.alloc_seq();
        assert_eq!(h1.0, h2.0, "dead slot reused");
    }

    #[test]
    fn int4_rows_are_half_size() {
        let p4 = pool(KvPrecision::Int4);
        let p8 = pool(KvPrecision::Int8);
        assert_eq!(p4.row_bytes() * 2, p8.row_bytes());
        assert_eq!(p4.token_code_bytes() * 2, p8.token_code_bytes());
    }

    #[test]
    fn f32_pool_stores_floats() {
        let mut p = pool(KvPrecision::F32);
        assert_eq!(p.row_bytes(), 32);
        let h = p.alloc_seq();
        let rb = p.row_bytes();
        let k: Vec<u8> = 1.5f32.to_le_bytes().repeat(2 * 2 * rb / 4);
        let ks = vec![1.0f32; 4];
        p.append_token(h, &k, &ks, &k, &ks).unwrap();
        assert_eq!(p.seq_len(h), 1);
    }

    #[test]
    fn append_chunk_matches_tokenwise() {
        // append_chunk([L,Hkv,S,rb]) == S × append_token.
        let mut pa = pool(KvPrecision::Int8);
        let mut pb = pool(KvPrecision::Int8);
        let (s_len, l, hk) = (3usize, 2usize, 2usize);
        let rb = pa.row_bytes();
        let k_chunk: Vec<u8> = (0..l * hk * s_len * rb).map(|i| i as u8).collect();
        let v_chunk: Vec<u8> = (0..l * hk * s_len * rb).map(|i| (i * 3) as u8).collect();
        let ks_chunk: Vec<f32> = (0..l * hk * s_len).map(|i| i as f32).collect();
        let vs_chunk: Vec<f32> = (0..l * hk * s_len).map(|i| i as f32 + 9.0).collect();

        let ha = pa.alloc_seq();
        pa.append_chunk(ha, s_len, s_len, &k_chunk, &ks_chunk, &v_chunk, &vs_chunk).unwrap();

        let hb = pb.alloc_seq();
        for t in 0..s_len {
            let mut kc = vec![0u8; l * hk * rb];
            let mut vc = vec![0u8; l * hk * rb];
            let mut ks = vec![0f32; l * hk];
            let mut vs = vec![0f32; l * hk];
            for li in 0..l {
                for hh in 0..hk {
                    let src = ((li * hk + hh) * s_len + t) * rb;
                    let dst = (li * hk + hh) * rb;
                    kc[dst..dst + rb].copy_from_slice(&k_chunk[src..src + rb]);
                    vc[dst..dst + rb].copy_from_slice(&v_chunk[src..src + rb]);
                    ks[li * hk + hh] = ks_chunk[(li * hk + hh) * s_len + t];
                    vs[li * hk + hh] = vs_chunk[(li * hk + hh) * s_len + t];
                }
            }
            pb.append_token(hb, &kc, &ks, &vc, &vs).unwrap();
        }

        let t_pad = 4;
        let mk = |p: &KvPool, h| {
            let rb = p.row_bytes();
            let mut k_out = vec![0u8; l * hk * t_pad * rb];
            let mut v_out = k_out.clone();
            let mut ks_out = vec![0f32; l * hk * t_pad];
            let mut vs_out = ks_out.clone();
            p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
                .unwrap();
            (k_out, ks_out, v_out, vs_out)
        };
        assert_eq!(mk(&pa, ha), mk(&pb, hb));
    }

    #[test]
    fn prop_pool_invariants() {
        // Invariant: free + Σ allocated == total; seq_len tracks appends;
        // gather returns exactly the appended bytes.
        run_prop("kvpool-invariants", 0xD00D, 30, |g| {
            let mut p = KvPool::new(KvPrecision::Int8, 1, 1, 4, 2, 16).unwrap();
            let total = p.total_blocks();
            let mut handles = vec![];
            let mut lens = vec![];
            for _ in 0..g.usize_in(1, 4) {
                let h = p.alloc_seq();
                let n = g.usize_in(0, 5);
                for t in 0..n {
                    let k = vec![t as u8; 4];
                    let s = vec![1.0f32];
                    if p.append_token(h, &k, &s, &k, &s).is_err() {
                        break;
                    }
                }
                handles.push(h);
                lens.push(p.seq_len(h));
            }
            let used: usize = lens.iter().map(|&n| n.div_ceil(2)).sum();
            assert_eq!(p.free_blocks() + used, total);
            for (h, &n) in handles.iter().zip(&lens) {
                assert_eq!(p.seq_len(*h), n);
            }
            for h in handles {
                p.free_seq(h);
            }
            assert_eq!(p.free_blocks(), total);
        });
    }

    #[test]
    fn prop_gather_plan_matches_scalar_walk() {
        // The planned word-wide gather vs the retained token-at-a-time
        // walk: byte- and bit-identical output across mixed layouts,
        // scrambled block orders, None handles, empty sequences, and
        // padded tails — with both destinations starting dirty so any
        // missed slot would diverge.
        run_prop("gather-plan-vs-scalar", 0x6A78E4, 30, |g| {
            let n_layers = g.usize_in(1, 4);
            let kv_heads = g.usize_in(1, 3);
            let head_dim = [3usize, 7, 8, 16][g.usize_in(0, 3)];
            let bt = g.usize_in(2, 4);
            let spec = (0..n_layers)
                .map(|l| format!("l{l}:{}", ["kv16", "kv8", "kv4"][g.usize_in(0, 2)]))
                .collect::<Vec<_>>()
                .join(",");
            let layout = KvLayout::parse(&spec, n_layers).unwrap();
            let mut p = KvPool::with_layout(layout, kv_heads, head_dim, bt, bt * 24).unwrap();

            let per_side = kv_heads * p.layout().sum_row_bytes(head_dim);
            let mut handles: Vec<Option<SeqHandle>> = Vec::new();
            for si in 0..g.usize_in(1, 3) {
                if g.bool() {
                    handles.push(None);
                }
                let h = p.alloc_seq();
                for t in 0..g.usize_in(0, 2 * bt + 1) {
                    let k: Vec<u8> =
                        (0..per_side).map(|i| (si * 31 + t * 7 + i) as u8).collect();
                    let v: Vec<u8> =
                        (0..per_side).map(|i| (si * 17 + t * 3 + i * 5) as u8).collect();
                    let ks = g.f32_vec(n_layers * kv_heads, 0.1, 4.0);
                    let vs = g.f32_vec(n_layers * kv_heads, 0.1, 4.0);
                    p.append_token(h, &k, &ks, &v, &vs).unwrap();
                }
                handles.push(Some(h));
                // Scramble arena block order for later sequences: a
                // freed throwaway block goes back on the (LIFO) free
                // list, so runs stop being arena-monotone.
                if g.bool() {
                    let tmp = p.alloc_seq();
                    let k = vec![0u8; per_side];
                    let s = vec![1.0f32; n_layers * kv_heads];
                    p.append_token(tmp, &k, &s, &k, &s).unwrap();
                    p.free_seq(tmp);
                }
            }
            let live = || handles.iter().flatten().copied().collect::<Vec<_>>();
            let max_len = live().iter().map(|&h| p.seq_len(h)).max().unwrap_or(0);
            let t_pad = (max_len + g.usize_in(0, 3)).max(1);
            let b = handles.len();
            let n = b * kv_heads * t_pad * p.layout().sum_row_bytes(head_dim);
            let sn = n_layers * b * kv_heads * t_pad;

            let (mut k1, mut v1) = (vec![0xAAu8; n], vec![0xAAu8; n]);
            let (mut ks1, mut vs1) = (vec![-1f32; sn], vec![-1f32; sn]);
            let planned =
                p.gather_batch(&handles, t_pad, &mut k1, &mut ks1, &mut v1, &mut vs1).unwrap();
            let (mut k2, mut v2) = (vec![0x55u8; n], vec![0x55u8; n]);
            let (mut ks2, mut vs2) = (vec![-2f32; sn], vec![-2f32; sn]);
            p.gather_batch_scalar(&handles, t_pad, &mut k2, &mut ks2, &mut v2, &mut vs2)
                .unwrap();

            assert_eq!(k1, k2, "K codes diverge ({spec}, b={b}, t_pad={t_pad})");
            assert_eq!(v1, v2, "V codes diverge ({spec}, b={b}, t_pad={t_pad})");
            assert!(ks1.iter().zip(&ks2).all(|(a, c)| a.to_bits() == c.to_bits()));
            assert!(vs1.iter().zip(&vs2).all(|(a, c)| a.to_bits() == c.to_bits()));

            // Plan accounting: tokens and modeled HBM bytes match the
            // live token population exactly.
            let tokens: usize = live().iter().map(|&h| p.seq_len(h)).sum();
            assert_eq!(planned, tokens * (p.token_code_bytes() + p.token_scale_bytes()));
            let plan = p.plan_gather(&handles, t_pad).unwrap();
            assert_eq!(plan.tokens(), tokens);
            assert_eq!(plan.hbm_bytes(), planned);
            assert_eq!(plan.batch(), b);
            assert!(plan.runs().iter().all(|r| r.len > 0));
            assert_eq!(plan.runs().iter().map(|r| r.len).sum::<usize>(), tokens);
        });
    }

    #[test]
    fn int4_odd_head_dim_rounds_up() {
        // head_dim 7 → 4 bytes/row; `head_dim / 2` would have dropped the
        // 7th element's nibble.
        assert_eq!(KvPrecision::Int4.row_bytes(7), 4);
        assert_eq!(KvPrecision::Int4.row_bytes(1), 1);
        assert_eq!(KvPrecision::Int4.row_bytes(8), 4);
        let mut p = KvPool::new(KvPrecision::Int4, 1, 1, 7, 2, 8).unwrap();
        let rb = p.row_bytes();
        assert_eq!(rb, 4);
        let h = p.alloc_seq();
        let k: Vec<u8> = (0..rb).map(|i| 0xA0u8.wrapping_add(i as u8)).collect();
        let v: Vec<u8> = (0..rb).map(|i| 0x50u8.wrapping_add(i as u8)).collect();
        let s = vec![0.5f32];
        p.append_token(h, &k, &s, &v, &s).unwrap();
        // Gather returns the full rounded row including the tail-nibble byte.
        let t_pad = 2;
        let mut k_out = vec![0u8; t_pad * rb];
        let mut v_out = k_out.clone();
        let mut ks_out = vec![0f32; t_pad];
        let mut vs_out = ks_out.clone();
        p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
            .unwrap();
        assert_eq!(&k_out[..rb], &k[..]);
        assert_eq!(&v_out[..rb], &v[..]);
    }

    #[test]
    fn odd_head_dims_valid_at_all_precisions() {
        for prec in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
            for hd in [1usize, 3, 5, 7, 9, 31] {
                let p = KvPool::new(prec, 2, 2, hd, 4, 16).unwrap();
                // Arena is sized for the rounded row.
                assert_eq!(p.token_code_bytes(), 2 * 2 * 2 * prec.row_bytes(hd));
            }
        }
    }

    #[test]
    fn zero_geometry_rejected_at_construction() {
        assert!(KvPool::new(KvPrecision::Int8, 0, 2, 8, 4, 32).is_err());
        assert!(KvPool::new(KvPrecision::Int8, 2, 0, 8, 4, 32).is_err());
        assert!(KvPool::new(KvPrecision::Int8, 2, 2, 0, 4, 32).is_err());
    }

    #[test]
    fn fork_shares_then_cow_on_divergence() {
        let mut p = pool(KvPrecision::Int8); // 4-token blocks, 8 blocks
        let h1 = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 1);
        for _ in 0..6 {
            p.append_token(h1, &k, &ks, &v, &vs).unwrap();
        }
        assert_eq!(p.free_blocks(), 6);
        let h2 = p.fork_seq(h1).unwrap();
        assert_eq!(p.free_blocks(), 6, "fork allocates nothing");
        assert_eq!(p.seq_len(h2), 6);
        assert_eq!(p.seq_blocks(h1), p.seq_blocks(h2));

        // Divergence: h2 appends → its shared partial tail is copied.
        let (k9, ks9, v9, vs9) = tok_data(&p, 9);
        p.append_token(h2, &k9, &ks9, &v9, &vs9).unwrap();
        assert_eq!(p.free_blocks(), 5, "CoW copied the tail block");
        assert_eq!(p.seq_blocks(h1)[0], p.seq_blocks(h2)[0], "full block still shared");
        assert_ne!(p.seq_blocks(h1)[1], p.seq_blocks(h2)[1], "tail diverged");
        assert_eq!(p.seq_len(h1), 6, "parent view unchanged");
        assert_eq!(p.seq_len(h2), 7);

        // Parent's gathered bytes are untouched by the fork's append.
        let t_pad = 8;
        let rb = p.row_bytes();
        let gather = |p: &KvPool, h| {
            let mut k_out = vec![0u8; 2 * 2 * t_pad * rb];
            let mut v_out = k_out.clone();
            let mut ks_out = vec![0f32; 2 * 2 * t_pad];
            let mut vs_out = ks_out.clone();
            p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
                .unwrap();
            k_out
        };
        let g1 = gather(&p, h1);
        // Token 5 (slot 1 of the tail block) must still be tag-1 data.
        assert_eq!(&g1[5 * rb..5 * rb + rb], &k[..rb]);

        p.free_seq(h1);
        assert_eq!(p.free_blocks(), 6, "h2 still holds its 2 blocks");
        p.free_seq(h2);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn adopt_blocks_shares_full_blocks() {
        let mut p = pool(KvPrecision::Int8); // 4-token blocks
        let h1 = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 2);
        for _ in 0..8 {
            p.append_token(h1, &k, &ks, &v, &vs).unwrap();
        }
        let shared: Vec<usize> = p.seq_blocks(h1).to_vec();
        assert_eq!(shared.len(), 2);

        let h2 = p.alloc_seq();
        p.adopt_blocks(h2, &shared, 8).unwrap();
        assert_eq!(p.seq_len(h2), 8);
        assert_eq!(p.free_blocks(), 6, "adoption allocates nothing");
        for &b in &shared {
            assert_eq!(p.block_ref_count(b), 2);
        }
        // Appending after a full adopted block opens a fresh block — no CoW
        // needed, the shared blocks stay intact.
        p.append_token(h2, &k, &ks, &v, &vs).unwrap();
        assert_eq!(p.free_blocks(), 5);
        assert_eq!(p.seq_blocks(h2)[..2], shared[..]);

        // Partial adoption is rejected, as is adopting into non-empty seqs.
        let h3 = p.alloc_seq();
        assert!(p.adopt_blocks(h3, &shared, 7).is_err(), "non-block-multiple");
        assert!(p.adopt_blocks(h2, &shared, 8).is_err(), "non-empty target");

        p.free_seq(h1);
        assert_eq!(p.free_blocks(), 5, "h2 keeps the shared blocks alive");
        p.free_seq(h2);
        p.free_seq(h3);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn retain_release_pins_blocks_like_an_index() {
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 3);
        for _ in 0..4 {
            p.append_token(h, &k, &ks, &v, &vs).unwrap();
        }
        let b = p.seq_blocks(h)[0];
        p.retain_block(b);
        p.free_seq(h);
        assert_eq!(p.free_blocks(), 7, "retained block survives its sequence");
        assert_eq!(p.block_ref_count(b), 1);
        p.release_block(b);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.block_ref_count(b), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_release_panics() {
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 4);
        p.append_token(h, &k, &ks, &v, &vs).unwrap();
        let b = p.seq_blocks(h)[0];
        p.retain_block(b);
        p.free_seq(h);
        p.release_block(b); // last reference → block freed
        p.release_block(b); // double free → panic
    }

    #[test]
    fn snapshot_roundtrip_is_byte_exact() {
        // export → free → import restores the identical gather bytes — the
        // property swap-mode preemption rests on.
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        for t in 0..6 {
            let (k, ks, v, vs) = tok_data(&p, 40 + t as u8);
            p.append_token(h, &k, &ks, &v, &vs).unwrap();
        }
        let t_pad = 8;
        let rb = p.row_bytes();
        let gather = |p: &KvPool, h| {
            let mut k_out = vec![0u8; 2 * 2 * t_pad * rb];
            let mut v_out = k_out.clone();
            let mut ks_out = vec![0f32; 2 * 2 * t_pad];
            let mut vs_out = ks_out.clone();
            p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
                .unwrap();
            (k_out, ks_out, v_out, vs_out)
        };
        let before = gather(&p, h);

        let snap = p.export_seq(h).unwrap();
        assert_eq!(snap.len, 6);
        assert_eq!(snap.code_bytes(), 6 * p.token_code_bytes());
        p.free_seq(h);
        assert_eq!(p.free_blocks(), 8, "victim fully released");

        let h2 = p.alloc_seq();
        p.import_seq(h2, &snap).unwrap();
        assert_eq!(p.seq_len(h2), 6);
        assert_eq!(p.free_blocks(), 6, "2 blocks re-allocated");
        assert_eq!(gather(&p, h2), before, "swap round-trip must be byte-exact");
    }

    #[test]
    fn import_rejects_bad_targets_and_dry_pool() {
        let mut p = pool(KvPrecision::Int8); // 8 blocks of 4 tokens
        let h = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 7);
        for _ in 0..8 {
            p.append_token(h, &k, &ks, &v, &vs).unwrap();
        }
        let snap = p.export_seq(h).unwrap();

        // Non-empty target.
        assert!(p.import_seq(h, &snap).is_err());
        // Dry pool: fill the rest, then import must fail cleanly…
        let h2 = p.alloc_seq();
        for _ in 0..24 {
            p.append_token(h2, &k, &ks, &v, &vs).unwrap();
        }
        let h3 = p.alloc_seq();
        let err = p.import_seq(h3, &snap).unwrap_err();
        assert!(err.to_string().contains("swap-in"), "{err}");
        assert_eq!(p.seq_len(h3), 0, "failed import leaves the target empty");
        // …and succeed once room frees up.
        p.free_seq(h);
        p.import_seq(h3, &snap).unwrap();
        assert_eq!(p.seq_len(h3), 8);
        // Exporting a freed handle is an error.
        assert!(p.export_seq(h).is_err());
    }

    #[test]
    fn import_rejects_layout_and_geometry_mismatch() {
        // The trap this guards: two layouts with EQUAL total token bytes
        // but different per-layer assignment. The aggregate-size check
        // alone cannot tell them apart, and the import would silently
        // misinterpret every per-layer offset.
        let a = KvLayout::parse("l0:kv16,l1:kv4", 2).unwrap();
        let b = KvLayout::parse("l0:kv4,l1:kv16", 2).unwrap();
        let mut pa = KvPool::with_layout(a, 2, 8, 4, 32).unwrap();
        let mut pb = KvPool::with_layout(b, 2, 8, 4, 32).unwrap();
        assert_eq!(pa.token_code_bytes(), pb.token_code_bytes(), "equal aggregate size");

        let ha = pa.alloc_seq();
        let sum_rb: usize = (0..2).map(|l| pa.row_bytes_at(l)).sum();
        let k: Vec<u8> = (0..2 * sum_rb).map(|i| i as u8).collect();
        let s = vec![1.0f32; 4];
        for _ in 0..4 {
            pa.append_token(ha, &k, &s, &k, &s).unwrap();
        }
        let snap = pa.export_seq(ha).unwrap();
        assert_eq!(snap.layout, *pa.layout(), "snapshot carries its export layout");

        let hb = pb.alloc_seq();
        let err = pb.import_seq(hb, &snap).unwrap_err();
        assert!(err.to_string().contains("layout"), "{err}");
        assert_eq!(pb.seq_len(hb), 0, "rejected import leaves the target empty");
        assert_eq!(pb.free_blocks(), pb.total_blocks(), "no blocks leaked");

        // Same layout string, different geometry (head_dim) — also rejected.
        let c = KvLayout::parse("l0:kv16,l1:kv4", 2).unwrap();
        let mut pc = KvPool::with_layout(c, 2, 6, 4, 32).unwrap();
        let hc = pc.alloc_seq();
        let err = pc.import_seq(hc, &snap).unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
    }

    #[test]
    fn snapshot_transcode_matches_relayout_then_export() {
        // snapshot.transcode_to(target) must be indistinguishable from
        // laddering the pool itself and re-exporting — same kernels, same
        // walk order, bit-identical codes and scales.
        let mut p = pool(KvPrecision::F32);
        let h = p.alloc_seq();
        let row = |t: usize, l: usize, hh: usize, side: usize| -> Vec<f32> {
            (0..8)
                .map(|i| ((t * 89 + l * 31 + hh * 7 + side * 13 + i) % 19) as f32 * 0.47 - 4.0)
                .collect()
        };
        for t in 0..6 {
            let mut k = Vec::new();
            let mut v = Vec::new();
            for l in 0..2 {
                for hh in 0..2 {
                    k.extend(f32_row_bytes(&row(t, l, hh, 0)));
                    v.extend(f32_row_bytes(&row(t, l, hh, 1)));
                }
            }
            let s = vec![1.0f32; 4];
            p.append_token(h, &k, &s, &v, &s).unwrap();
        }
        let snap16 = p.export_seq(h).unwrap();

        // Identity transcode is a clone.
        let same = snap16.transcode_to(&snap16.layout.clone()).unwrap();
        assert_eq!(same, snap16);

        // Downward mixed move; compare against relayout + export.
        let mid = KvLayout::parse("l0:kv16,l1:kv4", 2).unwrap();
        let host = snap16.transcode_to(&mid).unwrap();
        p.relayout(&mid).unwrap();
        let direct = p.export_seq(h).unwrap();
        assert_eq!(host, direct, "host-side transcode == pool relayout, bit for bit");

        // Upward transcode is rejected.
        let wide = KvLayout::parse("kv16", 2).unwrap();
        assert!(host.transcode_to(&wide).is_err(), "upward move must fail");

        // Per-rung extents reconcile with the headline wire bytes at both
        // layouts.
        for s in [&snap16, &host] {
            let total: usize = s.bytes_by_rung().iter().sum();
            assert_eq!(total, s.code_bytes() + s.scales.len() * 4);
        }
        // And a transitive step (kv16 → mixed → all-kv4) equals the direct
        // one-hop transcode — the nested-refinement property.
        let narrow = KvLayout::parse("kv4", 2).unwrap();
        assert_eq!(
            host.transcode_to(&narrow).unwrap(),
            snap16.transcode_to(&narrow).unwrap(),
            "two-hop transcode == one-hop"
        );
    }

    #[test]
    fn prop_cross_layout_transcode_import_round_trips_bit_exactly() {
        // Randomized closure of the migration wire contract: random
        // geometry (odd head_dims included — Int4 rows pack a ragged
        // tail), random mixed per-layer target layouts across all three
        // rungs. For source kv16 and any downward pair B ≥ A (rank-wise):
        //   * two-hop transcode (via B) == one-hop transcode to A;
        //   * importing the transcoded snapshot into a pool *at* A and
        //     re-exporting reproduces it byte for byte;
        //   * per-rung extents always sum to the headline wire bytes;
        //   * the strictly-upward move A → B is rejected, and a pool at A
        //     refuses to import a B-layout snapshot outright.
        run_prop("snapshot-cross-layout", 0x5EED_CAFE, 12, |g: &mut Gen| {
            let n_layers = g.usize_in(1, 3);
            let kv_heads = g.usize_in(1, 2);
            let head_dim = *g.choose(&[5usize, 7, 8, 9]);
            let keys = ["kv16", "kv8", "kv4"];
            // Per-layer ranks: A is the narrow destination, B sits between
            // the kv16 source and A (rank_B <= rank_A layer-wise).
            let ranks_a: Vec<usize> = (0..n_layers).map(|_| g.usize_in(0, 2)).collect();
            let ranks_b: Vec<usize> = ranks_a.iter().map(|&r| g.usize_in(0, r)).collect();
            let spec = |ranks: &[usize]| {
                ranks
                    .iter()
                    .enumerate()
                    .map(|(l, &r)| format!("l{l}:{}", keys[r]))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            let lay_a = KvLayout::parse(&spec(&ranks_a), n_layers).unwrap();
            let lay_b = KvLayout::parse(&spec(&ranks_b), n_layers).unwrap();

            // Fill a kv16 pool with deterministic rows and export.
            let lay16 = KvLayout::parse("kv16", n_layers).unwrap();
            let mut p16 = KvPool::with_layout(lay16, kv_heads, head_dim, 4, 48).unwrap();
            let h = p16.alloc_seq();
            let tag = g.usize_in(0, 999);
            let tokens = g.usize_in(1, 10);
            for t in 0..tokens {
                let mut k = Vec::new();
                let mut v = Vec::new();
                for l in 0..n_layers {
                    for hh in 0..kv_heads {
                        for side in 0..2 {
                            let row: Vec<f32> = (0..head_dim)
                                .map(|i| {
                                    ((tag + t * 89 + l * 31 + hh * 7 + side * 13 + i) % 19) as f32
                                        * 0.47
                                        - 4.0
                                })
                                .collect();
                            if side == 0 {
                                k.extend(f32_row_bytes(&row));
                            } else {
                                v.extend(f32_row_bytes(&row));
                            }
                        }
                    }
                }
                let s = vec![1.0f32; n_layers * kv_heads];
                p16.append_token(h, &k, &s, &v, &s).unwrap();
            }
            let snap16 = p16.export_seq(h).unwrap();

            let direct = snap16.transcode_to(&lay_a).unwrap();
            let via_b = snap16.transcode_to(&lay_b).unwrap().transcode_to(&lay_a).unwrap();
            assert_eq!(via_b, direct, "two-hop (via {lay_b}) != one-hop to {lay_a}");

            for s in [&snap16, &direct] {
                assert_eq!(
                    s.bytes_by_rung().iter().sum::<usize>(),
                    s.code_bytes() + s.scales.len() * 4,
                    "per-rung extents must sum to the wire bytes at {}",
                    s.layout
                );
            }

            // Import into a pool admitted at A, export, compare.
            let mut pa = KvPool::with_layout(lay_a.clone(), kv_heads, head_dim, 4, 48).unwrap();
            let ha = pa.alloc_seq();
            pa.import_seq(ha, &direct).unwrap();
            assert_eq!(pa.export_seq(ha).unwrap(), direct, "import/export round trip at {lay_a}");

            if ranks_b != ranks_a {
                // Some layer strictly widens: the reverse transcode and the
                // cross-layout import must both refuse.
                assert!(
                    direct.transcode_to(&lay_b).is_err(),
                    "upward {lay_a} → {lay_b} must fail"
                );
                let snap_b = snap16.transcode_to(&lay_b).unwrap();
                let hb = pa.alloc_seq();
                assert!(
                    pa.import_seq(hb, &snap_b).is_err(),
                    "pool at {lay_a} must reject a {lay_b} snapshot"
                );
            }
        });
    }

    #[test]
    fn prop_refcounted_blocks_never_leak_or_double_free() {
        // Randomized alloc/append/fork/free interleavings, including an
        // external retainer (the prefix index role). Invariants checked
        // after every op:
        //   * free + used == total;
        //   * each block's ref count equals its occurrences across live
        //     sequences plus external retains;
        //   * exactly the zero-ref blocks are free.
        run_prop("kvpool-refcount", 0x5EED_B10C, 40, |g| {
            let mut p = KvPool::new(KvPrecision::Int8, 1, 1, 4, 2, 24).unwrap();
            let total = p.total_blocks();
            let mut live: Vec<SeqHandle> = Vec::new();
            let mut retained: Vec<usize> = Vec::new();

            let check = |p: &KvPool, live: &[SeqHandle], retained: &[usize]| {
                assert_eq!(p.free_blocks() + p.used_blocks(), total);
                let mut expect = vec![0u32; total];
                for &h in live {
                    for &b in p.seq_blocks(h) {
                        expect[b] += 1;
                    }
                }
                for &b in retained {
                    expect[b] += 1;
                }
                let mut zero_ref = 0usize;
                for b in 0..total {
                    assert_eq!(p.block_ref_count(b), expect[b], "block {b} refcount");
                    if expect[b] == 0 {
                        zero_ref += 1;
                    }
                }
                assert_eq!(p.free_blocks(), zero_ref, "free list == zero-ref blocks");
            };

            for _ in 0..g.usize_in(10, 50) {
                match g.usize_in(0, 4) {
                    0 => {
                        live.push(p.alloc_seq());
                    }
                    1 if !live.is_empty() => {
                        let h = *g.choose(&live);
                        for t in 0..g.usize_in(1, 4) {
                            let k = vec![t as u8; 4];
                            let s = vec![1.0f32];
                            if p.append_token(h, &k, &s, &k, &s).is_err() {
                                break; // exhausted — fine, accounting must still hold
                            }
                        }
                    }
                    2 if !live.is_empty() => {
                        let h = *g.choose(&live);
                        if let Ok(nh) = p.fork_seq(h) {
                            live.push(nh);
                        }
                    }
                    3 if !live.is_empty() => {
                        let i = g.usize_in(0, live.len() - 1);
                        let h = live.remove(i);
                        // Sometimes pin a block first, like the prefix index.
                        if g.bool() {
                            if let Some(&b) = p.seq_blocks(h).first() {
                                p.retain_block(b);
                                retained.push(b);
                            }
                        }
                        p.free_seq(h);
                    }
                    4 if !retained.is_empty() => {
                        let i = g.usize_in(0, retained.len() - 1);
                        let b = retained.remove(i);
                        p.release_block(b);
                    }
                    _ => {}
                }
                check(&p, &live, &retained);
            }
            for h in live.drain(..) {
                p.free_seq(h);
            }
            for b in retained.drain(..) {
                p.release_block(b);
            }
            assert_eq!(p.free_blocks(), total, "everything reclaimed");
        });
    }

    fn f32_row_bytes(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn relayout_transcode_is_bit_identical_to_direct_quantization() {
        use crate::quant::{quantize_kv_int4, quantize_kv_int8};
        // kv16 pool (2 layers, 2 heads, head_dim 8): rows are exact floats.
        let mut p = pool(KvPrecision::F32);
        let h = p.alloc_seq();
        let row = |t: usize, l: usize, hh: usize, side: usize| -> Vec<f32> {
            (0..8)
                .map(|i| ((t * 131 + l * 17 + hh * 5 + side * 3 + i) % 23) as f32 * 0.31 - 3.0)
                .collect()
        };
        for t in 0..6 {
            let mut k = Vec::new();
            let mut v = Vec::new();
            for l in 0..2 {
                for hh in 0..2 {
                    k.extend(f32_row_bytes(&row(t, l, hh, 0)));
                    v.extend(f32_row_bytes(&row(t, l, hh, 1)));
                }
            }
            let s = vec![1.0f32; 4];
            p.append_token(h, &k, &s, &v, &s).unwrap();
        }
        let total16 = p.total_blocks();

        // Step down layer 1 only: kv16 → l0:kv16,l1:kv8.
        let mid = KvLayout::parse("l0:kv16,l1:kv8", 2).unwrap();
        let rep = p.relayout(&mid).unwrap();
        assert_eq!(rep.transcoded_blocks, 2, "both resident blocks moved");
        assert!(rep.gained_blocks > 0 && rep.transcoded_bytes > 0);
        assert_eq!(p.total_blocks(), total16 + rep.gained_blocks);
        assert_eq!(p.layout(), &mid);

        let t_pad = 8;
        let gather = |p: &KvPool| {
            let sum_rb: usize = (0..2).map(|l| p.row_bytes_at(l)).sum();
            let mut k_out = vec![0u8; 2 * t_pad * sum_rb];
            let mut v_out = k_out.clone();
            let mut ks_out = vec![0f32; 2 * 2 * t_pad];
            let mut vs_out = ks_out.clone();
            p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
                .unwrap();
            (k_out, ks_out, v_out, vs_out)
        };
        let (k_out, ks_out, _, _) = gather(&p);
        // Layer 0 is untouched f32 bytes; layer 1 codes + scales must be
        // bit-identical to quantizing the original rows directly at kv8.
        for t in 0..6 {
            for hh in 0..2 {
                let rb0 = 32;
                let dst0 = ((hh * t_pad) + t) * rb0;
                assert_eq!(&k_out[dst0..dst0 + rb0], &f32_row_bytes(&row(t, 0, hh, 0))[..]);
                let (c8, s8) = quantize_kv_int8(&row(t, 1, hh, 0));
                let rb1 = 8;
                let base1 = 2 * t_pad * 32;
                let dst1 = base1 + (hh * t_pad + t) * rb1;
                assert_eq!(
                    &k_out[dst1..dst1 + rb1],
                    &c8.iter().map(|&c| c as u8).collect::<Vec<u8>>()[..]
                );
                let sdst = ((1 * 1 + 0) * 2 + hh) * t_pad + t;
                assert_eq!(ks_out[sdst].to_bits(), s8.to_bits());
            }
        }

        // Second rung: l0 kv16→kv4 direct, l1 kv8→kv4 from resident codes.
        // Both must land bitwise on direct kv4 quantization (the nested-int4
        // transitivity the restart determinism contract needs).
        let lo = KvLayout::uniform(KvPrecision::Int4, 2);
        p.relayout(&lo).unwrap();
        let (k_out, ks_out, v_out, vs_out) = gather(&p);
        for t in 0..6 {
            for l in 0..2 {
                for hh in 0..2 {
                    let (c4k, s4k) = quantize_kv_int4(&row(t, l, hh, 0));
                    let (c4v, s4v) = quantize_kv_int4(&row(t, l, hh, 1));
                    let rb = 4;
                    let base = l * 2 * t_pad * rb;
                    let dst = base + (hh * t_pad + t) * rb;
                    assert_eq!(&k_out[dst..dst + rb], &c4k[..], "t{t} l{l} h{hh} K");
                    assert_eq!(&v_out[dst..dst + rb], &c4v[..], "t{t} l{l} h{hh} V");
                    let sdst = (l * 2 + hh) * t_pad + t;
                    assert_eq!(ks_out[sdst].to_bits(), s4k.to_bits());
                    assert_eq!(vs_out[sdst].to_bits(), s4v.to_bits());
                }
            }
        }
        assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());
        p.free_seq(h);
        assert_eq!(p.free_blocks(), p.total_blocks());
    }

    #[test]
    fn truncate_seq_releases_tail_blocks() {
        let mut p = pool(KvPrecision::Int8);
        let h = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 5);
        for _ in 0..10 {
            p.append_token(h, &k, &ks, &v, &vs).unwrap(); // 3 blocks: 4+4+2
        }
        assert_eq!(p.free_blocks(), 5);
        assert!(p.truncate_seq(h, 5).is_err(), "non-block-multiple keep");
        assert!(p.truncate_seq(h, 12).is_err(), "keep beyond len");
        assert_eq!(p.truncate_seq(h, 4).unwrap(), 2);
        assert_eq!(p.seq_len(h), 4);
        assert_eq!(p.free_blocks(), 7);
        // Appending after a truncate opens a fresh block cleanly.
        p.append_token(h, &k, &ks, &v, &vs).unwrap();
        assert_eq!(p.seq_len(h), 5);
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(p.truncate_seq(h, 0).unwrap(), 2);
        assert_eq!(p.free_blocks(), 8);
        p.free_seq(h);
        assert_eq!(p.live_seqs(), 0);
    }

    #[test]
    fn relayout_preserves_sharing_and_rejects_upward_moves() {
        let mut p = pool(KvPrecision::Int8);
        let h1 = p.alloc_seq();
        let (k, ks, v, vs) = tok_data(&p, 6);
        for _ in 0..6 {
            p.append_token(h1, &k, &ks, &v, &vs).unwrap();
        }
        let h2 = p.fork_seq(h1).unwrap();
        let shared = p.seq_blocks(h1).to_vec();
        let used = p.used_blocks();

        let rep = p.relayout(&KvLayout::uniform(KvPrecision::Int4, 2)).unwrap();
        assert_eq!(rep.transcoded_blocks, used);
        assert_eq!(p.seq_blocks(h1), shared.as_slice(), "block ids preserved");
        assert_eq!(p.seq_blocks(h2), shared.as_slice());
        for &b in &shared {
            assert_eq!(p.block_ref_count(b), 2, "sharing survives the ladder");
        }
        assert_eq!(p.free_blocks() + p.used_blocks(), p.total_blocks());

        // Both forks still gather identical bytes at the new layout.
        let t_pad = 8;
        let gather = |p: &KvPool, h| {
            let rb = p.row_bytes();
            let mut k_out = vec![0u8; 2 * 2 * t_pad * rb];
            let mut v_out = k_out.clone();
            let mut ks_out = vec![0f32; 2 * 2 * t_pad];
            let mut vs_out = ks_out.clone();
            p.gather_batch(&[Some(h)], t_pad, &mut k_out, &mut ks_out, &mut v_out, &mut vs_out)
                .unwrap();
            (k_out, ks_out, v_out, vs_out)
        };
        assert_eq!(gather(&p, h1), gather(&p, h2));

        assert!(
            p.relayout(&KvLayout::uniform(KvPrecision::Int8, 2)).is_err(),
            "no up-laddering"
        );
        p.free_seq(h1);
        p.free_seq(h2);
        assert_eq!(p.free_blocks(), p.total_blocks());
    }
}

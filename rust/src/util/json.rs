//! Minimal JSON reader/writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), the TCP
//! serving protocol, and bench-result dumps. Supports the full JSON value
//! model; numbers are kept as `f64` (the manifest only carries shapes and
//! names, well within exact-integer range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize to a compact string.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity; emit null rather than a
                    // bare token that corrupts the document.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (k, x) in xs.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_escaped(key, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field accessors used by the manifest loader.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError(format!("missing string field `{key}`")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| JsonError(format!("missing integer field `{key}`")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| JsonError(format!("missing array field `{key}`")))
    }
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a `Json::Arr` from an iterator of values.
pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
    Json::Arr(items.into_iter().collect())
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// JSON parse/shape error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our payloads;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-12", "3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{1}".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∞"));
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(2.5).dump(), "2.5");
    }

    #[test]
    fn non_finite_dumps_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        // The resulting document stays parseable.
        let doc = Json::Obj([("x".to_string(), Json::Num(f64::NAN))].into_iter().collect());
        assert_eq!(Json::parse(&doc.dump()).unwrap().get("x"), Some(&Json::Null));
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 7);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.req_str("missing").is_err());
        assert!(v.req_usize("s").is_err());
    }
}

//! Small self-contained utilities shared across the crate.
//!
//! The build environment is fully offline, so substrates that would normally
//! come from crates.io (`rand`, `serde_json`, `clap`, `proptest`) are
//! implemented here from scratch: a deterministic PRNG, a minimal JSON
//! reader/writer, a CLI argument parser, and a tiny property-testing driver.

pub mod args;
pub mod json;
pub mod proptest;
pub mod rng;

/// Round `x` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn ceil_to(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Ceiling division for `usize`.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Format a byte count with binary units (e.g. `1.50 MiB`).
pub fn human_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit + 1 < UNITS.len() {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

/// Format a duration in seconds with an adaptive unit (`ns`/`µs`/`ms`/`s`).
pub fn human_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_helpers() {
        assert_eq!(ceil_to(0, 8), 0);
        assert_eq!(ceil_to(1, 8), 8);
        assert_eq!(ceil_to(8, 8), 8);
        assert_eq!(ceil_to(9, 8), 16);
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(2.5e-9).ends_with("ns"));
        assert!(human_time(2.5e-6).ends_with("µs"));
        assert!(human_time(2.5e-3).ends_with("ms"));
        assert!(human_time(2.5).ends_with('s'));
    }
}

//! Tiny CLI argument parser (the offline build has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    named: BTreeMap<String, String>,
    /// Every `--key value` occurrence in order (repeatable options like
    /// `--replica-spec` keep all values; `named` keeps the last).
    named_all: Vec<(String, String)>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (not including argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.named.insert(k.to_string(), v.to_string());
                    out.named_all.push((k.to_string(), v.to_string()));
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.flags.push(body.to_string());
                    } else {
                        let v = it.next().unwrap();
                        out.named.insert(body.to_string(), v.clone());
                        out.named_all.push((body.to_string(), v));
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env(flag_names: &[&str]) -> Self {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.named.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Every value a repeatable option was given, in order (empty when the
    /// option never appeared).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.named_all
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str, flags: &[&str]) -> Args {
        Args::parse(s.split_whitespace().map(String::from), flags)
    }

    #[test]
    fn key_value_forms() {
        let a = parse("--model qwen3-8b --rate=2.5 serve", &[]);
        assert_eq!(a.get("model"), Some("qwen3-8b"));
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
        assert_eq!(a.positional(), ["serve"]);
    }

    #[test]
    fn flags_detected() {
        let a = parse("--verbose --n 3", &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn flag_before_another_option() {
        // --dry is not declared, but is followed by another option, so it is
        // treated as a flag rather than swallowing `--n`.
        let a = parse("--dry --n 3", &[]);
        assert!(a.flag("dry"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--n 3 --last", &[]);
        assert!(a.flag("last"));
    }

    #[test]
    fn repeated_options_keep_all_values() {
        let a = parse("--spec w4a16,kv8,a100 --spec w8a8,kv16,h100 --n 3", &[]);
        assert_eq!(a.get_all("spec"), ["w4a16,kv8,a100", "w8a8,kv16,h100"]);
        assert_eq!(a.get("spec"), Some("w8a8,kv16,h100"), "last wins for get()");
        assert!(a.get_all("missing").is_empty());
        // `--key=value` form participates too.
        let b = parse("--spec=one --spec=two", &[]);
        assert_eq!(b.get_all("spec"), ["one", "two"]);
    }

    #[test]
    fn defaults() {
        let a = parse("", &[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 9), 9);
        assert!(!a.flag("z"));
    }
}

//! Minimal property-testing driver (the offline build has no `proptest`).
//!
//! A property is a closure over a [`Gen`]; the driver runs it for a fixed
//! number of deterministic cases and, on failure, reports the case seed so
//! the exact input can be replayed by seeding a `Gen` directly.
//!
//! This intentionally skips shrinking: cases are seeded independently, so a
//! failure is already reproducible from its printed seed, which has proven
//! sufficient for the coordinator/kv-cache invariants checked in this repo.

use super::rng::Rng;

/// Case-local generator handed to properties.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.uniform(lo as f64, hi as f64) as f32).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` deterministic cases derived from `base_seed`.
/// Panics (failing the enclosing test) with the case seed on first failure.
pub fn run_prop(name: &str, base_seed: u64, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64)
            .wrapping_mul(0xD1B54A32D192ED03);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property `{name}` failed on case {case} (replay seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop("sum-commutes", 1, 50, |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            run_prop("always-fails", 2, 3, |_| panic!("boom"));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<usize> = vec![];
        run_prop("collect", 3, 10, |g| first.push(g.usize_in(0, 1 << 30)));
        let mut second: Vec<usize> = vec![];
        run_prop("collect", 3, 10, |g| second.push(g.usize_in(0, 1 << 30)));
        assert_eq!(first, second);
    }
}

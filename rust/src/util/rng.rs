//! Deterministic pseudo-random number generation.
//!
//! All stochastic components of the stack (workload arrivals, synthetic
//! weights, sampling, property tests) draw from this xoshiro256**-based
//! generator so every experiment is reproducible from a single seed — the
//! paper's Poisson-workload methodology (§5.1) depends on replayable traces.

/// xoshiro256** PRNG. Deterministic, seedable, and fast enough for the
/// request-path sampler.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // splitmix64 never yields all-zero state for distinct constants, but
        // guard anyway: xoshiro must not be seeded with all zeros.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Self { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be positive.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded rejection is overkill here; modulo bias is
        // negligible for n << 2^64 and determinism is what matters.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample with the given mu/sigma of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential inter-arrival gap for a Poisson process at `rate` (per
    /// second). Returns the gap in seconds.
    pub fn exp_gap(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.range(3, 9);
            assert!((3..=9).contains(&y));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_gap_mean_matches_rate() {
        let mut r = Rng::new(5);
        let rate = 4.0;
        let n = 50_000;
        let total: f64 = (0..n).map(|_| r.exp_gap(rate)).sum();
        let mean = total / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}

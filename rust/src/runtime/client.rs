//! PJRT runtime: load HLO-text artifacts, keep compiled executables and
//! weight literals resident, execute graphs from the request path.
//!
//! Pattern from `/opt/xla-example/load_hlo/`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Weight literals are created once at load; graph executables are compiled
//! lazily on first use and cached (one executable per (variant, batch/chunk)
//! — the "one compiled executable per model variant" rule).

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{GraphEntry, Manifest};
use super::tensor::{Dt, HostTensor};

/// Upload a host tensor as a device buffer (typed path; dims carry the
/// element count, bytes are reinterpreted per dtype).
fn upload(client: &PjRtClient, t: &HostTensor) -> Result<PjRtBuffer> {
    let r = match t.dtype {
        Dt::F32 => {
            let v = t.as_f32()?;
            client.buffer_from_host_buffer(&v, &t.shape, None)
        }
        Dt::I32 => {
            let v = t.as_i32()?;
            client.buffer_from_host_buffer(&v, &t.shape, None)
        }
        Dt::I8 => {
            // i8 has the same layout as the raw bytes.
            let v: Vec<i8> = t.data.iter().map(|&b| b as i8).collect();
            client.buffer_from_host_buffer(&v, &t.shape, None)
        }
        Dt::U8 => client.buffer_from_host_buffer(&t.data, &t.shape, None),
    };
    r.map_err(|e| anyhow!("input upload: {e:?}"))
}

/// The runtime: PJRT client + manifest + resident weights + executable cache.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    /// Weight tensors resident as **device buffers** per precision key
    /// ("w16"/"w4"), by tensor name. Uploaded once at load; `execute_b`
    /// consumes them without per-call host→device copies (§Perf: weights
    /// are by far the largest per-call operands).
    weights: BTreeMap<String, BTreeMap<String, PjRtBuffer>>,
    /// Compiled executables by graph name (interior mutability: compiling is
    /// a caching detail, callers keep `&Runtime`).
    executables: RefCell<BTreeMap<String, PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Load the manifest and weight binaries; no graphs compiled yet.
    pub fn load(artifacts_dir: &str) -> Result<Self> {
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let manifest = Manifest::load(artifacts_dir)?;

        let mut weights = BTreeMap::new();
        for (prec, wf) in &manifest.weights {
            let bin = std::fs::read(manifest.dir.join(&wf.file))
                .with_context(|| format!("reading {}", wf.file))?;
            let mut tensors = BTreeMap::new();
            for t in &wf.tensors {
                let slice = bin
                    .get(t.offset..t.offset + t.nbytes)
                    .ok_or_else(|| anyhow!("weight {} out of range in {}", t.name, wf.file))?;
                let host = HostTensor::new(t.dtype, t.shape.clone(), slice.to_vec())?;
                let buf = upload(&client, &host)
                    .map_err(|e| anyhow!("uploading weight {}: {e}", t.name))?;
                tensors.insert(t.name.clone(), buf);
            }
            weights.insert(prec.clone(), tensors);
        }

        Ok(Self { client, manifest, weights, executables: RefCell::new(BTreeMap::new()) })
    }

    /// Compile (or fetch cached) a graph by name.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let graph = self.graph(name)?.clone();
        let path = self.manifest.hlo_path(&graph);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of graphs (warm-up; keeps first-request latency flat).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    pub fn graph(&self, name: &str) -> Result<&GraphEntry> {
        self.manifest
            .graphs
            .get(name)
            .ok_or_else(|| anyhow!("graph `{name}` not in manifest (available: {:?})",
                self.manifest.graphs.keys().take(8).collect::<Vec<_>>()))
    }

    /// Execute a graph: dynamic inputs (validated against the manifest
    /// signature) followed by the resident weight literals. Returns the
    /// tuple outputs as host tensors.
    pub fn execute(&self, name: &str, dynamic_inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let graph = self.graph(name)?;

        // Validate the dynamic inputs against the signature.
        if dynamic_inputs.len() != graph.inputs.len() {
            bail!(
                "graph {name}: {} dynamic inputs given, signature has {}",
                dynamic_inputs.len(),
                graph.inputs.len()
            );
        }
        for (got, spec) in dynamic_inputs.iter().zip(&graph.inputs) {
            if got.shape != spec.shape || got.dtype != spec.dtype {
                bail!(
                    "graph {name}: input `{}` expects {:?}{:?}, got {:?}{:?}",
                    spec.name, spec.dtype, spec.shape, got.dtype, got.shape
                );
            }
        }

        // Dynamic inputs become fresh device buffers; weights are already
        // resident (uploaded once at load).
        let dyn_bufs: Vec<PjRtBuffer> = dynamic_inputs
            .iter()
            .map(|t| self.host_to_buffer(t))
            .collect::<Result<_>>()?;
        let mut args: Vec<&PjRtBuffer> = dyn_bufs.iter().collect();
        if !graph.weight_inputs.is_empty() {
            let prec = Manifest::weight_precision_of(name);
            let wmap = self
                .weights
                .get(prec)
                .ok_or_else(|| anyhow!("no weights for precision `{prec}`"))?;
            for wname in &graph.weight_inputs {
                let buf = wmap
                    .get(wname)
                    .ok_or_else(|| anyhow!("weight `{wname}` missing"))?;
                args.push(buf);
            }
        }

        let exes = self.executables.borrow();
        let exe = exes.get(name).expect("ensured above");
        let result = exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} outputs: {e:?}"))?;
        // Graphs are lowered with return_tuple=True.
        let parts = out_lit.to_tuple().map_err(|e| anyhow!("untupling: {e:?}"))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Upload a host tensor as a device buffer.
    fn host_to_buffer(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        upload(&self.client, t)
    }

    /// Names of every graph in the manifest (for warmup / diagnostics).
    pub fn graph_names(&self) -> Vec<String> {
        self.manifest.graphs.keys().cloned().collect()
    }

    pub fn compiled_count(&self) -> usize {
        self.executables.borrow().len()
    }
}

//! `SimBackend`: deterministic pure-Rust model execution.
//!
//! The hermetic stand-in for the PJRT backend: no artifacts, no Python, no
//! network. It drives the *entire* engine path — gathered quantized KV in,
//! logits + fresh quantized KV codes out — with three properties the tests
//! rely on:
//!
//! 1. **Determinism.** Every value derives from the backend seed and the
//!    request content. Same seed + greedy sampling ⇒ identical outputs,
//!    regardless of batch composition or scheduler policy (each batch slot
//!    is computed independently; padding slots never influence real ones).
//! 2. **Precision fidelity.** The configured [`PrecisionFormat`] shapes the
//!    numbers through the real `quant` round-trip error models: weights are
//!    passed through [`QuantizedMatrix`] groupwise quantization at the
//!    configured weight width, and KV rows are quantized per token per head
//!    with [`quant::quantize_kv_int8`] / [`quant::quantize_kv_int4`] before
//!    they enter the pool — decode reads them back *through the cache*, so
//!    KV4/KV8/KV16 genuinely diverge the way the paper's Table 1 studies.
//! 3. **Modeled latency.** Each invocation reports the iteration time the
//!    `serving_sim`/`gpusim` cost models predict for the tiny model on an
//!    A100 with TurboMind kernel traits (activation width participates
//!    here: W4A8 times differently from W4A16 even though the sim numerics
//!    model weights and KV only).
//!
//! The "transformer" itself is a seeded recency-weighted mixer: token
//! (l, h, position) K/V rows are hash-seeded pseudo-random vectors; a
//! per-position context is the exponentially-decayed sum of dequantized KV
//! rows; logits are the context (plus the input token's embedding) projected
//! through a seeded, precision-round-tripped output embedding. It is not a
//! language model — it is a deterministic function with the same dataflow,
//! shapes, and precision sensitivities as one.

use anyhow::bail;

use super::backend::{
    DecodeArgs, ExecutionBackend, ExecutionPlan, ModelSpec, PrefillArgs, StepOutputs,
};
use crate::config::{DType, DeviceProfile, ModelConfig, PrecisionFormat};
use crate::gpusim::Framework;
use crate::kvcache::{KvLayout, KvPrecision};
use crate::quant::{self, GroupwiseQuant, QuantizedMatrix};
use crate::serving_sim::{ServingSim, SimConfig, SimPrecision};
use crate::util::rng::Rng;
use crate::Result;

/// Exponential recency decay of the context mixer (per position step).
const DECAY: f32 = 0.9;
/// Weight of V rows relative to K rows in the context mixer.
const V_WEIGHT: f32 = 0.5;

/// The deterministic simulation backend.
pub struct SimBackend {
    model: ModelSpec,
    plan: ExecutionPlan,
    precision: PrecisionFormat,
    kv_prec: KvPrecision,
    seed: u64,
    /// Input-token embedding `[vocab, head_dim]`, weight-round-tripped.
    embed_in: Vec<f32>,
    /// Output projection `[vocab, head_dim]`, weight-round-tripped.
    embed_out: Vec<f32>,
    /// Iteration-latency model (gpusim kernel models at the tiny scale).
    timing: ServingSim,
}

impl SimBackend {
    /// Build a sim backend for `model` at `precision`. `max_batch` sizes the
    /// decode-batch buckets (mirroring "one compiled executable per batch
    /// size"). Fails for formats the sim has no numeric model for (FP8
    /// weights).
    pub fn new(
        model: ModelSpec,
        precision: PrecisionFormat,
        seed: u64,
        max_batch: usize,
    ) -> Result<Self> {
        Self::with_device(model, precision, seed, max_batch, DeviceProfile::a100(), 1)
    }

    /// Build a sim backend whose iteration-latency model runs on `dev` at
    /// tensor-parallel degree `tp` (the numerics are device-independent —
    /// only the modeled `sim_time_s` changes). This is what lets a
    /// precision-heterogeneous cluster model an A100 w4a16/kv8 replica next
    /// to an H100 w8a8/kv16 one.
    pub fn with_device(
        model: ModelSpec,
        precision: PrecisionFormat,
        seed: u64,
        max_batch: usize,
        dev: DeviceProfile,
        tp: usize,
    ) -> Result<Self> {
        if precision.weight == DType::Fp8 {
            bail!("sim backend has no numeric model for fp8 weights (format {precision})");
        }
        let kv_prec = KvPrecision::from_dtype(precision.kv)?;
        let plan = plan_for(&model, max_batch);

        let dim = model.head_dim;
        let vocab = model.vocab_size;
        let embed_in = embedding_table(seed ^ 0x5EED_E4B0, vocab, dim, &model, precision.weight);
        let embed_out = embedding_table(seed ^ 0x0E0E_D00D, vocab, dim, &model, precision.weight);

        let sim_prec = SimPrecision {
            w_bits: precision.weight.bits(),
            a_bits: precision.activation.bits(),
            kv_bits: precision.kv.bits(),
        };
        let mut timing_cfg =
            SimConfig::new(model_config_of(&model), dev, Framework::TurboMind, sim_prec);
        timing_cfg.tp = tp;
        let timing = ServingSim::new(timing_cfg);

        Ok(Self { model, plan, precision, kv_prec, seed, embed_in, embed_out, timing })
    }

    #[cfg(test)]
    fn rb(&self) -> usize {
        self.kv_prec.row_bytes(self.model.head_dim)
    }

    /// The uniform per-layer layout implied by the configured format's KV
    /// dtype — what an engine admits at when no `--kv-layout` is given.
    pub fn default_layout(&self) -> KvLayout {
        KvLayout::uniform(self.kv_prec, self.model.n_layers)
    }

    /// The deterministic "true" (pre-quantization) K and V rows for token
    /// `tok` at absolute position `pos` in layer `l`, KV head `h`.
    fn true_rows(&self, l: usize, h: usize, tok: i32, pos: usize) -> (Vec<f32>, Vec<f32>) {
        let mut s = self.seed ^ 0x7D0_C0FFEE;
        for v in [l as u64, h as u64, tok as u32 as u64, pos as u64] {
            s = s
                .wrapping_mul(0x100_0000_01B3)
                .wrapping_add(v)
                .rotate_left(23)
                .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        }
        let mut rng = Rng::new(s);
        let d = self.model.head_dim;
        let k = (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let v = (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        (k, v)
    }

    /// Quantize one row for layer storage at `prec`: (codes, scale).
    fn quantize_row_at(prec: KvPrecision, row: &[f32]) -> (Vec<u8>, f32) {
        match prec {
            KvPrecision::F32 => {
                let mut bytes = Vec::with_capacity(row.len() * 4);
                for x in row {
                    bytes.extend_from_slice(&x.to_le_bytes());
                }
                (bytes, 1.0)
            }
            KvPrecision::Int8 => {
                let (codes, scale) = quant::quantize_kv_int8(row);
                (codes.into_iter().map(|c| c as u8).collect(), scale)
            }
            KvPrecision::Int4 => quant::quantize_kv_int4(row),
        }
    }

    /// Quantize one row at the backend's uniform default precision (test
    /// helper; the serving path quantizes per layer via `quantize_row_at`).
    #[cfg(test)]
    fn quantize_row(&self, row: &[f32]) -> (Vec<u8>, f32) {
        Self::quantize_row_at(self.kv_prec, row)
    }

    /// Dequantize one cached row (`row_bytes(prec)` code bytes + scalar
    /// scale) into a caller-owned scratch buffer of `head_dim` elements —
    /// the context scans run this per (layer, head, token), so no per-row
    /// allocation.
    fn dequantize_row_into(prec: KvPrecision, codes: &[u8], scale: f32, out: &mut [f32]) {
        match prec {
            KvPrecision::F32 => {
                for (o, c) in out.iter_mut().zip(codes.chunks_exact(4)) {
                    *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
            KvPrecision::Int8 => {
                for (o, &b) in out.iter_mut().zip(codes) {
                    *o = b as i8 as f32 * scale;
                }
            }
            KvPrecision::Int4 => {
                for (i, o) in out.iter_mut().enumerate() {
                    let byte = codes[i / 2];
                    let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                    *o = quant::groupwise::sign_extend4(nib) as f32 * scale;
                }
            }
        }
    }

    /// Accumulate one (K, V) row into a decayed context sum.
    fn fold_row(ctx: &mut [f32], k: &[f32], v: &[f32]) {
        for (c, (kx, vx)) in ctx.iter_mut().zip(k.iter().zip(v)) {
            *c = *c * DECAY + (kx + V_WEIGHT * vx);
        }
    }

    /// Decayed normalization constant for a context of `len` rows.
    fn norm(len: usize) -> f32 {
        // Σ_{age=0..len-1} DECAY^age = (1 - DECAY^len) / (1 - DECAY)
        (1.0 - DECAY.powi(len as i32)) / (1.0 - DECAY)
    }

    /// Logits for an input token given its (already normalized) context.
    fn project_logits(&self, tok: i32, ctx: &[f32], out: &mut [f32]) {
        let d = self.model.head_dim;
        let e_in = &self.embed_in[tok as usize * d..(tok as usize + 1) * d];
        for (v, o) in out.iter_mut().enumerate() {
            let e_out = &self.embed_out[v * d..(v + 1) * d];
            let mut acc = 0f32;
            for i in 0..d {
                acc += (e_in[i] + ctx[i]) * e_out[i];
            }
            *o = acc;
        }
    }

    /// The per-(l, h) decayed sum of one sequence's cached rows
    /// `[0, kv_len)` read back through the quantized cache, for batch slot
    /// `bi` of a gathered `[L, B, Hkv, t_pad, rb(l)]` tensor set at the
    /// given per-layer layout (layer-major, variable row stride).
    #[allow(clippy::too_many_arguments)]
    fn cached_context(
        &self,
        layout: &KvLayout,
        bi: usize,
        b: usize,
        kv_len: usize,
        t_pad: usize,
        k_codes: &[u8],
        k_scales: &[f32],
        v_codes: &[u8],
        v_scales: &[f32],
    ) -> Vec<f32> {
        let m = &self.model;
        let d = m.head_dim;
        let mut ctx = vec![0f32; d];
        let mut acc = vec![0f32; d];
        let mut k = vec![0f32; d];
        let mut v = vec![0f32; d];
        for l in 0..m.n_layers {
            let prec = layout.prec(l);
            let rb = layout.row_bytes(l, d);
            let lbase = b * m.n_kv_heads * t_pad * layout.prefix_row_bytes(l, d);
            for h in 0..m.n_kv_heads {
                acc.iter_mut().for_each(|x| *x = 0.0);
                for t in 0..kv_len {
                    let base = lbase + ((bi * m.n_kv_heads + h) * t_pad + t) * rb;
                    let sbase = ((l * b + bi) * m.n_kv_heads + h) * t_pad + t;
                    Self::dequantize_row_into(prec, &k_codes[base..base + rb], k_scales[sbase], &mut k);
                    Self::dequantize_row_into(prec, &v_codes[base..base + rb], v_scales[sbase], &mut v);
                    Self::fold_row(&mut acc, &k, &v);
                }
                for (c, a) in ctx.iter_mut().zip(&acc) {
                    *c += a;
                }
            }
        }
        let heads = (m.n_layers * m.n_kv_heads) as f32;
        ctx.iter_mut().for_each(|x| *x /= heads);
        ctx
    }

    fn check_token(&self, tok: i32) -> Result<()> {
        if tok < 0 || tok as usize >= self.model.vocab_size {
            bail!("token {tok} outside vocab {}", self.model.vocab_size);
        }
        Ok(())
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn model(&self) -> &ModelSpec {
        &self.model
    }

    fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    fn precision(&self) -> PrecisionFormat {
        self.precision
    }

    fn warmup(&self) -> Result<()> {
        Ok(())
    }

    fn prefill(&self, args: &PrefillArgs<'_>) -> Result<StepOutputs> {
        let m = &self.model;
        let d = m.head_dim;
        let layout = args.layout;
        if layout.n_layers() != m.n_layers {
            bail!("prefill layout has {} layers, model has {}", layout.n_layers(), m.n_layers);
        }
        let bucket = args.tokens.len();
        let sum_rb = layout.sum_row_bytes(d);
        let expect = m.n_kv_heads * args.t_pad * sum_rb;
        if args.k_codes.len() != expect || args.v_codes.len() != expect {
            bail!("prefill cache size {} != expected {expect}", args.k_codes.len());
        }
        if args.real == 0 || args.real > bucket {
            bail!("prefill real {} out of bucket {bucket}", args.real);
        }
        if args.pos + args.real > args.t_pad {
            bail!("prefill chunk [{}, {}) exceeds t_pad {}", args.pos, args.pos + args.real, args.t_pad);
        }

        // Fresh (exact) rows for the chunk's real tokens, plus their
        // per-layer quantized codes for the pool.
        let mut k_out = vec![0u8; m.n_kv_heads * bucket * sum_rb];
        let mut v_out = vec![0u8; m.n_kv_heads * bucket * sum_rb];
        let mut ks_out = vec![1f32; m.n_layers * m.n_kv_heads * bucket];
        let mut vs_out = vec![1f32; m.n_layers * m.n_kv_heads * bucket];
        // chunk_rows[l][h][j] = (k, v) exact rows.
        let mut chunk_rows: Vec<Vec<Vec<(Vec<f32>, Vec<f32>)>>> = Vec::with_capacity(m.n_layers);
        for l in 0..m.n_layers {
            let prec = layout.prec(l);
            let rb = layout.row_bytes(l, d);
            let lbase = m.n_kv_heads * bucket * layout.prefix_row_bytes(l, d);
            let mut per_head = Vec::with_capacity(m.n_kv_heads);
            for h in 0..m.n_kv_heads {
                let mut rows = Vec::with_capacity(args.real);
                for j in 0..args.real {
                    let tok = args.tokens[j];
                    self.check_token(tok)?;
                    let (k, v) = self.true_rows(l, h, tok, args.pos + j);
                    let (kc, ks) = Self::quantize_row_at(prec, &k);
                    let (vc, vs) = Self::quantize_row_at(prec, &v);
                    let base = lbase + (h * bucket + j) * rb;
                    k_out[base..base + rb].copy_from_slice(&kc);
                    v_out[base..base + rb].copy_from_slice(&vc);
                    let sbase = (l * m.n_kv_heads + h) * bucket + j;
                    ks_out[sbase] = ks;
                    vs_out[sbase] = vs;
                    rows.push((k, v));
                }
                per_head.push(rows);
            }
            chunk_rows.push(per_head);
        }

        // Per-(l, h) decayed sum of the quantized past, then advanced
        // incrementally through the chunk's exact rows.
        let mut sums: Vec<Vec<f32>> = Vec::with_capacity(m.n_layers * m.n_kv_heads);
        let mut k_row = vec![0f32; d];
        let mut v_row = vec![0f32; d];
        for l in 0..m.n_layers {
            let prec = layout.prec(l);
            let rb = layout.row_bytes(l, d);
            let lbase = m.n_kv_heads * args.t_pad * layout.prefix_row_bytes(l, d);
            for h in 0..m.n_kv_heads {
                let mut acc = vec![0f32; d];
                for t in 0..args.pos {
                    let base = lbase + (h * args.t_pad + t) * rb;
                    let sbase = (l * m.n_kv_heads + h) * args.t_pad + t;
                    Self::dequantize_row_into(
                        prec,
                        &args.k_codes[base..base + rb],
                        args.k_scales[sbase],
                        &mut k_row,
                    );
                    Self::dequantize_row_into(
                        prec,
                        &args.v_codes[base..base + rb],
                        args.v_scales[sbase],
                        &mut v_row,
                    );
                    Self::fold_row(&mut acc, &k_row, &v_row);
                }
                sums.push(acc);
            }
        }

        let vocab = m.vocab_size;
        let heads = (m.n_layers * m.n_kv_heads) as f32;
        let mut logits = vec![0f32; bucket * vocab];
        let mut ctx = vec![0f32; d];
        for j in 0..args.real {
            for l in 0..m.n_layers {
                for h in 0..m.n_kv_heads {
                    let (k, v) = &chunk_rows[l][h][j];
                    Self::fold_row(&mut sums[l * m.n_kv_heads + h], k, v);
                }
            }
            let norm = Self::norm(args.pos + j + 1) * heads;
            for x in ctx.iter_mut() {
                *x = 0.0;
            }
            for s in &sums {
                for (c, a) in ctx.iter_mut().zip(s) {
                    *c += a;
                }
            }
            ctx.iter_mut().for_each(|x| *x /= norm);
            self.project_logits(args.tokens[j], &ctx, &mut logits[j * vocab..(j + 1) * vocab]);
        }

        Ok(StepOutputs {
            logits,
            k_codes: k_out,
            k_scales: ks_out,
            v_codes: v_out,
            v_scales: vs_out,
            sim_time_s: self.timing.prefill_iter_time(bucket, args.pos),
        })
    }

    fn decode(&self, args: &DecodeArgs<'_>) -> Result<StepOutputs> {
        let m = &self.model;
        let layout = args.layout;
        if layout.n_layers() != m.n_layers {
            bail!("decode layout has {} layers, model has {}", layout.n_layers(), m.n_layers);
        }
        let b = args.tokens.len();
        if args.kv_len.len() != b {
            bail!("decode kv_len length {} != batch {b}", args.kv_len.len());
        }
        let d = m.head_dim;
        let sum_rb = layout.sum_row_bytes(d);
        let expect = b * m.n_kv_heads * args.t_pad * sum_rb;
        if args.k_codes.len() != expect || args.v_codes.len() != expect {
            bail!("decode cache size {} != expected {expect}", args.k_codes.len());
        }

        let vocab = m.vocab_size;
        let heads = (m.n_layers * m.n_kv_heads) as f32;
        let mut logits = vec![0f32; b * vocab];
        let mut k_out = vec![0u8; b * m.n_kv_heads * sum_rb];
        let mut v_out = vec![0u8; b * m.n_kv_heads * sum_rb];
        let mut ks_out = vec![1f32; m.n_layers * b * m.n_kv_heads];
        let mut vs_out = vec![1f32; m.n_layers * b * m.n_kv_heads];

        let mut mean_kv = 0usize;
        for bi in 0..b {
            let tok = args.tokens[bi];
            self.check_token(tok)?;
            let kv_len = args.kv_len[bi].max(0) as usize;
            if kv_len > args.t_pad {
                bail!("decode kv_len {kv_len} exceeds t_pad {}", args.t_pad);
            }
            mean_kv += kv_len;

            // Context: quantized history + this token's fresh (exact) rows;
            // the fresh rows also become the appended cache codes.
            let mut ctx = self.cached_context(
                layout, bi, b, kv_len, args.t_pad, args.k_codes, args.k_scales, args.v_codes,
                args.v_scales,
            );
            // cached_context normalized by head count only; re-scale to add
            // the fresh rows and apply the decayed norm uniformly.
            ctx.iter_mut().for_each(|x| *x *= heads);
            let mut fresh = vec![0f32; d];
            for l in 0..m.n_layers {
                let prec = layout.prec(l);
                let rb = layout.row_bytes(l, d);
                let lbase = b * m.n_kv_heads * layout.prefix_row_bytes(l, d);
                for h in 0..m.n_kv_heads {
                    let (k, v) = self.true_rows(l, h, tok, kv_len);
                    for (f, (kx, vx)) in fresh.iter_mut().zip(k.iter().zip(&v)) {
                        *f += kx + V_WEIGHT * vx;
                    }
                    let (kc, ks) = Self::quantize_row_at(prec, &k);
                    let (vc, vs) = Self::quantize_row_at(prec, &v);
                    let base = lbase + (bi * m.n_kv_heads + h) * rb;
                    k_out[base..base + rb].copy_from_slice(&kc);
                    v_out[base..base + rb].copy_from_slice(&vc);
                    let sbase = (l * b + bi) * m.n_kv_heads + h;
                    ks_out[sbase] = ks;
                    vs_out[sbase] = vs;
                }
            }
            let norm = Self::norm(kv_len + 1) * heads;
            for (c, f) in ctx.iter_mut().zip(&fresh) {
                *c = (*c * DECAY + f) / norm;
            }
            self.project_logits(tok, &ctx, &mut logits[bi * vocab..(bi + 1) * vocab]);
        }

        Ok(StepOutputs {
            logits,
            k_codes: k_out,
            k_scales: ks_out,
            v_codes: v_out,
            v_scales: vs_out,
            sim_time_s: self.timing.decode_iter_time(b, (mean_kv / b.max(1)).max(1)),
        })
    }
}

/// Shape buckets for a sim model: powers of two, PJRT-style.
fn plan_for(model: &ModelSpec, max_batch: usize) -> ExecutionPlan {
    let mut decode_batches = Vec::new();
    let mut b = 1usize;
    let cap = max_batch.max(1).next_power_of_two();
    while b <= cap {
        decode_batches.push(b);
        b *= 2;
    }
    let mut decode_t = Vec::new();
    let mut t = 64usize.min(model.max_seq_len);
    loop {
        decode_t.push(t);
        if t >= model.max_seq_len {
            break;
        }
        t = (t * 2).min(model.max_seq_len);
    }
    let chunk_cap = 256usize.min(model.max_seq_len);
    let mut prefill_chunks = Vec::new();
    let mut c = 32usize.min(chunk_cap);
    loop {
        prefill_chunks.push(c);
        if c >= chunk_cap {
            break;
        }
        c = (c * 2).min(chunk_cap);
    }
    ExecutionPlan { decode_batches, decode_t, prefill_chunks }
}

/// Seeded `[vocab, dim]` embedding table, round-tripped through groupwise
/// quantization at the configured weight width (the §4.1 error model).
fn embedding_table(
    seed: u64,
    vocab: usize,
    dim: usize,
    model: &ModelSpec,
    weight: DType,
) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let table: Vec<f32> = (0..vocab * dim).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let group = if model.group_size > 0 && vocab % model.group_size == 0 {
        model.group_size
    } else {
        vocab
    };
    match weight {
        DType::Int4 => {
            QuantizedMatrix::quantize(&table, vocab, dim, GroupwiseQuant::int4(group)).dequantize()
        }
        DType::Int8 => {
            QuantizedMatrix::quantize(&table, vocab, dim, GroupwiseQuant::int8(group)).dequantize()
        }
        _ => table,
    }
}

fn model_config_of(spec: &ModelSpec) -> ModelConfig {
    ModelConfig {
        name: spec.name.clone(),
        n_layers: spec.n_layers,
        d_model: spec.d_model,
        n_heads: spec.n_heads,
        n_kv_heads: spec.n_kv_heads,
        head_dim: spec.head_dim,
        d_ff: spec.d_ff,
        vocab_size: spec.vocab_size,
        max_seq_len: spec.max_seq_len,
        n_experts: 1,
        experts_per_token: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(format: &str) -> SimBackend {
        SimBackend::new(ModelSpec::tiny(), format.parse().unwrap(), 0, 4).unwrap()
    }

    fn empty_cache(b: &SimBackend, t_pad: usize) -> (Vec<u8>, Vec<f32>) {
        let m = b.model();
        let n = m.n_layers * m.n_kv_heads * t_pad;
        (vec![0u8; n * b.rb()], vec![1f32; n])
    }

    fn prefill_chunk(b: &SimBackend, tokens: &[i32]) -> StepOutputs {
        let t_pad = b.model().max_seq_len;
        let layout = b.default_layout();
        let (kc, ks) = empty_cache(b, t_pad);
        let (vc, vs) = (kc.clone(), ks.clone());
        let mut padded = tokens.to_vec();
        padded.resize(32, 0);
        b.prefill(&PrefillArgs {
            tokens: &padded,
            real: tokens.len(),
            pos: 0,
            t_pad,
            layout: &layout,
            k_codes: &kc,
            k_scales: &ks,
            v_codes: &vc,
            v_scales: &vs,
        })
        .unwrap()
    }

    #[test]
    fn prefill_shapes_and_determinism() {
        let b = backend("W4A16KV8");
        let out1 = prefill_chunk(&b, &[5, 17, 99]);
        let out2 = prefill_chunk(&b, &[5, 17, 99]);
        let m = b.model();
        assert_eq!(out1.logits.len(), 32 * m.vocab_size);
        assert_eq!(out1.k_codes.len(), m.n_layers * m.n_kv_heads * 32 * b.rb());
        assert_eq!(out1.k_scales.len(), m.n_layers * m.n_kv_heads * 32);
        assert_eq!(out1.logits, out2.logits, "same seed+input must be bit-identical");
        assert_eq!(out1.k_codes, out2.k_codes);
        assert!(out1.sim_time_s > 0.0, "gpusim timing must be attached");
    }

    #[test]
    fn logits_depend_on_tokens_and_weight_precision() {
        let b = backend("W4A16KV8");
        let a = prefill_chunk(&b, &[5, 17, 99]);
        let c = prefill_chunk(&b, &[5, 17, 100]);
        assert_ne!(a.logits[2 * 2048..3 * 2048], c.logits[2 * 2048..3 * 2048]);

        let w16 = backend("W16A16KV8");
        let d = prefill_chunk(&w16, &[5, 17, 99]);
        assert_ne!(
            a.logits[2 * 2048..3 * 2048],
            d.logits[2 * 2048..3 * 2048],
            "weight quantization must perturb logits"
        );
    }

    #[test]
    fn kv_precision_changes_row_bytes_not_first_chunk_logits() {
        // Chunk-1 prefill never reads the cache: logits agree across KV
        // precisions (the Table 1 "first token" equivalence) while the
        // emitted codes differ in width.
        let b8 = backend("W4A16KV8");
        let b4 = backend("W4A16KV4");
        let b16 = backend("W4A16KV16");
        let o8 = prefill_chunk(&b8, &[9, 8, 7]);
        let o4 = prefill_chunk(&b4, &[9, 8, 7]);
        let o16 = prefill_chunk(&b16, &[9, 8, 7]);
        assert_eq!(o8.logits, o4.logits);
        assert_eq!(o8.logits, o16.logits);
        assert_eq!(o4.k_codes.len() * 2, o8.k_codes.len());
        assert_eq!(o8.k_codes.len() * 4, o16.k_codes.len());
    }

    #[test]
    fn decode_reads_the_cache() {
        // Same input token, different cached histories ⇒ different logits.
        let b = backend("W4A16KV8");
        let m = b.model();
        let layout = b.default_layout();
        let t_pad = 64;
        let run = |hist_tok: i32| {
            let n = m.n_layers * m.n_kv_heads * t_pad;
            let mut kc = vec![0u8; n * b.rb()];
            let mut ks = vec![1f32; n];
            let mut vc = kc.clone();
            let mut vs = ks.clone();
            // Store one history token's rows at t=0 via the backend's own
            // quantizer to mimic the pool contents.
            for l in 0..m.n_layers {
                for h in 0..m.n_kv_heads {
                    let (k, v) = b.true_rows(l, h, hist_tok, 0);
                    let (kq, kqs) = b.quantize_row(&k);
                    let (vq, vqs) = b.quantize_row(&v);
                    let base = ((l * m.n_kv_heads + h) * t_pad) * b.rb();
                    kc[base..base + b.rb()].copy_from_slice(&kq);
                    vc[base..base + b.rb()].copy_from_slice(&vq);
                    let sbase = (l * m.n_kv_heads + h) * t_pad;
                    ks[sbase] = kqs;
                    vs[sbase] = vqs;
                }
            }
            b.decode(&DecodeArgs {
                tokens: &[42],
                kv_len: &[1],
                t_pad,
                layout: &layout,
                k_codes: &kc,
                k_scales: &ks,
                v_codes: &vc,
                v_scales: &vs,
            })
            .unwrap()
            .logits
        };
        assert_ne!(run(7), run(8), "decode logits must depend on cached KV");
    }

    #[test]
    fn batch_slots_are_independent() {
        // Slot 0's logits must not change when a second slot is added —
        // the property that makes greedy outputs scheduler-invariant.
        let b = backend("W4A16KV8");
        let m = b.model();
        let layout = b.default_layout();
        let t_pad = 64;
        let n1 = m.n_layers * m.n_kv_heads * t_pad;
        let (kc1, ks1) = (vec![0u8; n1 * b.rb()], vec![1f32; n1]);
        let solo = b
            .decode(&DecodeArgs {
                tokens: &[3],
                kv_len: &[0],
                t_pad,
                layout: &layout,
                k_codes: &kc1,
                k_scales: &ks1,
                v_codes: &kc1,
                v_scales: &ks1,
            })
            .unwrap();
        let n2 = m.n_layers * 2 * m.n_kv_heads * t_pad;
        let (kc2, ks2) = (vec![0u8; n2 * b.rb()], vec![1f32; n2]);
        let duo = b
            .decode(&DecodeArgs {
                tokens: &[3, 200],
                kv_len: &[0, 0],
                t_pad,
                layout: &layout,
                k_codes: &kc2,
                k_scales: &ks2,
                v_codes: &kc2,
                v_scales: &ks2,
            })
            .unwrap();
        assert_eq!(solo.logits[..2048], duo.logits[..2048]);
    }

    #[test]
    fn plan_buckets_cover_the_model() {
        let b = backend("W4A16KV8");
        let p = b.plan();
        assert!(p.decode_batches.contains(&4));
        assert_eq!(*p.decode_t.last().unwrap(), b.model().max_seq_len);
        assert!(p.prefill_chunks.contains(&128));
    }

    #[test]
    fn device_changes_timing_not_numerics() {
        // A heterogeneous fleet's replicas must stay bit-compatible: the
        // device profile only scales the modeled iteration latency.
        let a100 = backend("W4A16KV8");
        let h100 = SimBackend::with_device(
            ModelSpec::tiny(),
            "W4A16KV8".parse().unwrap(),
            0,
            4,
            DeviceProfile::h100(),
            1,
        )
        .unwrap();
        let oa = prefill_chunk(&a100, &[5, 17, 99]);
        let oh = prefill_chunk(&h100, &[5, 17, 99]);
        assert_eq!(oa.logits, oh.logits, "numerics are device-independent");
        assert_eq!(oa.k_codes, oh.k_codes);
        assert!(oh.sim_time_s < oa.sim_time_s, "H100 models faster than A100");
    }

    #[test]
    fn fp8_weights_rejected() {
        let err = SimBackend::new(ModelSpec::tiny(), "W8FA16KV8".parse().unwrap(), 0, 4)
            .unwrap_err();
        assert!(err.to_string().contains("fp8"), "{err}");
    }

    #[test]
    fn bad_tokens_rejected() {
        let b = backend("W4A16KV8");
        let t_pad = b.model().max_seq_len;
        let layout = b.default_layout();
        let (kc, ks) = empty_cache(&b, t_pad);
        let err = b
            .prefill(&PrefillArgs {
                tokens: &[9999; 32],
                real: 1,
                pos: 0,
                t_pad,
                layout: &layout,
                k_codes: &kc,
                k_scales: &ks,
                v_codes: &kc,
                v_scales: &ks,
            })
            .unwrap_err();
        assert!(err.to_string().contains("vocab"), "{err}");
    }

    #[test]
    fn mixed_layout_prefill_emits_per_layer_widths() {
        // A per-layer ladder layout: first chunk reads no cache, so logits
        // agree with the uniform run, while emitted codes shrink to the
        // per-layer widths and kv16 layers keep unit scales.
        let b = backend("W4A16KV16");
        let m = b.model().clone();
        let mixed = KvLayout::parse("l0:kv16,l1:kv8,l2:kv8,l3:kv4", m.n_layers).unwrap();
        let t_pad = m.max_seq_len;
        let sum_rb = mixed.sum_row_bytes(m.head_dim);
        let kc = vec![0u8; m.n_kv_heads * t_pad * sum_rb];
        let ks = vec![1f32; m.n_layers * m.n_kv_heads * t_pad];
        let mut padded = vec![9, 8, 7];
        padded.resize(32, 0);
        let out = b
            .prefill(&PrefillArgs {
                tokens: &padded,
                real: 3,
                pos: 0,
                t_pad,
                layout: &mixed,
                k_codes: &kc,
                k_scales: &ks,
                v_codes: &kc,
                v_scales: &ks,
            })
            .unwrap();
        assert_eq!(out.k_codes.len(), m.n_kv_heads * 32 * sum_rb);
        let uniform = prefill_chunk(&b, &[9, 8, 7]);
        assert_eq!(out.logits, uniform.logits, "first chunk is cache-independent");
        // Layer 0 (kv16) scales stay exactly 1.0; layer 3 (kv4) must not.
        for h in 0..m.n_kv_heads {
            for j in 0..3 {
                assert_eq!(out.k_scales[h * 32 + j], 1.0);
                let s3 = out.k_scales[(3 * m.n_kv_heads + h) * 32 + j];
                assert!(s3 > 0.0 && s3 != 1.0, "kv4 layer scale {s3}");
            }
        }
    }
}

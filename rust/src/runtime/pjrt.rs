//! `PjrtBackend`: the AOT-artifact execution backend (behind `pjrt`).
//!
//! Wraps [`client::Runtime`] (PJRT client + compiled-executable cache) in
//! the [`ExecutionBackend`] surface: graph-name selection, host-tensor
//! packing, and output unpacking all live here, so the coordinator never
//! sees PJRT types. One compiled executable exists per (precision, bucket)
//! variant; construction fails loudly when the configured precision has no
//! compiled graphs.

use anyhow::{bail, Context};

use super::backend::{
    DecodeArgs, ExecutionBackend, ExecutionPlan, ModelSpec, PrefillArgs, StepOutputs,
};
use super::client::Runtime;
use super::manifest::Manifest;
use super::tensor::{Dt, HostTensor};
use crate::config::{DType, PrecisionFormat};
use crate::kvcache::KvPrecision;
use crate::Result;

/// The PJRT-backed execution backend.
pub struct PjrtBackend {
    runtime: Runtime,
    model: ModelSpec,
    plan: ExecutionPlan,
    precision: PrecisionFormat,
    wprec: &'static str,
    kv_key: &'static str,
    kv_prec: KvPrecision,
    max_batch: usize,
}

impl PjrtBackend {
    /// Load artifacts from `artifacts_dir` and validate that every
    /// (batch ≤ `max_batch`, context) decode variant exists for `precision`.
    pub fn new(artifacts_dir: &str, precision: PrecisionFormat, max_batch: usize) -> Result<Self> {
        let runtime = Runtime::load(artifacts_dir)?;
        let m = &runtime.manifest.model;

        let wprec: &'static str = match precision.weight {
            DType::Int4 => "w4",
            DType::F16 | DType::F32 => "w16",
            other => bail!("no compiled weight variant for {other} weights"),
        };
        let kv_prec = KvPrecision::from_dtype(precision.kv)?;
        let kv_key = kv_prec.graph_key();

        for &b in &runtime.manifest.decode_batches {
            for &t in &runtime.manifest.decode_t {
                if b <= max_batch {
                    let name = Manifest::decode_graph(wprec, kv_key, b, t);
                    runtime.graph(&name).with_context(|| {
                        format!("precision {precision} has no compiled variant")
                    })?;
                }
            }
        }

        let model = ModelSpec {
            name: m.name.clone(),
            n_layers: m.n_layers,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_kv_heads,
            head_dim: m.head_dim,
            d_ff: m.d_ff,
            vocab_size: m.vocab_size,
            max_seq_len: m.max_seq_len,
            group_size: m.group_size,
        };
        let plan = ExecutionPlan {
            decode_batches: runtime.manifest.decode_batches.clone(),
            decode_t: runtime.manifest.decode_t.clone(),
            prefill_chunks: runtime.manifest.prefill_chunks.clone(),
        };
        Ok(Self { runtime, model, plan, precision, wprec, kv_key, kv_prec, max_batch })
    }

    fn code_dt(&self) -> Dt {
        match self.kv_prec {
            KvPrecision::F32 => Dt::F32,
            KvPrecision::Int8 => Dt::I8,
            KvPrecision::Int4 => Dt::U8,
        }
    }

    fn rb(&self) -> usize {
        self.kv_prec.row_bytes(self.model.head_dim)
    }

    /// Cache tensors for a gathered `[L, B, Hkv, t_pad, rb]` byte buffer.
    ///
    /// The borrowed backend args force one copy of the gathered buffers
    /// here (`to_vec`) that the pre-refactor engine avoided by moving its
    /// owned Vecs straight into tensors. Accepted tradeoff: borrowed args
    /// keep the `ExecutionBackend` contract free of buffer-ownership
    /// churn, and the upload to device copies these bytes again anyway.
    fn cache_tensors(
        &self,
        b: usize,
        t_pad: usize,
        k_codes: &[u8],
        k_scales: &[f32],
        v_codes: &[u8],
        v_scales: &[f32],
    ) -> Result<[HostTensor; 4]> {
        let m = &self.model;
        let code_dt = self.code_dt();
        let elem = code_dt.size();
        let cache_shape = vec![m.n_layers, b, m.n_kv_heads, t_pad, self.rb() / elem];
        let scale_shape = vec![m.n_layers, b, m.n_kv_heads, t_pad];
        Ok([
            HostTensor::new(code_dt, cache_shape.clone(), k_codes.to_vec())?,
            HostTensor::from_f32(scale_shape.clone(), k_scales)?,
            HostTensor::new(code_dt, cache_shape, v_codes.to_vec())?,
            HostTensor::from_f32(scale_shape, v_scales)?,
        ])
    }

    /// Compiled graphs exist per *uniform* KV precision only — a per-layer
    /// mixed layout has no executable variant, so reject it loudly instead
    /// of misreading strides.
    fn check_layout(&self, layout: &crate::kvcache::KvLayout) -> Result<()> {
        match layout.as_uniform() {
            Some(p) if p == self.kv_prec => Ok(()),
            _ => bail!(
                "pjrt backend has no compiled variant for per-layer KV layout `{layout}` \
                 (compiled graphs are uniform {}; run the sim backend for laddered layouts)",
                self.kv_key
            ),
        }
    }

    fn unpack(&self, outputs: Vec<HostTensor>, sim_time_s: f64) -> Result<StepOutputs> {
        let [logits, k_new, k_sc, v_new, v_sc] = take5(outputs)?;
        Ok(StepOutputs {
            logits: logits.as_f32()?,
            k_scales: k_sc.as_f32()?,
            v_scales: v_sc.as_f32()?,
            k_codes: k_new.data,
            v_codes: v_new.data,
            sim_time_s,
        })
    }
}

impl ExecutionBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model(&self) -> &ModelSpec {
        &self.model
    }

    fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    fn precision(&self) -> PrecisionFormat {
        self.precision
    }

    /// Pre-compile every graph this configuration can reach (keeps
    /// first-request latency flat).
    fn warmup(&self) -> Result<()> {
        let mut names = Vec::new();
        for &b in &self.plan.decode_batches {
            for &t in &self.plan.decode_t {
                if b <= self.max_batch {
                    names.push(Manifest::decode_graph(self.wprec, self.kv_key, b, t));
                }
            }
        }
        for &s in &self.plan.prefill_chunks {
            names.push(Manifest::prefill_graph(self.wprec, self.kv_key, s));
        }
        self.runtime.warmup(&names)
    }

    fn prefill(&self, args: &PrefillArgs<'_>) -> Result<StepOutputs> {
        self.check_layout(args.layout)?;
        let bucket = args.tokens.len();
        let graph = Manifest::prefill_graph(self.wprec, self.kv_key, bucket);
        let [kc, ks, vc, vs] = self.cache_tensors(
            1, args.t_pad, args.k_codes, args.k_scales, args.v_codes, args.v_scales,
        )?;
        let outputs = self.runtime.execute(
            &graph,
            &[
                HostTensor::from_i32(vec![bucket], args.tokens)?,
                HostTensor::from_i32(vec![1], &[args.pos as i32])?,
                kc,
                ks,
                vc,
                vs,
            ],
        )?;
        self.unpack(outputs, 0.0)
    }

    fn decode(&self, args: &DecodeArgs<'_>) -> Result<StepOutputs> {
        self.check_layout(args.layout)?;
        let bsize = args.tokens.len();
        let graph = Manifest::decode_graph(self.wprec, self.kv_key, bsize, args.t_pad);
        let [kc, ks, vc, vs] = self.cache_tensors(
            bsize, args.t_pad, args.k_codes, args.k_scales, args.v_codes, args.v_scales,
        )?;
        let outputs = self.runtime.execute(
            &graph,
            &[
                HostTensor::from_i32(vec![bsize], args.tokens)?,
                HostTensor::from_i32(vec![bsize], args.kv_len)?,
                kc,
                ks,
                vc,
                vs,
            ],
        )?;
        self.unpack(outputs, 0.0)
    }
}

fn take5(mut v: Vec<HostTensor>) -> Result<[HostTensor; 5]> {
    if v.len() != 5 {
        bail!("expected 5 outputs, got {}", v.len());
    }
    let e = v.remove(4);
    let d = v.remove(3);
    let c = v.remove(2);
    let b = v.remove(1);
    let a = v.remove(0);
    Ok([a, b, c, d, e])
}

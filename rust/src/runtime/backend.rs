//! The pluggable execution backend: the surface the serving coordinator
//! drives, independent of *how* graphs execute.
//!
//! The engine owns request lifecycle, scheduling, sampling, and the paged
//! quantized KV pool; a backend owns the model forward pass. Two
//! implementations exist:
//!
//! * [`crate::runtime::SimBackend`] — deterministic pure-Rust execution:
//!   seeded pseudo-transformer logits that honor the configured
//!   [`PrecisionFormat`] through the `quant` round-trip error models, with
//!   iteration latency from the `gpusim`/`serving_sim` cost models. Runs
//!   everywhere, hermetically (no artifacts, no Python, no network).
//! * `PjrtBackend` (behind the `pjrt` feature) — the AOT-compiled
//!   HLO graphs executed through the PJRT C API, exactly the seed's
//!   original request path.
//!
//! The contract mirrors the AOT graph signatures so the two backends are
//! interchangeable: prefill/decode consume the *gathered* quantized KV
//! batch tensors (`[L, B, Hkv, T, row_bytes]` codes + `[L, B, Hkv, T]`
//! scales) and emit logits plus the new tokens' quantized KV codes, which
//! the engine appends back into the pool untouched.

use crate::config::PrecisionFormat;
use crate::kvcache::KvLayout;
use crate::Result;

/// The served model's architecture, as the backend reports it.
///
/// For the PJRT backend this comes from the artifact manifest; for the sim
/// backend it is the same tiny Qwen-shaped config the artifacts are built
/// from (`config::ModelConfig::tiny`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq_len: usize,
    /// Groupwise weight-quantization group size.
    pub group_size: usize,
}

impl ModelSpec {
    /// The tiny Qwen-shaped model every hermetic test serves
    /// (mirrors `config::ModelConfig::tiny`).
    pub fn tiny() -> Self {
        let m = crate::config::ModelConfig::tiny();
        Self {
            name: m.name,
            n_layers: m.n_layers,
            d_model: m.d_model,
            n_heads: m.n_heads,
            n_kv_heads: m.n_kv_heads,
            head_dim: m.head_dim,
            d_ff: m.d_ff,
            vocab_size: m.vocab_size,
            max_seq_len: m.max_seq_len,
            group_size: 64,
        }
    }
}

/// The shape buckets a backend can execute. The engine picks the smallest
/// covering bucket per iteration (compiled-graph semantics: the PJRT
/// backend genuinely has one executable per bucket; the sim backend adopts
/// the same discipline so padding behaviour matches).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecutionPlan {
    /// Decode batch sizes, ascending.
    pub decode_batches: Vec<usize>,
    /// Decode context (padded KV length) buckets, ascending.
    pub decode_t: Vec<usize>,
    /// Prefill chunk lengths, ascending.
    pub prefill_chunks: Vec<usize>,
}

/// One prefill invocation: a chunk of prompt tokens on top of the
/// sequence's (possibly empty) gathered quantized past.
#[derive(Debug)]
pub struct PrefillArgs<'a> {
    /// Chunk token ids, padded to the compiled bucket length.
    pub tokens: &'a [i32],
    /// Real (unpadded) token count in this chunk.
    pub real: usize,
    /// Tokens of this sequence already prefilled (the chunk's base position).
    pub pos: usize,
    /// Padded context extent of the gathered cache tensors.
    pub t_pad: usize,
    /// Per-layer KV precision of the gathered cache (and of the codes this
    /// call must emit). Layer `l`'s rows are `layout.row_bytes(l, head_dim)`
    /// wide; the flat codes tensors are layer-major with those per-layer
    /// strides (layer `l` starts at `Hkv × t_pad ×
    /// layout.prefix_row_bytes(l, head_dim)`).
    pub layout: &'a KvLayout,
    /// Gathered past KV codes, `[L, 1, Hkv, t_pad, row_bytes(l)]`.
    pub k_codes: &'a [u8],
    /// Gathered past K scales, `[L, 1, Hkv, t_pad]`.
    pub k_scales: &'a [f32],
    pub v_codes: &'a [u8],
    pub v_scales: &'a [f32],
}

/// One decode invocation over a padded batch.
#[derive(Debug)]
pub struct DecodeArgs<'a> {
    /// Input token per slot (last sampled token), padded to the batch bucket.
    pub tokens: &'a [i32],
    /// Per-slot KV history length (1 for padding slots).
    pub kv_len: &'a [i32],
    /// Padded context extent of the gathered cache tensors.
    pub t_pad: usize,
    /// Per-layer KV precision of the gathered cache (see
    /// [`PrefillArgs::layout`]; layer `l` starts at `B × Hkv × t_pad ×
    /// layout.prefix_row_bytes(l, head_dim)`).
    pub layout: &'a KvLayout,
    /// Gathered KV codes, `[L, B, Hkv, t_pad, row_bytes(l)]`.
    pub k_codes: &'a [u8],
    pub k_scales: &'a [f32],
    pub v_codes: &'a [u8],
    pub v_scales: &'a [f32],
}

/// What one backend invocation produced.
///
/// Prefill: `logits` is `[bucket, vocab]` row-major (rows past `real` are
/// padding); KV codes are `[L, Hkv, bucket, row_bytes(l)]` with scales
/// `[L, Hkv, bucket]` — the layout `KvPool::append_chunk` consumes (rows at
/// layer `l` quantized to the request layout's per-layer precision).
///
/// Decode: `logits` is `[B, vocab]`; KV codes are `[L, B, Hkv,
/// row_bytes(l)]` with scales `[L, B, Hkv]` — the per-token append layout.
#[derive(Debug, Clone)]
pub struct StepOutputs {
    pub logits: Vec<f32>,
    pub k_codes: Vec<u8>,
    pub k_scales: Vec<f32>,
    pub v_codes: Vec<u8>,
    pub v_scales: Vec<f32>,
    /// Modeled device time for this invocation (0 when the backend measures
    /// nothing — the PJRT path is wall-clock-timed by its callers instead).
    pub sim_time_s: f64,
}

/// A model execution backend: load-weights at construction, then
/// prefill/decode from the request path.
pub trait ExecutionBackend {
    /// Short human-readable backend name (`"sim"` / `"pjrt"`).
    fn name(&self) -> &'static str;

    /// The served model's architecture.
    fn model(&self) -> &ModelSpec;

    /// The shape buckets this backend executes.
    fn plan(&self) -> &ExecutionPlan;

    /// The precision format the weights were loaded at.
    fn precision(&self) -> PrecisionFormat;

    /// Prepare for serving (compile graphs, prime caches). Idempotent.
    fn warmup(&self) -> Result<()>;

    /// Run one prefill chunk.
    fn prefill(&self, args: &PrefillArgs<'_>) -> Result<StepOutputs>;

    /// Run one decode step over a padded batch.
    fn decode(&self, args: &DecodeArgs<'_>) -> Result<StepOutputs>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_spec_matches_model_config() {
        let s = ModelSpec::tiny();
        let m = crate::config::ModelConfig::tiny();
        assert_eq!(s.vocab_size, m.vocab_size);
        assert_eq!(s.n_layers, m.n_layers);
        assert_eq!(s.n_kv_heads, m.n_kv_heads);
        assert_eq!(s.head_dim, m.head_dim);
        assert_eq!(s.max_seq_len, m.max_seq_len);
    }
}

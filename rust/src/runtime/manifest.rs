//! AOT artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `manifest.json` describes every lowered graph (input signature + weight
//! tail) and the weight binary layouts. The runtime validates shapes against
//! this before anything touches PJRT, so mismatches fail loudly at load
//! time rather than as cryptic XLA errors mid-request.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::tensor::Dt;
use crate::util::json::Json;

/// One graph input (or output) signature entry.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dt,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .req_arr("shape")?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            name: v.req_str("name")?.to_string(),
            dtype: Dt::parse(v.req_str("dtype")?)?,
            shape,
        })
    }
}

/// One AOT-lowered graph.
#[derive(Debug, Clone)]
pub struct GraphEntry {
    pub name: String,
    pub file: String,
    /// Dynamic (per-call) inputs, in positional order.
    pub inputs: Vec<TensorSpec>,
    /// Weight tensor names appended after the dynamic inputs.
    pub weight_inputs: Vec<String>,
}

/// A tensor slice inside a weight binary.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub name: String,
    pub dtype: Dt,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// One weight binary (per weight precision).
#[derive(Debug, Clone)]
pub struct WeightFile {
    pub file: String,
    pub tensors: Vec<WeightTensor>,
}

/// The served model's architecture as recorded by the AOT step.
#[derive(Debug, Clone)]
pub struct ManifestModel {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq_len: usize,
    pub group_size: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ManifestModel,
    pub decode_batches: Vec<usize>,
    /// Decode context buckets (padded KV extents the decode graphs were
    /// compiled at; the engine picks the smallest covering the batch).
    pub decode_t: Vec<usize>,
    pub prefill_chunks: Vec<usize>,
    pub graphs: BTreeMap<String, GraphEntry>,
    pub weights: BTreeMap<String, WeightFile>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;

        let m = v.get("model").ok_or_else(|| anyhow!("missing `model`"))?;
        let model = ManifestModel {
            name: m.req_str("name")?.to_string(),
            n_layers: m.req_usize("n_layers")?,
            d_model: m.req_usize("d_model")?,
            n_heads: m.req_usize("n_heads")?,
            n_kv_heads: m.req_usize("n_kv_heads")?,
            head_dim: m.req_usize("head_dim")?,
            d_ff: m.req_usize("d_ff")?,
            vocab_size: m.req_usize("vocab_size")?,
            max_seq_len: m.req_usize("max_seq_len")?,
            group_size: m.req_usize("group_size")?,
        };

        let to_usizes = |key: &str| -> Result<Vec<usize>> {
            v.req_arr(key)?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad `{key}` entry")))
                .collect()
        };

        let mut graphs = BTreeMap::new();
        for g in v.req_arr("graphs")? {
            let name = g.req_str("name")?.to_string();
            let inputs = g
                .req_arr("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let weight_inputs = g
                .req_arr("weight_inputs")?
                .iter()
                .map(|w| {
                    w.as_str().map(String::from).ok_or_else(|| anyhow!("bad weight name"))
                })
                .collect::<Result<Vec<_>>>()?;
            graphs.insert(
                name.clone(),
                GraphEntry { name, file: g.req_str("file")?.to_string(), inputs, weight_inputs },
            );
        }

        let mut weights = BTreeMap::new();
        let wobj = v
            .get("weights")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing `weights`"))?;
        for (prec, wf) in wobj {
            let tensors = wf
                .req_arr("tensors")?
                .iter()
                .map(|t| {
                    Ok(WeightTensor {
                        name: t.req_str("name")?.to_string(),
                        dtype: Dt::parse(t.req_str("dtype")?)?,
                        shape: t
                            .req_arr("shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<Vec<_>>>()?,
                        offset: t.req_usize("offset")?,
                        nbytes: t.req_usize("nbytes")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            weights.insert(
                prec.clone(),
                WeightFile { file: wf.req_str("file")?.to_string(), tensors },
            );
        }

        let decode_t = to_usizes("decode_t").unwrap_or_else(|_| vec![model.max_seq_len]);
        let manifest = Self {
            dir,
            model,
            decode_batches: to_usizes("decode_batches")?,
            decode_t,
            prefill_chunks: to_usizes("prefill_chunks")?,
            graphs,
            weights,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        if self.graphs.is_empty() {
            bail!("manifest has no graphs");
        }
        for g in self.graphs.values() {
            if !g.weight_inputs.is_empty() {
                // Every weight name must resolve in some weight file.
                let prec = if g.name.contains("_w4_") || g.name.ends_with("_w4") {
                    "w4"
                } else {
                    "w16"
                };
                let wf = self
                    .weights
                    .get(prec)
                    .ok_or_else(|| anyhow!("graph {} needs weights `{prec}`", g.name))?;
                for w in &g.weight_inputs {
                    if !wf.tensors.iter().any(|t| &t.name == w) {
                        bail!("graph {}: weight `{w}` not in weights_{prec}", g.name);
                    }
                }
            }
        }
        for (prec, wf) in &self.weights {
            let mut cursor = 0usize;
            for t in &wf.tensors {
                if t.offset != cursor {
                    bail!("weights_{prec}: tensor {} offset {} != cursor {cursor}", t.name, t.offset);
                }
                let expect: usize = t.shape.iter().product::<usize>() * t.dtype.size();
                if expect != t.nbytes {
                    bail!("weights_{prec}: tensor {} nbytes mismatch", t.name);
                }
                cursor += t.nbytes;
            }
        }
        Ok(())
    }

    /// Weight precision key a graph name implies (`w4` / `w16`).
    pub fn weight_precision_of(graph_name: &str) -> &'static str {
        if graph_name.contains("_w4_") {
            "w4"
        } else {
            "w16"
        }
    }

    /// Decode graph name for a precision pair + batch + context bucket.
    pub fn decode_graph(wprec: &str, kvprec: &str, batch: usize, t_pad: usize) -> String {
        format!("decode_{wprec}_{kvprec}_b{batch}_t{t_pad}")
    }

    /// Prefill graph name for a precision pair + chunk.
    pub fn prefill_graph(wprec: &str, kvprec: &str, chunk: usize) -> String {
        format!("prefill_{wprec}_{kvprec}_s{chunk}")
    }

    pub fn hlo_path(&self, graph: &GraphEntry) -> PathBuf {
        self.dir.join(&graph.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that need real artifacts live in `rust/tests/`; here we cover
    /// pure parsing with a synthetic manifest.
    fn synthetic_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{
            "model": {"name": "tiny", "n_layers": 1, "d_model": 8, "n_heads": 2,
                      "n_kv_heads": 1, "head_dim": 4, "d_ff": 16, "vocab_size": 32,
                      "max_seq_len": 64, "group_size": 8, "seed": 0},
            "decode_batches": [1, 2],
            "prefill_chunks": [8],
            "graphs": [
                {"name": "decode_w16_kv16_b1", "file": "d.hlo.txt",
                 "inputs": [{"name": "tokens", "dtype": "i32", "shape": [1]}],
                 "weight_inputs": ["embed"]}
            ],
            "weights": {
                "w16": {"file": "weights_w16.bin", "tensors": [
                    {"name": "embed", "dtype": "f32", "shape": [32, 8],
                     "offset": 0, "nbytes": 1024}
                ]}
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        dir
    }

    #[test]
    fn parses_synthetic_manifest() {
        let dir = synthetic_dir();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab_size, 32);
        assert_eq!(m.decode_batches, vec![1, 2]);
        let g = &m.graphs["decode_w16_kv16_b1"];
        assert_eq!(g.inputs[0].dtype, Dt::I32);
        assert_eq!(g.weight_inputs, vec!["embed"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn graph_name_helpers() {
        assert_eq!(Manifest::decode_graph("w4", "kv8", 4, 128), "decode_w4_kv8_b4_t128");
        assert_eq!(Manifest::prefill_graph("w16", "kv16", 32), "prefill_w16_kv16_s32");
        assert_eq!(Manifest::weight_precision_of("decode_w4_kv8_b4_t128"), "w4");
        assert_eq!(Manifest::weight_precision_of("decode_w16_kv16_b1"), "w16");
    }
}

//! Execution layer: the pluggable backend trait, the artifact manifest, and
//! the two backend implementations.
//!
//! * [`backend`] — the [`ExecutionBackend`] trait the coordinator drives:
//!   load-weights (construction), prefill, and decode over gathered
//!   quantized-KV batch tensors.
//! * [`sim`] — the default, hermetic [`SimBackend`]: deterministic seeded
//!   logits honoring the configured precision format, with `gpusim`-modeled
//!   iteration latency. No artifacts, no Python, no network.
//! * [`manifest`] — the AOT artifact contract (`manifest.json`), always
//!   compiled so artifact tooling and validation stay testable.
//! * [`client`] / [`pjrt`] / [`tensor`]'s literal conversions — the PJRT
//!   path (`python/compile/aot.py` lowers the Layer-2 graphs to HLO text;
//!   these execute them via the `xla` crate), behind the `pjrt` feature.

pub mod backend;
pub mod manifest;
pub mod sim;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{
    DecodeArgs, ExecutionBackend, ExecutionPlan, ModelSpec, PrefillArgs, StepOutputs,
};
pub use manifest::{GraphEntry, Manifest, TensorSpec};
pub use sim::SimBackend;
pub use tensor::{Dt, HostTensor};

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

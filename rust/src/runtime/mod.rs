//! PJRT runtime layer: artifact manifest, host tensors, and the executable
//! cache that runs the AOT-compiled graphs from the request path.
//!
//! Python (`python/compile/aot.py`) lowers the Layer-2 graphs to HLO text at
//! build time; this module loads and executes them via the `xla` crate's
//! PJRT CPU client. No Python anywhere at runtime.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::Runtime;
pub use manifest::{GraphEntry, Manifest, TensorSpec};
pub use tensor::{Dt, HostTensor};

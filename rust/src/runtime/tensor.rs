//! Host-side tensors (and, behind the `pjrt` feature, conversions to/from
//! PJRT `Literal`s).
//!
//! The engine moves four dtypes across the backend boundary: `f32`
//! activations and scales, `i32` tokens/lengths, and `i8`/`u8` quantized
//! codes. A [`HostTensor`] owns raw little-endian bytes plus shape/dtype
//! metadata — the same layout the weight binaries use, so weight loading is
//! a single read + slice.

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context};
use anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use xla::{ArrayShape, ElementType, Literal};

/// Element types crossing the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dt {
    F32,
    I32,
    I8,
    U8,
}

impl Dt {
    pub fn size(self) -> usize {
        match self {
            Dt::F32 | Dt::I32 => 4,
            Dt::I8 | Dt::U8 => 1,
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn to_element_type(self) -> ElementType {
        match self {
            Dt::F32 => ElementType::F32,
            Dt::I32 => ElementType::S32,
            Dt::I8 => ElementType::S8,
            Dt::U8 => ElementType::U8,
        }
    }

    /// Parse the manifest's dtype names.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dt::F32,
            "i32" => Dt::I32,
            "i8" => Dt::I8,
            "u8" => Dt::U8,
            other => bail!("unsupported dtype `{other}`"),
        })
    }
}

/// An owned host tensor: raw bytes + shape + dtype.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub dtype: Dt,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn new(dtype: Dt, shape: Vec<usize>, data: Vec<u8>) -> Result<Self> {
        let expect: usize = shape.iter().product::<usize>() * dtype.size();
        if data.len() != expect {
            bail!("tensor data {} bytes, shape {:?} needs {}", data.len(), shape, expect);
        }
        Ok(Self { dtype, shape, data })
    }

    pub fn zeros(dtype: Dt, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product::<usize>() * dtype.size();
        Self { dtype, shape, data: vec![0u8; n] }
    }

    pub fn from_f32(shape: Vec<usize>, vals: &[f32]) -> Result<Self> {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self::new(Dt::F32, shape, data)
    }

    pub fn from_i32(shape: Vec<usize>, vals: &[i32]) -> Result<Self> {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self::new(Dt::I32, shape, data)
    }

    pub fn from_i8(shape: Vec<usize>, vals: &[i8]) -> Result<Self> {
        Self::new(Dt::I8, shape, vals.iter().map(|&v| v as u8).collect())
    }

    pub fn from_u8(shape: Vec<usize>, vals: &[u8]) -> Result<Self> {
        Self::new(Dt::U8, shape, vals.to_vec())
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dt::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dt::I32 {
            bail!("tensor is {:?}, not i32", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i8(&self) -> Result<&[u8]> {
        if self.dtype != Dt::I8 {
            bail!("tensor is {:?}, not i8", self.dtype);
        }
        Ok(&self.data)
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        if self.dtype != Dt::U8 {
            bail!("tensor is {:?}, not u8", self.dtype);
        }
        Ok(&self.data)
    }

    /// Convert to a PJRT `Literal` (copies the bytes).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        Literal::create_from_shape_and_untyped_data(
            self.dtype.to_element_type(),
            &self.shape,
            &self.data,
        )
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    /// Convert a PJRT `Literal` back to a host tensor.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let arr = ArrayShape::try_from(&shape).map_err(|e| anyhow!("array shape: {e:?}"))?;
        let dims: Vec<usize> = arr.dims().iter().map(|&d| d as usize).collect();
        let dtype = match arr.ty() {
            ElementType::F32 => Dt::F32,
            ElementType::S32 => Dt::I32,
            ElementType::S8 => Dt::I8,
            ElementType::U8 => Dt::U8,
            other => bail!("unsupported output element type {other:?}"),
        };
        let data = literal_bytes(lit, dtype, dims.iter().product())
            .context("literal raw copy")?;
        Ok(Self { dtype, shape: dims, data })
    }
}

/// Copy a literal's elements out as little-endian bytes. Uses the crate's
/// typed `copy_raw_to` (a direct memcpy) per dtype.
#[cfg(feature = "pjrt")]
fn literal_bytes(lit: &Literal, dtype: Dt, n: usize) -> Result<Vec<u8>> {
    match dtype {
        Dt::F32 => {
            let mut v = vec![0f32; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("{e:?}"))?;
            let mut out = Vec::with_capacity(n * 4);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
            Ok(out)
        }
        Dt::I32 => {
            let mut v = vec![0i32; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("{e:?}"))?;
            let mut out = Vec::with_capacity(n * 4);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
            Ok(out)
        }
        Dt::I8 => {
            let mut v = vec![0i8; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("{e:?}"))?;
            Ok(v.into_iter().map(|x| x as u8).collect())
        }
        Dt::U8 => {
            let mut v = vec![0u8; n];
            lit.copy_raw_to(&mut v).map_err(|e| anyhow!("{e:?}"))?;
            Ok(v)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_bytes() {
        let t = HostTensor::from_f32(vec![2, 2], &[1.0, -2.5, 3.25, 0.0]).unwrap();
        assert_eq!(t.as_f32().unwrap(), vec![1.0, -2.5, 3.25, 0.0]);
        assert_eq!(t.element_count(), 4);
    }

    #[test]
    fn i32_roundtrip() {
        let t = HostTensor::from_i32(vec![3], &[-1, 0, 7]).unwrap();
        assert_eq!(t.as_i32().unwrap(), vec![-1, 0, 7]);
    }

    #[test]
    fn i8_stores_twos_complement() {
        let t = HostTensor::from_i8(vec![2], &[-1, 7]).unwrap();
        assert_eq!(t.as_i8().unwrap(), &[0xFF, 7]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::new(Dt::F32, vec![2], vec![0u8; 4]).is_err());
        assert!(HostTensor::new(Dt::U8, vec![4], vec![0u8; 4]).is_ok());
    }

    #[test]
    fn wrong_dtype_accessors_fail() {
        let t = HostTensor::zeros(Dt::U8, vec![4]);
        assert!(t.as_f32().is_err());
        assert!(t.as_u8().is_ok());
    }

    #[test]
    fn dt_parse() {
        assert_eq!(Dt::parse("f32").unwrap(), Dt::F32);
        assert_eq!(Dt::parse("u8").unwrap(), Dt::U8);
        assert!(Dt::parse("f64").is_err());
    }
}
